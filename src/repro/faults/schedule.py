"""Replayable fault schedules — the deterministic half of the chaos harness.

A :class:`FaultSchedule` is a tuple of :class:`FaultEvent` records plus a
seed.  Everything downstream is a pure function of (schedule, fleet,
trace): the injector derives every random draw (which blobs to corrupt)
from ``(seed, step)``, so re-running the same schedule over the same
trace replays the same faults bit-for-bit — chaos results are diffable
across commits, which is the whole point.

Fault taxonomy (``FaultEvent.kind``):

* ``"crash"``     — hard node crash (``CacheGenius.crash_node``: cache
  lost, nothing reassigned); ``duration > 0`` schedules a rejoin that
  many steps later — journal-replayed when the injector holds a
  ``CacheJournal`` for the node, cold otherwise.
* ``"fail"``      — graceful failure (``fail_node``: shard reassigned).
* ``"transient"`` — arm the :class:`repro.faults.injector.FlakyBackend`
  to fail the next ``count`` backend generation calls with
  ``TransientBackendError`` (fleet-level: backend calls carry no node
  identity; the Generate stage attributes each to the failing group's
  node).
* ``"corrupt"``   — silently corrupt a ``frac`` fraction of the blob
  store's entries (checksums left stale — only verify-on-hit catches it).
* ``"stall"``     — slow-node stall: multiply the node's speed by
  ``factor`` for ``duration`` steps, then restore it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

__all__ = ["FaultEvent", "FaultSchedule", "PRESETS"]

_KINDS = ("crash", "fail", "transient", "corrupt", "stall")


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.  ``step`` is the injection boundary the event
    fires at (group number in group mode, denoising-step number in
    step-level mode); unused fields are ignored per kind."""

    step: int
    kind: str
    node: int = -1          # crash/fail/stall target; -1 = fleet-level
    count: int = 1          # transient: backend calls to fail
    duration: int = 0       # crash: steps until rejoin (0 = stay down);
    #                         stall: steps before the speed is restored
    factor: float = 0.25    # stall: speed multiplier while stalled
    frac: float = 0.25      # corrupt: fraction of live blobs to damage

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {_KINDS}")
        if self.step < 0:
            raise ValueError(f"step must be >= 0, got {self.step}")


@dataclass(frozen=True)
class FaultSchedule:
    """An immutable, seeded script of fault events."""

    events: Tuple[FaultEvent, ...]
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def at(self, step: int) -> List[FaultEvent]:
        """Events firing at this injection boundary, in script order."""
        return [e for e in self.events if e.step == step]

    def rng(self, step: int) -> np.random.Generator:
        """The deterministic per-step random stream: every draw the
        injector makes at ``step`` comes from here, so a schedule replays
        identically however many times it runs."""
        return np.random.default_rng([self.seed, step])

    @property
    def horizon(self) -> int:
        """Last scripted step (rejoins scheduled past it still apply —
        the injector tracks them independently)."""
        return max((e.step for e in self.events), default=0)

    # -- canned schedules -----------------------------------------------------

    @classmethod
    def preset(cls, name: str, *, nodes: int, horizon: int,
               seed: int = 0) -> "FaultSchedule":
        """A named schedule scaled to the fleet/trace at hand.  ``nodes``
        is the fleet size (crash/stall targets are chosen inside it);
        ``horizon`` the number of injection boundaries the run will see
        (events land at fixed fractions of it)."""
        if name not in PRESETS:
            raise ValueError(
                f"unknown preset {name!r}; expected one of "
                f"{sorted(PRESETS)}")
        if nodes < 2 and name in ("chaos", "crash"):
            raise ValueError(
                f"preset {name!r} crashes a node and needs nodes >= 2, "
                f"got {nodes}")
        horizon = max(int(horizon), 10)
        return cls(events=tuple(PRESETS[name](nodes, horizon)), seed=seed)

    @classmethod
    def generate(cls, *, nodes: int, horizon: int, seed: int,
                 rate: float = 0.05) -> "FaultSchedule":
        """A seeded random schedule: each boundary independently draws a
        fault with probability ``rate`` (kind uniform over transient /
        corrupt / stall — crashes are scripted, not drawn, so zero-loss
        accounting stays easy to reason about)."""
        rng = np.random.default_rng([seed, nodes, horizon])
        events = []
        for step in range(int(horizon)):
            if rng.random() >= rate:
                continue
            kind = ("transient", "corrupt", "stall")[int(rng.integers(3))]
            if kind == "stall":
                events.append(FaultEvent(
                    step=step, kind="stall",
                    node=int(rng.integers(nodes)),
                    duration=int(rng.integers(2, 6))))
            elif kind == "corrupt":
                events.append(FaultEvent(step=step, kind="corrupt",
                                         frac=0.1))
            else:
                events.append(FaultEvent(step=step, kind="transient",
                                         count=int(rng.integers(1, 3))))
        return cls(events=tuple(events), seed=seed)


def _crash_events(nodes: int, horizon: int) -> List[FaultEvent]:
    down = max(2, horizon // 5)
    return [FaultEvent(step=max(1, int(horizon * 0.3)), kind="crash",
                       node=nodes - 1, duration=down)]


def _corrupt_events(nodes: int, horizon: int) -> List[FaultEvent]:
    return [FaultEvent(step=max(1, int(horizon * f)), kind="corrupt",
                       frac=0.25) for f in (0.3, 0.6)]


def _transient_events(nodes: int, horizon: int) -> List[FaultEvent]:
    return [FaultEvent(step=max(1, int(horizon * f)), kind="transient",
                       count=2) for f in (0.2, 0.5, 0.8)]


def _chaos_events(nodes: int, horizon: int) -> List[FaultEvent]:
    events = (_transient_events(nodes, horizon)
              + _corrupt_events(nodes, horizon)
              + _crash_events(nodes, horizon))
    events.append(FaultEvent(step=max(1, int(horizon * 0.45)), kind="stall",
                             node=0, duration=max(2, horizon // 10),
                             factor=0.25))
    return sorted(events, key=lambda e: e.step)


PRESETS = {
    "crash": _crash_events,
    "corrupt": _corrupt_events,
    "transient": _transient_events,
    "chaos": _chaos_events,
}
