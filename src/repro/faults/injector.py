"""Fault injector — applies a :class:`FaultSchedule` to a live fleet.

Wire-up (see ``repro.launch.serve`` for the CLI form)::

    journals = attach_journals(system, "/tmp/journals")   # durability on
    injector = FaultInjector(system, schedule, journals=journals)
    engine.run(trace, on_step=injector.on_step, ...)      # either mode
    print(injector.report())

The injector owns three fault surfaces:

* the serving engine's ``on_step`` hook — crash/fail/stall/corrupt
  events fire at the injection boundary they are scripted for, and
  scheduled rejoins/unstalls land the boundary their countdown expires;
* a :class:`FlakyBackend` proxy swapped in as ``system.backend`` —
  transient events arm it to raise ``TransientBackendError`` from the
  next N generation calls (the retry machinery in
  ``GenerateStage``/``ServingEngine``/``Dispatcher`` absorbs them);
* the node journals (optional) — a crashed node with a journal rejoins
  via ``CacheJournal.replay`` + ``CacheGenius.rejoin_node`` (bitwise its
  pre-crash cache); without one it rejoins cold.

Every action is appended to ``self.log`` as ``(step, action, detail)``
so a chaos run is auditable after the fact; :meth:`report` summarises.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.journal import CacheJournal
from repro.core.pipeline import TransientBackendError
from repro.faults.schedule import FaultEvent, FaultSchedule

__all__ = ["FaultInjector", "FlakyBackend", "attach_journals"]


class FlakyBackend:
    """Transparent generation-backend proxy with an armable fault
    counter: while armed, the three batched generation entry points
    raise :class:`TransientBackendError` instead of generating (one
    charge per call).  Everything else — scalar entry points, latent
    archiving, ``make_slot_engine``, ``supports_latent_resume`` —
    delegates untouched, so a real accelerator backend keeps its own
    slot engine and compiled functions."""

    def __init__(self, inner):
        self._inner = inner
        self._armed = 0
        self.faults_injected = 0

    def arm(self, count: int = 1) -> None:
        """Fail the next ``count`` generation calls.  Saturating, not
        additive: two transient events with no backend call between them
        leave the counter at ``max`` of the two, so no single retried
        call ever faces more consecutive faults than one scripted event's
        ``count`` — which is what keeps scripted chaos inside the serving
        stack's ``transient_retries`` budget (zero accepted-job loss)."""
        self._armed = max(self._armed, int(count))

    def _maybe_fail(self) -> None:
        if self._armed > 0:
            self._armed -= 1
            self.faults_injected += 1
            raise TransientBackendError("injected transient backend fault")

    def txt2img_batch(self, *args, **kwargs):
        self._maybe_fail()
        return self._inner.txt2img_batch(*args, **kwargs)

    def img2img_batch(self, *args, **kwargs):
        self._maybe_fail()
        return self._inner.img2img_batch(*args, **kwargs)

    def resume_batch(self, *args, **kwargs):
        self._maybe_fail()
        return self._inner.resume_batch(*args, **kwargs)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def attach_journals(system, root: str, *,
                    snapshot_every: int = 64) -> Dict[int, CacheJournal]:
    """One :class:`CacheJournal` per node under ``root/node<i>/``, bound
    to the node's ``VectorDB``.  A base snapshot is published immediately
    so pre-attach cache content (the corpus pre-population) is part of
    the durable state — the WAL only ever needs to cover mutations made
    AFTER attachment."""
    journals: Dict[int, CacheJournal] = {}
    for i, db in enumerate(system.dbs):
        j = CacheJournal(os.path.join(root, f"node{i}"),
                         snapshot_every=snapshot_every)
        db.attach_journal(j)
        j.snapshot()
        journals[i] = j
    return journals


class FaultInjector:
    """Applies a :class:`FaultSchedule` to ``system`` via ``on_step``.

    Constructing the injector swaps ``system.backend`` for a
    :class:`FlakyBackend` proxy (kept on ``self.backend``); pass
    ``journals`` (from :func:`attach_journals`) to give crashed nodes a
    durable rejoin path."""

    def __init__(self, system, schedule: FaultSchedule, *,
                 journals: Optional[Dict[int, CacheJournal]] = None):
        self.system = system
        self.schedule = schedule
        self.journals = dict(journals or {})
        self.backend = FlakyBackend(system.backend)
        system.backend = self.backend
        self.log: List[Tuple[int, str, str]] = []
        self._rejoin_at: Dict[int, List[int]] = {}        # step -> nodes
        self._unstall_at: Dict[int, List[Tuple[int, float]]] = {}
        self.steps_seen = 0

    # -- the hook -------------------------------------------------------------

    def on_step(self, step_no: int) -> None:
        """The serving engine's injection hook: settle due countdowns
        (rejoins, unstalls) first, then fire this boundary's events."""
        self.steps_seen = max(self.steps_seen, step_no + 1)
        for node in self._rejoin_at.pop(step_no, []):
            self._rejoin(node, step_no)
        for node, speed in self._unstall_at.pop(step_no, []):
            self.system.scheduler.nodes[node].speed = speed
            self.log.append((step_no, "unstall", f"node{node}"))
        for e in self.schedule.at(step_no):
            self._fire(e, step_no)

    def finish(self) -> None:
        """Settle countdowns still pending when the trace ends (a rejoin
        scheduled past the last step must still happen, or the recovery
        benchmarks would compare against a half-dead fleet)."""
        for step in sorted(self._rejoin_at):
            for node in self._rejoin_at[step]:
                self._rejoin(node, step)
        self._rejoin_at.clear()
        for step in sorted(self._unstall_at):
            for node, speed in self._unstall_at[step]:
                self.system.scheduler.nodes[node].speed = speed
                self.log.append((step, "unstall", f"node{node}"))
        self._unstall_at.clear()

    # -- event handlers -------------------------------------------------------

    def _fire(self, e: FaultEvent, step_no: int) -> None:
        if e.kind == "crash":
            self._crash(e, step_no)
        elif e.kind == "fail":
            if self.system.scheduler.nodes[e.node].alive:
                self.system.fail_node(e.node)
                self.log.append((step_no, "fail", f"node{e.node}"))
            else:
                self.log.append((step_no, "skip-fail",
                                 f"node{e.node} already dead"))
        elif e.kind == "transient":
            self.backend.arm(e.count)
            self.log.append((step_no, "transient", f"arm {e.count}"))
        elif e.kind == "corrupt":
            self._corrupt(e, step_no)
        elif e.kind == "stall":
            n = self.system.scheduler.nodes[e.node]
            self._unstall_at.setdefault(step_no + max(e.duration, 1),
                                        []).append((e.node, n.speed))
            n.speed *= e.factor
            self.log.append((step_no, "stall",
                             f"node{e.node} x{e.factor} "
                             f"for {max(e.duration, 1)}"))

    def _crash(self, e: FaultEvent, step_no: int) -> None:
        sched = self.system.scheduler
        if not sched.nodes[e.node].alive:
            self.log.append((step_no, "skip-crash",
                             f"node{e.node} already dead"))
            return
        if sum(n.alive for n in sched.nodes) == 1:
            self.log.append((step_no, "skip-crash",
                             f"node{e.node} is the last alive node"))
            return
        self.system.crash_node(e.node)
        self.log.append((step_no, "crash", f"node{e.node}"))
        if e.duration > 0:
            self._rejoin_at.setdefault(step_no + e.duration,
                                       []).append(e.node)

    def _corrupt(self, e: FaultEvent, step_no: int) -> None:
        store = self.system.blob_store
        bids = sorted(store._blobs)
        if not bids:
            self.log.append((step_no, "skip-corrupt", "empty blob store"))
            return
        rng = self.schedule.rng(step_no)
        k = max(1, int(round(len(bids) * e.frac)))
        picks = rng.choice(np.asarray(bids), size=min(k, len(bids)),
                           replace=False)
        for bid in picks:
            store.corrupt(int(bid), rng)
        self.log.append((step_no, "corrupt", f"{len(picks)} blobs"))

    def _rejoin(self, node: int, step_no: int) -> None:
        if self.system.scheduler.nodes[node].alive:
            self.log.append((step_no, "skip-rejoin",
                             f"node{node} already alive"))
            return
        j = self.journals.get(node)
        cur = self.system.dbs[node]
        if j is not None:
            db = j.replay(cur.dim, cur.capacity, name=cur.name,
                          use_pallas=cur.use_pallas, interpret=cur.interpret)
            db.attach_journal(j)
            self.system.rejoin_node(node, db)
            self.log.append((step_no, "rejoin-journaled",
                             f"node{node} ({db.size} entries)"))
        else:
            self.system.rejoin_node(node)
            self.log.append((step_no, "rejoin-cold", f"node{node}"))

    # -- summary --------------------------------------------------------------

    def report(self) -> Dict[str, object]:
        """Audit summary of the run: what fired, what the system absorbed."""
        counts: Dict[str, int] = {}
        for _, action, _ in self.log:
            counts[action] = counts.get(action, 0) + 1
        stats = self.system.stats
        return {
            "steps_seen": self.steps_seen,
            "actions": counts,
            "faults_injected": self.backend.faults_injected,
            "corrupt_hits": stats.corrupt_hits,
            "degraded_serves": stats.degraded_serves,
            "transient_retries": stats.transient_retries,
            "log": list(self.log),
        }
