"""Deterministic fault-injection harness (chaos engineering surface).

``FaultSchedule`` is a replayable, seeded script of fault events at step
granularity; ``FaultInjector`` applies it to a live ``CacheGenius``
fleet through the serving engine's ``on_step`` hook (group mode fires it
per group, step-level mode per denoising step).  See
``docs/ARCHITECTURE.md`` (Fault tolerance) for the taxonomy and the
invariants every chaos run must preserve.
"""
from repro.faults.injector import FaultInjector, FlakyBackend, \
    attach_journals
from repro.faults.schedule import FaultEvent, FaultSchedule

__all__ = ["FaultEvent", "FaultSchedule", "FaultInjector", "FlakyBackend",
           "attach_journals"]
