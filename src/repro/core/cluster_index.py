"""Device-resident cross-node retrieval engine (ROADMAP: "cross-node
batched retrieval — one fused scan over all node slabs").

The cluster's whole cache state lives ON DEVICE as one stacked slab

    slabs: (2, nodes, capacity, dim)    # plane 0 = img index, 1 = txt
    valid: (nodes, capacity)            # shared dual-index validity

and is updated INCREMENTALLY: every ``VectorDB.add`` / ``evict_slots``
pushes only the touched rows through a donated functional
``.at[node, slots].set`` — after the one build-time upload there are no
steady-state host→device slab copies (pinned by the transfer-count
test; ``stats["slab_uploads"]`` counts full-slab uploads,
``stats["row_updates"]`` the incremental ones).

Retrieval is ONE fused scan per micro-batch regardless of node count:
``search_batch`` answers every query against its scheduled node's slab
(query→node mask) across both dual-retrieval indexes in a single device
launch — the jnp path is one masked einsum + top-k, the Pallas path is
:func:`repro.kernels.vdb_topk.vdb_topk_sharded` with grid
``(index, node, db_block)`` and the per-query running top-k in VMEM
scratch.  Two all-nodes modes share the same launch structure:
``search_cluster`` (one flat global candidate list per query) and
``search_cluster_nodes`` (a top-k PER node per query — the scan that
score-aware scheduling issues once per micro-batch and the Retrieve
stage then reuses for the chosen node's candidates, collapsing the
Schedule and Retrieve device scans into one).

Each :class:`repro.core.vdb.VectorDB` stays the per-node VIEW over this
shared state: its numpy arrays remain the host source of truth for
eviction bookkeeping / snapshot / restore, and once registered here its
``search``/``search_batch`` delegate to the fused device scan with
identical semantics (same union-dedup, same FIFO-overwrite and eviction
behaviour — pinned by parity tests against the per-node jnp oracle).

``mesh_nodes > 1`` shards all of the above over a 1-D ``("nodes",)``
device mesh: the node axis pads up to a multiple of the mesh size with
masked-invalid nodes, the slabs/validity live as ``NamedSharding``
arrays (specs from :mod:`repro.runtime.partition`), and every scan mode
runs the same per-node kernels inside ``shard_map``
(:func:`repro.kernels.vdb_topk.vdb_topk_sharded_mesh` /
``vdb_topk_pernode_mesh``) so each device scans only its local node
shard.  Only the per-node best-k rows are gathered
(``stats["allgather_bytes"]`` counts them) and the cross-shard merge
(:func:`repro.kernels.vdb_topk.merge_shard_topk`) reproduces the
single-device tie-break bitwise.  Incremental row updates go through
the SAME donated scatter — XLA routes each write to the owning shard,
so the zero steady-state host→device-slab-copy guarantee (and its
stats pins) carries over unchanged.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vdb import VectorDB, _union_topk
from repro.utils import l2n, next_pow2


@partial(jax.jit, donate_argnums=(0, 1))
def _apply_rows(slabs, valid, node, slots, img_rows, txt_rows, flags):
    """Write freshly inserted rows into both index planes + validity.
    Donation keeps the update in place — no slab reallocation."""
    slabs = slabs.at[0, node, slots].set(img_rows)
    slabs = slabs.at[1, node, slots].set(txt_rows)
    valid = valid.at[node, slots].set(flags)
    return slabs, valid


@partial(jax.jit, donate_argnums=(0,))
def _apply_valid(valid, node, slots, flags):
    """Eviction only flips validity — the stale vectors stay in place,
    exactly like the numpy slabs (so device state == rebuilt-from-host)."""
    return valid.at[node, slots].set(flags)


@partial(jax.jit, static_argnames=("k", "mask_nodes"))
def _fused_topk(slabs, valid, queries, node_ids, k: int, mask_nodes: bool):
    """jnp path of the fused scan — jitted delegation to the shared test
    oracle (one masked einsum over the flattened cluster, global slot ids
    ``node * cap + col``), numerically the per-node ``_masked_topk_batch``
    restricted to each query's scheduled node."""
    from repro.kernels.ref import vdb_topk_sharded_ref
    return vdb_topk_sharded_ref(queries, slabs, valid, node_ids, k,
                                mask_nodes=mask_nodes)


@partial(jax.jit, static_argnames=("k",))
def _fused_topk_pernode(slabs, valid, queries, k: int):
    """jnp path of the per-node scan (one einsum + per-node top-k) —
    jitted delegation to the shared test oracle."""
    from repro.kernels.ref import vdb_topk_pernode_ref
    return vdb_topk_pernode_ref(queries, slabs, valid, k)


class ClusterIndex:
    """Device-resident dual-index cache state for a whole node fleet."""

    def __init__(self, dim: int, capacities: Sequence[int], *,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 mesh_nodes: int = 1):
        self.dim = dim
        self.capacities = [int(c) for c in capacities]
        self.n_nodes = len(self.capacities)
        self.capacity = max(self.capacities) if self.capacities else 0
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.mesh_nodes = int(mesh_nodes)
        self.dbs: List[Optional[VectorDB]] = [None] * self.n_nodes
        self.stats: Dict[str, int] = {
            "slab_uploads": 0, "row_updates": 0, "fused_scans": 0,
            "allgather_bytes": 0}
        if self.mesh_nodes > 1:
            from repro.launch.mesh import make_node_mesh
            self._mesh = make_node_mesh(self.mesh_nodes)
            # pad the node axis to a mesh multiple with masked-invalid
            # nodes (their validity rows stay all-False forever, so their
            # NEG_INF candidates never survive the union)
            self.padded_nodes = (
                -(-max(self.n_nodes, 1) // self.mesh_nodes)
                * self.mesh_nodes)
        else:
            self._mesh = None
            self.padded_nodes = self.n_nodes
        self._slabs = self._shard(
            jnp.zeros((2, self.padded_nodes, self.capacity, dim),
                      jnp.float32), slab=True)
        self._valid = self._shard(
            jnp.zeros((self.padded_nodes, self.capacity), bool), slab=False)

    def _shard(self, arr, *, slab: bool):
        """Commit ``arr`` (jnp or host numpy) to the node mesh — without
        one, a plain device array (``device_put`` IS the one upload when
        ``arr`` is numpy, no staging copy)."""
        if self._mesh is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding

        from repro.runtime.partition import (CLUSTER_SLAB_SPEC,
                                             CLUSTER_VALID_SPEC)
        spec = CLUSTER_SLAB_SPEC if slab else CLUSTER_VALID_SPEC
        return jax.device_put(arr, NamedSharding(self._mesh, spec))

    def per_device_slab_bytes(self) -> int:
        """Bytes of cluster cache state resident on EACH device — the
        quantity the mesh shrinks ~linearly (benchmarks gate on it)."""
        if self._mesh is None:
            return int(self._slabs.nbytes + self._valid.nbytes)
        from repro.runtime.partition import (CLUSTER_SLAB_SPEC,
                                            CLUSTER_VALID_SPEC,
                                            count_sharded_bytes)
        return count_sharded_bytes(
            [self._slabs, self._valid],
            [CLUSTER_SLAB_SPEC, CLUSTER_VALID_SPEC], self._mesh)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_dbs(cls, dbs: Sequence[VectorDB], *,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 mesh_nodes: int = 1) -> "ClusterIndex":
        """Build the stacked device slabs from a fleet's current numpy
        state (ONE upload) and register each db as a view: subsequent
        mutations flow through the incremental row updates.
        ``mesh_nodes > 1`` commits the upload straight to the node mesh —
        still ONE host→device transfer, just scattered across shards."""
        if use_pallas is None:
            use_pallas = any(db.use_pallas for db in dbs)
        if interpret is None:
            interprets = {db.interpret for db in dbs}
            interpret = interprets.pop() if len(interprets) == 1 else None
        ci = cls(dbs[0].dim, [db.capacity for db in dbs],
                 use_pallas=use_pallas, interpret=interpret,
                 mesh_nodes=mesh_nodes)
        img = np.zeros((ci.padded_nodes, ci.capacity, ci.dim), np.float32)
        txt = np.zeros_like(img)
        val = np.zeros((ci.padded_nodes, ci.capacity), bool)
        for ni, db in enumerate(dbs):
            img[ni, :db.capacity] = db.img_vecs
            txt[ni, :db.capacity] = db.txt_vecs
            val[ni, :db.capacity] = db.valid
            ci.dbs[ni] = db
        ci._slabs = ci._shard(np.stack([img, txt]), slab=True)
        ci._valid = ci._shard(val, slab=False)
        ci.stats["slab_uploads"] += 1
        for ni, db in enumerate(dbs):
            db.register_cluster(ci, ni)
        return ci

    # -- incremental mutation (called by the VectorDB views) ----------------

    @staticmethod
    def _pad_slots(slots: np.ndarray) -> Tuple[np.ndarray, int]:
        """Pad the slot vector to a power-of-two bucket (duplicating the
        last slot) so the donated scatter compiles for a handful of
        shapes, not one per insert size."""
        n = len(slots)
        bucket = next_pow2(max(n, 1))
        if bucket != n:
            slots = np.concatenate(
                [slots, np.full(bucket - n, slots[-1], slots.dtype)])
        return slots, n

    def update_rows(self, node: int, slots: np.ndarray,
                    img_rows: np.ndarray, txt_rows: np.ndarray) -> None:
        """A batch of rows was inserted into ``node`` at ``slots``."""
        slots = np.asarray(slots, np.int32)
        if slots.size == 0:
            return
        padded, n = self._pad_slots(slots)
        if n != len(padded):
            img_rows = np.concatenate(
                [img_rows, np.repeat(img_rows[-1:], len(padded) - n, 0)])
            txt_rows = np.concatenate(
                [txt_rows, np.repeat(txt_rows[-1:], len(padded) - n, 0)])
        self._slabs, self._valid = _apply_rows(
            self._slabs, self._valid, jnp.int32(node), jnp.asarray(padded),
            jnp.asarray(img_rows, jnp.float32),
            jnp.asarray(txt_rows, jnp.float32),
            jnp.ones((len(padded),), bool))
        self.stats["row_updates"] += 1

    def invalidate_rows(self, node: int, slots: np.ndarray) -> None:
        """Slots were evicted from ``node`` — only validity flips (the
        numpy slabs keep the stale vectors too)."""
        slots = np.asarray(slots, np.int32)
        if slots.size == 0:
            return
        padded, _ = self._pad_slots(slots)
        self._valid = _apply_valid(self._valid, jnp.int32(node),
                                   jnp.asarray(padded),
                                   jnp.zeros((len(padded),), bool))
        self.stats["row_updates"] += 1

    def refresh_node(self, node: int,
                     db: Optional[VectorDB] = None) -> None:
        """Escape hatch: re-upload one node's slab from its numpy state
        after out-of-band mutation.  Pass ``db`` to REBIND the view to a
        replacement object (e.g. a ``VectorDB.restore`` result) — restore
        returns a new instance, so without the rebind the index would
        keep serving the pre-restore slab."""
        if db is not None:
            old = self.dbs[node]
            if old is not None:
                old.unregister_cluster(self)
            self.dbs[node] = db
            db.register_cluster(self, node)
        db = self.dbs[node]
        if db is None:
            return
        img = np.zeros((self.capacity, self.dim), np.float32)
        txt = np.zeros_like(img)
        val = np.zeros((self.capacity,), bool)
        img[:db.capacity] = db.img_vecs
        txt[:db.capacity] = db.txt_vecs
        val[:db.capacity] = db.valid
        self._slabs = self._slabs.at[0, node].set(jnp.asarray(img))
        self._slabs = self._slabs.at[1, node].set(jnp.asarray(txt))
        self._valid = self._valid.at[node].set(jnp.asarray(val))
        if self._mesh is not None:
            # out-of-jit .at updates may leave XLA-chosen layouts;
            # re-commit to the node mesh (this path is a slab upload
            # anyway — steady-state updates never come through here)
            self._slabs = self._shard(self._slabs, slab=True)
            self._valid = self._shard(self._valid, slab=False)
        self.stats["slab_uploads"] += 1

    # -- search -------------------------------------------------------------

    def _planes(self, index: str) -> Tuple[int, ...]:
        return {"img": (0,), "txt": (1,), "both": (0, 1)}[index]

    @staticmethod
    def _prep_queries(query_vecs: np.ndarray) -> Tuple[np.ndarray, int]:
        """Shared query prep for every scan mode: L2-normalise and pad
        the block to a power-of-two bucket (micro-batch sizes vary, and
        an unpadded (Q, D) shape would re-compile per distinct Q).
        Returns ``(padded_queries, true_batch)``; batch 0 -> (None, 0)."""
        Q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        b = Q.shape[0]
        if b == 0:
            return None, 0
        Qn = l2n(Q)
        bucket = next_pow2(b)
        if bucket != b:
            Qn = np.concatenate(
                [Qn, np.zeros((bucket - b, Qn.shape[1]), np.float32)])
        return Qn, b

    def _scan(self, Qn: np.ndarray, node_ids: Optional[np.ndarray], k: int,
              index: str, mask_nodes: bool, *, per_node: bool = False):
        """The one device launch (every scan mode dispatches here):
        returns (scores, global idx) numpy arrays of shape
        (planes, Qpad, k) — or (planes, nodes, Qpad, k) with
        ``per_node=True``, where the top-k is kept per node and
        ``node_ids``/``mask_nodes`` are ignored."""
        planes = self._planes(index)
        self.stats["fused_scans"] += 1
        slabs = (self._slabs if planes == (0, 1)
                 else self._slabs[planes[0]:planes[0] + 1])
        if self._mesh is not None:
            return self._scan_mesh(Qn, node_ids, k, slabs, mask_nodes,
                                   per_node)
        if per_node:
            if self.use_pallas:
                from repro.kernels.vdb_topk import vdb_topk_pernode
                s, i = vdb_topk_pernode(jnp.asarray(Qn), slabs, self._valid,
                                        k, interpret=self.interpret)
            else:
                s, i = _fused_topk_pernode(slabs, self._valid,
                                           jnp.asarray(Qn), k)
            return np.asarray(s), np.asarray(i)
        nids = jnp.asarray(node_ids, jnp.int32)
        if self.use_pallas:
            from repro.kernels.vdb_topk import vdb_topk_sharded
            s, i = vdb_topk_sharded(jnp.asarray(Qn), slabs, self._valid,
                                    nids, k, mask_nodes=mask_nodes,
                                    interpret=self.interpret)
        else:
            s, i = _fused_topk(slabs, self._valid, jnp.asarray(Qn), nids, k,
                               mask_nodes)
        return np.asarray(s), np.asarray(i)

    def _scan_mesh(self, Qn, node_ids, k: int, slabs, mask_nodes: bool,
                   per_node: bool):
        """Mesh-sharded body of :meth:`_scan` — still the same single
        launch per micro-batch, but run through ``shard_map`` so each
        device scans only its local node shard.  Only the per-shard
        best-k rows come back to the host (counted in
        ``stats["allgather_bytes"]``); the global modes then merge them
        with the single-device tie-break."""
        from repro.kernels.vdb_topk import (merge_shard_topk,
                                            vdb_topk_pernode_mesh,
                                            vdb_topk_sharded_mesh)
        if per_node:
            s, i = vdb_topk_pernode_mesh(
                jnp.asarray(Qn), slabs, self._valid, k, mesh=self._mesh,
                use_pallas=self.use_pallas, interpret=self.interpret)
            s, i = np.asarray(s), np.asarray(i)
            self.stats["allgather_bytes"] += s.nbytes + i.nbytes
            # pad nodes are all-invalid — drop their (NEG_INF, 0) rows
            return s[:, :self.n_nodes], i[:, :self.n_nodes]
        # per-shard k never exceeds the shard's own candidate count; the
        # merged pool (mesh_nodes × k_local) still holds >= k candidates
        n_shard = self.padded_nodes // self.mesh_nodes
        k_local = min(k, n_shard * self.capacity)
        s, i = vdb_topk_sharded_mesh(
            jnp.asarray(Qn), slabs, self._valid,
            jnp.asarray(node_ids, jnp.int32), k_local, mesh=self._mesh,
            mask_nodes=mask_nodes, use_pallas=self.use_pallas,
            interpret=self.interpret)
        s, i = np.asarray(s), np.asarray(i)
        self.stats["allgather_bytes"] += s.nbytes + i.nbytes
        return merge_shard_topk(s, i, k)

    def search_batch(self, query_vecs: np.ndarray, node_ids: Sequence[int],
                     k: int, *, index: str = "both",
                     count_queries: bool = True,
                     ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Fused cross-node dual ANN retrieval: every query against its
        scheduled node, both indexes, ONE device scan for the whole
        micro-batch regardless of how many nodes it touches.

        Returns one ``(scores, slots)`` pair per query with
        ``VectorDB.search`` semantics: deduped union across indexes,
        invalid/masked candidates dropped, scores descending, slots LOCAL
        to the query's node.
        """
        Qn, b = self._prep_queries(query_vecs)
        if b == 0:
            return []
        nids = np.asarray(list(node_ids), np.int32)
        if count_queries:
            for ni in nids:
                if self.dbs[ni] is not None:
                    self.dbs[ni].query_count += 1
        if len(Qn) != b:
            nids = np.concatenate([nids, np.zeros(len(Qn) - b, np.int32)])
        k = min(k, self.capacity)
        s, i = self._scan(Qn, nids, k, index, mask_nodes=True)
        out = []
        for row in range(b):
            local = i[:, row] - nids[row] * self.capacity
            out.append(_union_topk(list(s[:, row]), list(local)))
        return out

    def search_cluster(self, query_vecs: np.ndarray, k: int, *,
                       index: str = "both",
                       ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """All-nodes flat mode: each query scans the WHOLE cluster in the
        same single launch and gets ONE global candidate list; returned
        slots are global ids ``node * capacity + col``
        (``node = slot // capacity``).

        Note for routing callers: a single hot node can monopolise the
        global top-k, hiding every other node's best match — score-aware
        scheduling therefore uses :meth:`search_cluster_nodes`, which
        keeps a top-k PER node at identical slab traffic."""
        Qn, b = self._prep_queries(query_vecs)
        if b == 0:
            return []
        k = min(k, self.capacity * max(self.n_nodes, 1))
        s, i = self._scan(Qn, np.zeros(len(Qn), np.int32), k, index,
                          mask_nodes=False)
        return [_union_topk(list(s[:, row]), list(i[:, row]))
                for row in range(b)]

    def search_cluster_nodes(self, query_vecs: np.ndarray, k: int, *,
                             index: str = "both",
                             ) -> List[List[Tuple[np.ndarray, np.ndarray]]]:
        """All-nodes PER-NODE mode — the schedule+retrieve fusion scan.

        ONE device launch (jnp: one einsum + per-node top-k; Pallas:
        :func:`repro.kernels.vdb_topk.vdb_topk_pernode`) answers every
        query against EVERY node's slab across both dual-retrieval
        indexes.  Returns ``out[query][node] = (scores, slots)`` with
        exactly :meth:`VectorDB.search` semantics per node (deduped union
        across indexes, invalid candidates dropped, scores descending,
        slots LOCAL to that node) — so ``out[q][n]`` is bit-identical to
        what a masked ``search_batch`` on node ``n`` would have returned,
        and the Retrieve stage can reuse the chosen node's row without a
        second scan while the scheduler routes on every node's best
        match.
        """
        Qn, b = self._prep_queries(query_vecs)
        if b == 0:
            return []
        k = min(k, self.capacity)
        s, i = self._scan(Qn, None, k, index, mask_nodes=False,
                          per_node=True)         # (planes, nodes, Qpad, k)
        out: List[List[Tuple[np.ndarray, np.ndarray]]] = []
        for row in range(b):
            per_node = []
            for node in range(self.n_nodes):
                local = i[:, node, row] - node * self.capacity
                per_node.append(_union_topk(list(s[:, node, row]),
                                            list(local)))
            out.append(per_node)
        return out

    # -- derived state ------------------------------------------------------

    def node_vectors(self) -> np.ndarray:
        """L2-normalised node representation vectors (Eq. 6) from the
        per-db running centroids — O(nodes·dim), no slab reduction.
        Delegates to the scheduler's single implementation."""
        from repro.core.scheduler import RequestScheduler
        return RequestScheduler.node_vectors(
            [db if db is not None else VectorDB(self.dim, 0)
             for db in self.dbs])

    # -- introspection (tests / debugging) ----------------------------------

    def device_state(self) -> Tuple[np.ndarray, np.ndarray]:
        """Device slabs/validity pulled to host, sliced to the REAL nodes
        (mesh padding stripped) so it compares directly against
        :meth:`rebuild_reference` at any mesh size."""
        return (np.asarray(self._slabs)[:, :self.n_nodes],
                np.asarray(self._valid)[:self.n_nodes])

    def rebuild_reference(self) -> Tuple[np.ndarray, np.ndarray]:
        """What the device state SHOULD be, rebuilt from the numpy views
        (parity oracle for the incremental-update tests)."""
        img = np.zeros((self.n_nodes, self.capacity, self.dim), np.float32)
        txt = np.zeros_like(img)
        val = np.zeros((self.n_nodes, self.capacity), bool)
        for ni, db in enumerate(self.dbs):
            if db is None:
                continue
            img[ni, :db.capacity] = db.img_vecs
            txt[ni, :db.capacity] = db.txt_vecs
            val[ni, :db.capacity] = db.valid
        return np.stack([img, txt]), val
