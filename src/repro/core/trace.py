"""Request-trace generator + arrival processes for the serving experiments.

Real text-to-image traffic is heavy-tailed with topic drift (NIRVANA's
production observation, which the paper's LCU experiment leans on: "5 cache
updates" under a shifting query distribution).  We model:

  * a Zipf popularity law over scene specs,
  * topic drift: the Zipf ranking rotates every ``drift_every`` requests,
  * optional quality-tier users (paper's artistic/professional requests),
  * near-duplicate prompts (verbatim repeats) at rate ``repeat_rate`` to
    exercise the historical-query cache.

WHAT arrives is only half a workload — WHEN it arrives is the other half.
The paper's §V deployment sits behind an asynchronous task queue, so
latency under load depends on the arrival process.  :class:`TimedRequest`
stamps each trace request with an arrival time on the serving clock, and
three generators build the processes the experiments need:

  * :func:`poisson_arrivals` — memoryless open-loop traffic at a given
    offered load (requests/second), the queueing-theory baseline;
  * :func:`trace_arrivals` — trace-driven replay of explicit timestamps
    (recorded production traces, adversarial schedules, test fixtures);
  * :func:`bursty_arrivals` — synchronized bursts separated by idle gaps,
    the worst case for fixed-drain batching (stragglers that miss a batch
    boundary wait out a whole burst period).

All three preserve request order and are deterministic in their seed.
Multi-tenant traffic is built by tagging each process with a
``tenant``/``tier`` and interleaving them with :func:`merge_arrivals`
(stable, deterministic tie-break on equal timestamps) — one trace
generator becomes one client among many at the front door
(:mod:`repro.frontdoor`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.data.synthetic import (COLORS, SceneSpec, all_specs, caption_of,
                                  random_spec)


@dataclass
class TraceRequest:
    """One untimed trace entry: the prompt, its ground-truth scene spec,
    whether the issuing user is quality-tier (the paper's
    artistic/professional requests, eligible for priority scheduling),
    and whether this is a verbatim repeat of the previous request (the
    historical-query-cache workload knob)."""

    prompt: str
    spec: SceneSpec
    quality_tier: bool = False
    is_repeat: bool = False


@dataclass
class RequestTrace:
    """Deterministic Zipf-with-drift request generator (WHAT arrives).

    ``n_specs`` scenes are drawn once from the synthetic pool;
    :meth:`generate` then samples prompts Zipf(``zipf_a``)-popular over
    them, rotating which scenes are popular every ``drift_every``
    requests (topic drift), repeating the previous prompt verbatim at
    ``repeat_rate``, and tagging requests quality-tier at
    ``quality_rate``.  Identical seeds yield identical traces — every
    parity/property test in the repo leans on this."""

    n_specs: int = 400
    zipf_a: float = 1.2
    drift_every: int = 250
    repeat_rate: float = 0.08
    quality_rate: float = 0.05
    seed: int = 0
    _specs: List[SceneSpec] = field(default_factory=list)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        seen = set()
        while len(self._specs) < self.n_specs:
            s = random_spec(rng)
            if s.key() not in seen:
                seen.add(s.key())
                self._specs.append(s)

    def generate(self, n: int) -> Iterator[TraceRequest]:
        """Yield ``n`` :class:`TraceRequest`\\ s (deterministic in the
        trace seed; see the class docstring for the sampling law).  Pair
        with :func:`poisson_arrivals` / :func:`trace_arrivals` /
        :func:`bursty_arrivals` to add WHEN each request lands."""
        rng = np.random.default_rng(self.seed + 1)
        order = rng.permutation(self.n_specs)
        # Zipf over ranks, truncated to the spec pool
        ranks = np.arange(1, self.n_specs + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        probs /= probs.sum()
        last_prompt: Optional[TraceRequest] = None
        for i in range(n):
            if i > 0 and i % self.drift_every == 0:
                # topic drift: rotate which specs are popular
                order = np.roll(order, self.n_specs // 7)
            if last_prompt is not None and rng.random() < self.repeat_rate:
                yield TraceRequest(last_prompt.prompt, last_prompt.spec,
                                   quality_tier=rng.random() < self.quality_rate,
                                   is_repeat=True)
                continue
            rank = rng.choice(self.n_specs, p=probs)
            spec = self._specs[order[rank]]
            req = TraceRequest(caption_of(spec), spec,
                               quality_tier=rng.random() < self.quality_rate)
            last_prompt = req
            yield req

    @property
    def specs(self) -> List[SceneSpec]:
        return list(self._specs)


def band_mutation_trace(n: int, *, band_fraction: float = 0.5,
                        seed: int = 0) -> List[TraceRequest]:
    """Novel-spec / attribute-mutation workload for the latent-depth cache.

    The Zipf trace's img2img-band matches overwhelmingly land on the
    pre-seeded reference corpus, whose entries carry no archived latents —
    so it never exercises depth resumes.  This trace does, by
    construction: each request is either a *base* (a scene spec never
    requested before, drawn from a seeded permutation of the full spec
    pool — routes txt2img against a small corpus and is archived with
    latents) or, with probability ``band_fraction``, a single-attribute
    *mutation* (color swap) of a previously requested base.  Mutations
    score in or near the paper's [lo, hi] reference band against their
    base's archived generation, which is exactly the workload where
    resuming from a noised intermediate saves denoising steps.

    Pair with a small seed corpus (``corpus_n`` ≲ 50) so served archives,
    not warm corpus entries, win retrieval.  Deterministic in ``seed``.
    """
    if not 0.0 <= band_fraction <= 1.0:
        raise ValueError(f"band_fraction must be in [0, 1], "
                         f"got {band_fraction}")
    rng = np.random.default_rng(seed)
    specs = all_specs()
    perm = rng.permutation(len(specs))
    bases: List[SceneSpec] = []
    out: List[TraceRequest] = []
    nxt = 0
    for _ in range(n):
        if bases and rng.random() < band_fraction:
            b = bases[int(rng.integers(len(bases)))]
            colors = [c for c in COLORS if c != b.color]
            mut = SceneSpec(b.shape, colors[int(rng.integers(len(colors)))],
                            b.background, b.size, b.position)
            out.append(TraceRequest(caption_of(mut), mut))
        else:
            b = specs[perm[nxt % len(specs)]]
            nxt += 1
            bases.append(b)
            out.append(TraceRequest(caption_of(b), b))
    return out


def mixed_hit_trace(n: int, *, band_fraction: float = 0.35,
                    repeat_fraction: float = 0.25,
                    seed: int = 0) -> List[TraceRequest]:
    """Hit-rate-mix workload: every route class in one stream.

    Extends :func:`band_mutation_trace` with VERBATIM repeats of earlier
    requests, so a single trace exercises txt2img misses (novel bases),
    img2img band hits and latent-depth resumes (mutations), AND
    HIT_RETURN / history fast paths (repeats) — the full step-count
    spread the step-level serving engine's ragged admission has to
    interleave (its property suite draws hit mixes from here).  Each
    request is a repeat with probability ``repeat_fraction``, else a
    mutation with probability ``band_fraction``, else a fresh base.
    Deterministic in ``seed``; repeats are tagged ``is_repeat``.
    """
    if not 0.0 <= repeat_fraction <= 1.0:
        raise ValueError(f"repeat_fraction must be in [0, 1], "
                         f"got {repeat_fraction}")
    if not 0.0 <= band_fraction <= 1.0:
        raise ValueError(f"band_fraction must be in [0, 1], "
                         f"got {band_fraction}")
    rng = np.random.default_rng(seed + 7)
    body = band_mutation_trace(n, band_fraction=band_fraction, seed=seed)
    out: List[TraceRequest] = []
    for req in body:
        if out and rng.random() < repeat_fraction:
            prev = out[int(rng.integers(len(out)))]
            out.append(TraceRequest(prev.prompt, prev.spec,
                                    quality_tier=prev.quality_tier,
                                    is_repeat=True))
        else:
            out.append(req)
    return out


# ---------------------------------------------------------------------------
# arrival processes (timestamped traffic for the continuous-batching engine)
# ---------------------------------------------------------------------------


@dataclass
class TimedRequest:
    """A trace request stamped with its arrival time on the serving clock.

    ``arrival_time`` is in seconds on the engine's virtual clock (which
    advances by measured service wall time, so simulated gaps and real
    compute compose).  ``seed`` defaults to the request's position in the
    stream so replays match the seeded drivers elsewhere in the repo.
    """

    arrival_time: float
    prompt: str
    seed: int = 0
    quality_tier: bool = False
    spec: Optional[SceneSpec] = None
    is_repeat: bool = False
    # multi-tenant serving tags (None = untagged legacy traffic): which
    # tenant issued the request and at which SLA tier.  The front-door
    # gateway and the tagged-percentile stats key on these; every
    # existing untagged call site is unchanged.
    tenant: Optional[str] = None
    tier: Optional[str] = None


def _as_timed(reqs: Iterable, times: Sequence[float],
              seed_base: int = 0, tenant: Optional[str] = None,
              tier: Optional[str] = None) -> List[TimedRequest]:
    out: List[TimedRequest] = []
    for i, (r, t) in enumerate(zip(reqs, times)):
        if isinstance(r, TraceRequest):
            out.append(TimedRequest(float(t), r.prompt, seed=seed_base + i,
                                    quality_tier=r.quality_tier,
                                    spec=r.spec, is_repeat=r.is_repeat,
                                    tenant=tenant, tier=tier))
        else:
            out.append(TimedRequest(float(t), str(r), seed=seed_base + i,
                                    tenant=tenant, tier=tier))
    return out


def poisson_arrivals(reqs: Iterable, rate: float, *, seed: int = 0,
                     start: float = 0.0, seed_base: int = 0,
                     tenant: Optional[str] = None,
                     tier: Optional[str] = None) -> List[TimedRequest]:
    """Open-loop Poisson arrivals at ``rate`` requests/second.

    Inter-arrival gaps are i.i.d. exponential with mean ``1/rate``;
    request order is preserved.  ``reqs`` may be :class:`TraceRequest`
    objects or bare prompt strings.  Generation seeds are assigned as
    ``seed_base + position`` — offset ``seed_base`` when timing a later
    slice of a longer trace so seeds stay distinct across slices.
    ``tenant``/``tier`` tag every request (one arrival process = one
    tenant's traffic; interleave tenants with :func:`merge_arrivals`).
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    reqs = list(reqs)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(reqs))
    times = start + np.cumsum(gaps)
    return _as_timed(reqs, times, seed_base, tenant, tier)


def trace_arrivals(reqs: Iterable, timestamps: Sequence[float],
                   *, seed_base: int = 0, tenant: Optional[str] = None,
                   tier: Optional[str] = None) -> List[TimedRequest]:
    """Trace-driven arrivals: replay explicit per-request timestamps.

    ``timestamps`` must be non-decreasing and as long as ``reqs`` — this is
    the injection point for recorded production traces and for tests that
    need adversarial schedules.
    """
    reqs = list(reqs)
    times = [float(t) for t in timestamps]
    if len(times) != len(reqs):
        raise ValueError(f"{len(reqs)} requests but {len(times)} timestamps")
    if any(b < a for a, b in zip(times, times[1:])):
        raise ValueError("timestamps must be non-decreasing")
    return _as_timed(reqs, times, seed_base, tenant, tier)


def merge_arrivals(*processes: Sequence[TimedRequest]) -> List[TimedRequest]:
    """Interleave per-tenant arrival processes into one timeline.

    The merge is by ``arrival_time`` with a DETERMINISTIC, STABLE
    tie-break: requests landing at the same instant keep the order of
    their processes in the argument list, and within one process their
    original order — so ``merge_arrivals(a, b)`` is reproducible and
    ``merge_arrivals(a) == list(a)``.  Tags travel with the requests
    (build each process with its own ``tenant``/``tier``).

    Seed discipline: each process assigns generation seeds as
    ``seed_base + position``, so give every process a distinct
    ``seed_base`` (e.g. ``i * len(reqs_i)``) to keep seeds unique in the
    merged stream.
    """
    tagged = [(r.arrival_time, pi, j, r)
              for pi, proc in enumerate(processes)
              for j, r in enumerate(proc)]
    tagged.sort(key=lambda x: (x[0], x[1], x[2]))
    return [r for _, _, _, r in tagged]


def bursty_arrivals(reqs: Iterable, *, burst_size: int, burst_gap: float,
                    within_burst_gap: float = 0.0,
                    start: float = 0.0,
                    seed_base: int = 0,
                    tenant: Optional[str] = None,
                    tier: Optional[str] = None) -> List[TimedRequest]:
    """Synchronized bursts: ``burst_size`` requests land together every
    ``burst_gap`` seconds (spaced ``within_burst_gap`` apart inside the
    burst).  This is the fixed-drain worst case: a request that misses a
    batch-closure boundary waits out the idle gap until the next burst
    refills the bucket, while a continuous engine serves it as soon as the
    in-flight group completes.
    """
    if burst_size < 1:
        raise ValueError(f"burst_size must be >= 1, got {burst_size}")
    if burst_gap < 0 or within_burst_gap < 0:
        raise ValueError("burst_gap and within_burst_gap must be >= 0")
    reqs = list(reqs)
    times = [start + (i // burst_size) * burst_gap
             + (i % burst_size) * within_burst_gap
             for i in range(len(reqs))]
    return _as_timed(reqs, times, seed_base, tenant, tier)
