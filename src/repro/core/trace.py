"""Request-trace generator for the serving experiments.

Real text-to-image traffic is heavy-tailed with topic drift (NIRVANA's
production observation, which the paper's LCU experiment leans on: "5 cache
updates" under a shifting query distribution).  We model:

  * a Zipf popularity law over scene specs,
  * topic drift: the Zipf ranking rotates every ``drift_every`` requests,
  * optional quality-tier users (paper's artistic/professional requests),
  * near-duplicate prompts (verbatim repeats) at rate ``repeat_rate`` to
    exercise the historical-query cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional

import numpy as np

from repro.data.synthetic import SceneSpec, caption_of, random_spec


@dataclass
class TraceRequest:
    prompt: str
    spec: SceneSpec
    quality_tier: bool = False
    is_repeat: bool = False


@dataclass
class RequestTrace:
    n_specs: int = 400
    zipf_a: float = 1.2
    drift_every: int = 250
    repeat_rate: float = 0.08
    quality_rate: float = 0.05
    seed: int = 0
    _specs: List[SceneSpec] = field(default_factory=list)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        seen = set()
        while len(self._specs) < self.n_specs:
            s = random_spec(rng)
            if s.key() not in seen:
                seen.add(s.key())
                self._specs.append(s)

    def generate(self, n: int) -> Iterator[TraceRequest]:
        rng = np.random.default_rng(self.seed + 1)
        order = rng.permutation(self.n_specs)
        # Zipf over ranks, truncated to the spec pool
        ranks = np.arange(1, self.n_specs + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        probs /= probs.sum()
        last_prompt: Optional[TraceRequest] = None
        for i in range(n):
            if i > 0 and i % self.drift_every == 0:
                # topic drift: rotate which specs are popular
                order = np.roll(order, self.n_specs // 7)
            if last_prompt is not None and rng.random() < self.repeat_rate:
                yield TraceRequest(last_prompt.prompt, last_prompt.spec,
                                   quality_tier=rng.random() < self.quality_rate,
                                   is_repeat=True)
                continue
            rank = rng.choice(self.n_specs, p=probs)
            spec = self._specs[order[rank]]
            req = TraceRequest(caption_of(spec), spec,
                               quality_tier=rng.random() < self.quality_rate)
            last_prompt = req
            yield req

    @property
    def specs(self) -> List[SceneSpec]:
        return list(self._specs)
