"""Prompt optimizer (paper §IV-D).

The paper splits the prompt into phrases with SpaCy dependency parsing,
ranks them with BERT attention mass, and re-emits the phrases in descending
importance (diffusion models weight early tokens more heavily).

Offline adaptation (no SpaCy/BERT): phrases are split on punctuation and
coordinating conjunctions; importance is an attention-mass proxy computed
from (a) content-word rarity (hashed IDF-style weights — rarer = more
specific = more important) and (b) a noun-ish heuristic (head position in
the phrase).  An optional ``attention_fn`` hook lets the trained text tower
supply real attention mass — the integration tests exercise both.
"""
from __future__ import annotations

import re
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.utils import stable_hash

_STOPWORDS = {
    "a", "an", "the", "of", "on", "in", "at", "with", "and", "or", "to",
    "is", "are", "was", "were", "by", "for", "from", "very", "some",
}
_SPLIT_RE = re.compile(r"[,.;]| and | with | on | in | near ")


def split_phrases(prompt: str) -> List[str]:
    parts = [p.strip() for p in _SPLIT_RE.split(" " + prompt + " ")]
    return [p for p in parts if p]


def _rarity(word: str) -> float:
    """Deterministic IDF proxy: hash-derived rarity in (0, 1]."""
    if word.lower() in _STOPWORDS:
        return 0.05
    return 0.25 + 0.75 * (stable_hash(word.lower(), 10_000) / 10_000.0)


def phrase_importance(phrase: str) -> float:
    words = [w for w in re.findall(r"[a-zA-Z']+", phrase)]
    if not words:
        return 0.0
    scores = [_rarity(w) for w in words]
    # head-word bonus: last content word of a phrase is usually its noun head
    content = [i for i, w in enumerate(words) if w.lower() not in _STOPWORDS]
    if content:
        scores[content[-1]] *= 1.5
    return float(np.mean(scores))


class PromptOptimizer:
    def __init__(self, attention_fn: Optional[Callable[[Sequence[str]], np.ndarray]] = None):
        """attention_fn: phrases -> per-phrase attention mass (from the text
        tower); overrides the heuristic when provided."""
        self.attention_fn = attention_fn

    def rank(self, prompt: str) -> List[Tuple[str, float]]:
        phrases = split_phrases(prompt)
        if not phrases:
            return []
        if self.attention_fn is not None:
            w = np.asarray(self.attention_fn(phrases), np.float64)
        else:
            w = np.array([phrase_importance(p) for p in phrases])
        order = np.argsort(-w, kind="stable")
        return [(phrases[i], float(w[i])) for i in order]

    def optimize(self, prompt: str) -> str:
        """Re-emit phrases in descending importance (structured prompt)."""
        ranked = self.rank(prompt)
        if not ranked:
            return prompt
        return ", ".join(p for p, _ in ranked)
