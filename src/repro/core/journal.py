"""Per-node cache durability journal — WAL + periodic snapshot for VectorDB.

The paper's cache-hit serving economics (NIRVANA's argument, PAPERS.md)
collapse when an edge node restarts cold: every archived reference on
that node is gone and its share of the fleet hit-rate with it.  This
module makes a node's cache state DURABLE without changing a single
steady-state code path:

* every ``VectorDB`` mutation (``add`` / ``evict_slots`` / ``mark_access``)
  is appended to a write-ahead log as ONE record carrying the RAW call
  arguments (pre-normalisation — replay re-runs the real method, so the
  double L2-normalisation, FIFO overwrite walk and centroid bookkeeping
  are bit-for-bit the originals);
* every ``snapshot_every`` records the journal publishes a full
  ``VectorDB.snapshot()`` atomically (tmp dir + ``os.rename`` — the same
  crash-safe publish discipline as ``repro.checkpoint.manager``) and
  prunes the WAL records the snapshot has absorbed; the publish is
  deferred to the NEXT mutation's hook so it never captures a state the
  just-logged record has not yet applied to;
* :meth:`CacheJournal.replay` rebuilds the db from the newest complete
  snapshot plus the WAL tail — bitwise-equal (every ``snapshot()`` array)
  to the live db at the instant of the last journaled mutation, which is
  the crash instant itself because records are written synchronously
  BEFORE the slab mutates.

Layout of one node's journal directory::

    <root>/wal_0000000042.npz     one mutation record (kind + raw args)
    <root>/snap_0000000040/       atomically published snapshot
        arrays.npz                VectorDB.snapshot() arrays
        manifest.json             {"seq": 40}
    <root>/snap_0000000040.tmp/   (in-flight write — ignored by replay)

Attach with ``db.attach_journal(CacheJournal(path))``; recover with
``CacheJournal(path).replay(dim, capacity)``.  The chaos harness
(``repro.faults``) wires one journal per node and rejoins crashed nodes
through ``CacheGenius.rejoin_node`` with the replayed db.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["CacheJournal"]

_WAL_PREFIX = "wal_"
_SNAP_PREFIX = "snap_"


class CacheJournal:
    """Write-ahead log + periodic snapshot for one node's ``VectorDB``.

    ``snapshot_every`` bounds replay work: a restart reads one snapshot
    plus at most ``snapshot_every`` WAL records.  ``0`` disables periodic
    snapshots (pure WAL — replay walks every record since the last
    explicit :meth:`snapshot` call, if any).
    """

    def __init__(self, root: str, *, snapshot_every: int = 64):
        if snapshot_every < 0:
            raise ValueError(
                f"snapshot_every must be >= 0, got {snapshot_every}")
        self.root = root
        self.snapshot_every = int(snapshot_every)
        os.makedirs(root, exist_ok=True)
        self._db = None                   # bound VectorDB (attach_journal)
        self.seq = self._latest_seq()     # last durable record number
        self._snap_seq = self._latest_snapshot()[0]   # newest snapshot seq

    # -- binding ------------------------------------------------------------

    def bind(self, db) -> None:
        """Called by ``VectorDB.attach_journal``; the bound db is the
        snapshot source."""
        self._db = db

    # -- record (called from the VectorDB mutation hooks) --------------------

    def record_add(self, img_vecs, txt_vecs, payload_ids, t,
                   depths, source_ids) -> None:
        rec = {"img_vecs": np.atleast_2d(np.asarray(img_vecs, np.float32)),
               "txt_vecs": np.atleast_2d(np.asarray(txt_vecs, np.float32)),
               "payload_ids": np.atleast_1d(np.asarray(payload_ids,
                                                       np.int64)),
               "t": np.float64(t)}
        if depths is not None:
            rec["depths"] = np.atleast_1d(np.asarray(depths, np.int64))
        if source_ids is not None:
            rec["source_ids"] = np.atleast_1d(np.asarray(source_ids,
                                                         np.int64))
        self._append("add", rec)

    def record_evict(self, slots) -> None:
        self._append("evict",
                     {"slots": np.atleast_1d(np.asarray(slots, np.int64))})

    def record_access(self, slots, t) -> None:
        self._append("access",
                     {"slots": np.atleast_1d(np.asarray(slots, np.int64)),
                      "t": np.float64(t)})

    def _append(self, kind: str, arrays: Dict[str, np.ndarray]) -> None:
        # Auto-snapshot is DEFERRED to the next mutation's append: the
        # hook that wrote record N runs BEFORE the db applies N, so
        # snapshotting inside that call would publish a state missing N's
        # effect while pruning N from the WAL — a lost mutation.  Here,
        # inside record N+1's hook, record N is guaranteed applied.
        if (self.snapshot_every and self._db is not None
                and self.seq > self._snap_seq
                and self.seq % self.snapshot_every == 0):
            self.snapshot()
        self.seq += 1
        path = os.path.join(self.root, f"{_WAL_PREFIX}{self.seq:010d}.npz")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:   # handle, not path: savez must not
            np.savez(f, kind=np.array(kind), **arrays)  # append ".npz"
        os.rename(tmp, path)     # a record is either whole or absent

    # -- snapshot ------------------------------------------------------------

    def snapshot(self) -> str:
        """Publish the bound db's full state atomically at the current
        ``seq`` (checkpoint-manager discipline: write ``.tmp`` dir, then
        one ``os.rename``) and prune absorbed WAL records.  Returns the
        published directory."""
        if self._db is None:
            raise RuntimeError("journal is not bound to a VectorDB — "
                               "call db.attach_journal(journal) first")
        final = os.path.join(self.root, f"{_SNAP_PREFIX}{self.seq:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **self._db.snapshot())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"seq": self.seq}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._snap_seq = self.seq
        self._prune(self.seq)
        return final

    def _prune(self, upto: int) -> None:
        """Drop WAL records absorbed by the snapshot at ``upto`` and any
        older snapshots (the newest snapshot alone is the restart base)."""
        for name in os.listdir(self.root):
            if name.startswith(_WAL_PREFIX) and name.endswith(".npz"):
                if int(name[len(_WAL_PREFIX):-len(".npz")]) <= upto:
                    os.remove(os.path.join(self.root, name))
            elif (name.startswith(_SNAP_PREFIX) and not name.endswith(".tmp")
                  and int(name[len(_SNAP_PREFIX):]) < upto):
                shutil.rmtree(os.path.join(self.root, name))

    # -- replay --------------------------------------------------------------

    def _latest_seq(self) -> int:
        seqs = [0]
        for name in os.listdir(self.root):
            if name.startswith(_WAL_PREFIX) and name.endswith(".npz"):
                seqs.append(int(name[len(_WAL_PREFIX):-len(".npz")]))
            elif (name.startswith(_SNAP_PREFIX)
                  and not name.endswith(".tmp")):
                seqs.append(int(name[len(_SNAP_PREFIX):]))
        return max(seqs)

    def _latest_snapshot(self) -> Tuple[int, Optional[str]]:
        best, path = 0, None
        for name in os.listdir(self.root):
            if name.startswith(_SNAP_PREFIX) and not name.endswith(".tmp"):
                seq = int(name[len(_SNAP_PREFIX):])
                if seq >= best:
                    best, path = seq, os.path.join(self.root, name)
        return best, path

    def _wal_tail(self, after: int) -> List[str]:
        out = []
        for name in sorted(os.listdir(self.root)):
            if name.startswith(_WAL_PREFIX) and name.endswith(".npz"):
                if int(name[len(_WAL_PREFIX):-len(".npz")]) > after:
                    out.append(os.path.join(self.root, name))
        return out

    def replay(self, dim: int, capacity: int, *, name: str = "node",
               **db_kwargs):
        """Rebuild a ``VectorDB`` from the newest snapshot + WAL tail.

        Replay calls the REAL mutation methods (with no journal attached,
        so nothing re-journals), so every derived quantity — the slot
        choices of the FIFO overwrite walk, the double L2-normalisation,
        the fresh ``access_count`` — is recomputed by the same code that
        produced it live: the result is bitwise-equal to the live db's
        ``snapshot()`` at the last journaled mutation."""
        from repro.core.vdb import VectorDB

        snap_seq, snap_path = self._latest_snapshot()
        if snap_path is not None:
            with np.load(os.path.join(snap_path, "arrays.npz")) as z:
                state = {k: z[k] for k in z.files}
            db = VectorDB.restore(dim, capacity, state, name=name,
                                  **db_kwargs)
        else:
            db = VectorDB(dim, capacity, name=name, **db_kwargs)
        for path in self._wal_tail(snap_seq):
            with np.load(path) as z:
                kind = str(z["kind"])
                rec = {k: z[k] for k in z.files if k != "kind"}
            if kind == "add":
                db.add(rec["img_vecs"], rec["txt_vecs"],
                       rec["payload_ids"], float(rec["t"]),
                       depths=rec.get("depths"),
                       source_ids=rec.get("source_ids"))
            elif kind == "evict":
                db.evict_slots(rec["slots"])
            elif kind == "access":
                db.mark_access(rec["slots"], float(rec["t"]))
            else:
                raise ValueError(f"unknown journal record kind {kind!r} "
                                 f"in {path}")
        return db
