"""Request scheduler (paper §IV-E) — centroid and score-aware routing.

Routes each request to an edge node, in one of two modes:

* ``"centroid"`` — the paper's Eq. 6 baseline: route to the node whose
  VDB's mean embedding (the "node representation vector") is most
  cosine-similar to the prompt embedding.  The centroid is a coarse
  partition proxy: it says what a node's cache is ABOUT, not whether it
  actually holds a good reference for THIS prompt.
* ``"score"`` — route on the node's TRUE best match: the serve pipeline
  hands :meth:`RequestScheduler.schedule_batch` a ``(batch, nodes)``
  matrix of per-node best composite (Eq. 7) scores from ONE cluster-wide
  device scan (``ClusterIndex.search_cluster_nodes``), and the routing
  utility blends that best-match score with a small centroid-affinity
  prior (keeps novel prompts clustering semantically, so caches stay
  skew-partitioned), the queue-depth load penalty, and an
  expected-latency term from the Eq. 8 latency model (slow nodes pay
  for the steps their best match would still require).  This mirrors
  how Approximate Caching (NIRVANA) selects references by actual
  retrieval hit quality rather than partition proxies.

Both modes share the paper's two fast paths:

* **historical query cache** — near-duplicate prompts (cosine above
  ``dedup_threshold``) return the previously generated image directly,
  skipping scheduling AND VDB retrieval;
* **quality-aware priority scheduling** — repeated prompts from
  quality-tier users are pinned to the fastest node and forced through the
  full text-to-image path for maximum quality.

The scheduler also load-balances: the routing utility is penalised by each
node's queue depth so a hot cluster does not starve (the paper's async task
queue serves the same purpose).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.vdb import VectorDB
from repro.utils import l2n


class UnknownNodeError(ValueError):
    """A node index outside the fleet was passed to a scheduler/fleet
    operation (``mark_failed`` / ``fail_node`` / ``rejoin_node`` / ...).
    Raised instead of letting Python's negative indexing silently alias
    the LAST node — the bug class this error type exists to surface."""


@dataclass
class NodeHealth:
    """Per-node health state driving degraded-mode serving.

    ``ewma`` is an exponentially weighted success score in [0, 1]: every
    observed fault (transient backend error, stall, corrupt reference)
    decays it toward 0, every success pulls it back toward 1.  A
    fault-free node stays at EXACTLY 1.0 (``1 + a*(1-1) == 1``), so the
    routing penalty is exactly 0 and fault-free routing is bitwise
    unchanged.  The circuit breaker quarantines a node after
    ``breaker_threshold`` consecutive faults (``state="open"`` — treated
    like dead by routing while alternatives exist), then probes it back
    in after ``breaker_cooldown`` scheduling rounds (``"half_open"``:
    routable again, one success closes it, one fault reopens it)."""

    ewma: float = 1.0
    consecutive_faults: int = 0
    state: str = "closed"        # closed | open | half_open
    cooldown: int = 0            # scheduling rounds until open -> half_open


@dataclass
class NodeInfo:
    """Per-node scheduling state: relative denoise-step throughput
    (``speed``, the paper's heterogeneous RTX mix), current ``queue_depth``
    (the load-penalty input), liveness (``alive=False`` nodes are
    never routed to — see ``CacheGenius.fail_node``), and the fault
    ``health`` score / circuit-breaker state (see :class:`NodeHealth`)."""

    index: int
    speed: float = 1.0           # relative denoise-step throughput (RTX mix)
    queue_depth: int = 0
    alive: bool = True
    health: NodeHealth = field(default_factory=NodeHealth)


@dataclass
class ScheduleDecision:
    """One request's routing outcome.

    ``fast_path`` is ``None`` (normal retrieval path), ``"history"``
    (historical-query duplicate; ``history_payload`` is the blob id to
    return) or ``"priority"`` (quality-tier repeat pinned to the fastest
    node).  ``match_score`` carries the similarity the decision was based
    on: the history-cache cosine for history decisions, the centroid
    similarity minus load penalty in centroid mode, or the routed node's
    best composite (Eq. 7) score in score mode — PlanStage uses it to
    arbitrate history hits against in-flight batch members."""

    node: int
    fast_path: Optional[str] = None      # None | "history" | "priority"
    history_payload: Optional[int] = None
    match_score: float = 0.0


@dataclass
class RequestScheduler:
    """Batch-first request router (see module docstring for the two
    routing modes and the fast paths).

    Weights of the score-mode routing utility (all applied to scores on
    the Eq. 7 [0, 1] scale):

    * ``balance_weight`` — per-queued-request penalty (both modes);
    * ``affinity_weight`` — centroid-similarity prior blended into score
      mode so novel prompts (no meaningful best match anywhere) still
      cluster semantically instead of all chasing the fastest node;
    * ``latency_weight`` — penalty per unit of expected Eq. 8 latency
      (normalised by the full-generation latency at speed 1.0), from the
      route the node's best match would take on that node's speed.  Set
      by ``CacheGenius`` wiring ``policy``/``latency_model``; without
      them the term is skipped.
    """

    nodes: List[NodeInfo]
    dedup_threshold: float = 0.97
    balance_weight: float = 0.02
    history_capacity: int = 4096
    affinity_weight: float = 0.10
    latency_weight: float = 0.05
    # health-aware degraded-mode serving (see NodeHealth): EWMA decay per
    # observation, routing penalty per unit of lost health, consecutive
    # faults before the breaker opens, scheduling rounds before probing
    health_alpha: float = 0.25
    health_weight: float = 0.20
    breaker_threshold: int = 3
    breaker_cooldown: int = 8
    policy: Optional[object] = None          # GenerationPolicy (score mode)
    latency_model: Optional[object] = None   # LatencyModel (score mode)
    _hist_vecs: np.ndarray = field(default=None, repr=False)  # type: ignore
    _hist_payloads: List[int] = field(default_factory=list, repr=False)
    _hist_hits: int = 0
    _prompt_counts: Dict[int, int] = field(default_factory=dict, repr=False)

    def __post_init__(self):
        self._hist_vecs = np.zeros((0, 512), np.float32)

    # -- node representation vectors ----------------------------------------

    @staticmethod
    def node_vectors(dbs: Sequence[VectorDB]) -> np.ndarray:
        """L2-normalised node representation vectors (Eq. 6).

        ``VectorDB.centroid`` is served from a running sum/count
        maintained on every mutation, so building the representation
        matrix is O(nodes·dim) per micro-batch — NOT an
        O(capacity·dim) slab reduction per node (``ClusterIndex
        .node_vectors`` reads the same cached centroids)."""
        vecs = np.stack([db.centroid() for db in dbs])
        n = np.linalg.norm(vecs, axis=-1, keepdims=True)
        return vecs / np.maximum(n, 1e-12)

    # -- main entry -----------------------------------------------------------

    def schedule(self, prompt_vec: np.ndarray, dbs: Sequence[VectorDB], *,
                 quality_tier: bool = False, prompt_key: Optional[int] = None,
                 ) -> ScheduleDecision:
        """Route ONE request (centroid mode only — the scalar legacy
        surface; the serve pipeline routes whole micro-batches through
        :meth:`schedule_batch`, which is also where score-aware routing
        lives).  Unlike ``schedule_batch`` this mutates ``queue_depth``:
        callers pair it with :meth:`complete`."""
        # fast path 1: historical query cache
        hist = self._history_lookup(prompt_vec)
        if hist is not None:
            self._hist_hits += 1
            return ScheduleDecision(node=-1, fast_path="history",
                                    history_payload=hist, match_score=1.0)

        self._breaker_tick()
        # fast path 2: quality-aware priority scheduling for repeated prompts
        if prompt_key is not None:
            c = self._prompt_counts.get(prompt_key, 0)
            self._prompt_counts[prompt_key] = c + 1
            if quality_tier and c > 0:
                fastest = max(self._routable_nodes(), key=lambda n: n.speed)
                fastest.queue_depth += 1
                return ScheduleDecision(node=fastest.index, fast_path="priority")

        # Eq. 6: cosine(prompt, node representation), minus a load penalty
        reps = self.node_vectors(dbs)
        q = prompt_vec / max(np.linalg.norm(prompt_vec), 1e-12)
        sims = reps @ q
        routable = {n.index for n in self._routable_nodes()}
        for n in self.nodes:
            if n.index not in routable:
                sims[n.index] = -np.inf
            else:
                sims[n.index] -= self.balance_weight * n.queue_depth
                pen = self.health_weight * (1.0 - n.health.ewma)
                if pen:
                    sims[n.index] -= pen
        node = int(np.argmax(sims))
        self.nodes[node].queue_depth += 1
        return ScheduleDecision(node=node, match_score=float(sims[node]))

    def schedule_batch(self, prompt_vecs: np.ndarray, dbs: Sequence[VectorDB],
                       *, quality_tiers: Optional[Sequence[bool]] = None,
                       prompt_keys: Optional[Sequence[Optional[int]]] = None,
                       node_scores: Optional[np.ndarray] = None,
                       ) -> List[ScheduleDecision]:
        """Embed-and-route a whole micro-batch in one shot.

        The expensive vector math is amortised: ONE matmul against the
        historical-query cache, ONE node-representation build, ONE
        similarity matmul — then the per-request fast-path / priority /
        load logic runs over the precomputed rows in submission order,
        mutating ``_prompt_counts`` exactly like sequential calls.

        ``node_scores`` switches routing to SCORE mode: a ``(b, nodes)``
        matrix of each request's best composite (Eq. 7) match on every
        node — produced by the Schedule stage from ONE cluster-wide
        ``ClusterIndex.search_cluster_nodes`` scan (empty nodes = 0.0).
        The routing utility becomes ``best_match + affinity_weight *
        centroid_sim - balance_weight * queue_depth - latency_weight *
        expected_latency`` (see :meth:`_score_utilities`); ``None`` keeps
        the Eq. 6 centroid-only baseline.  Fast paths are identical in
        both modes.

        Batch semantics: the micro-batch is treated as scheduled-and-
        completed atomically, so queue depths are read (for the load
        penalty) but not left incremented — mirroring the sequential
        serve loop, where every request completes before the next one
        schedules.  History decisions carry the true match similarity in
        ``match_score`` so callers can arbitrate against in-flight
        (not-yet-archived) batch members.
        """
        self._breaker_tick()
        P = np.atleast_2d(np.asarray(prompt_vecs, np.float32))
        b = P.shape[0]
        tiers = list(quality_tiers) if quality_tiers is not None else [False] * b
        keys = list(prompt_keys) if prompt_keys is not None else [None] * b
        Qn = l2n(P)
        hist_sims = (Qn @ self._hist_vecs.T
                     if self._hist_vecs.shape[0] else None)      # (b, H)
        reps = self.node_vectors(dbs)                            # built once
        base_sims = Qn @ reps.T                                  # (b, N)
        lat_full = self._full_gen_latency()                      # hoisted
        decisions: List[ScheduleDecision] = []
        for i in range(b):
            # fast path 1: historical query cache
            if hist_sims is not None:
                j = int(np.argmax(hist_sims[i]))
                if hist_sims[i, j] >= self.dedup_threshold:
                    self._hist_hits += 1
                    decisions.append(ScheduleDecision(
                        node=-1, fast_path="history",
                        history_payload=self._hist_payloads[j],
                        match_score=float(hist_sims[i, j])))
                    continue
            # fast path 2: quality-aware priority for repeated prompts
            if keys[i] is not None:
                c = self._prompt_counts.get(keys[i], 0)
                self._prompt_counts[keys[i]] = c + 1
                if tiers[i] and c > 0:
                    fastest = max(self._routable_nodes(),
                                  key=lambda n: n.speed)
                    decisions.append(ScheduleDecision(node=fastest.index,
                                                      fast_path="priority"))
                    continue
            if node_scores is not None:          # score-aware routing
                util = self._score_utilities(node_scores[i], base_sims[i],
                                             lat_full)
                node = int(np.argmax(util))
                decisions.append(ScheduleDecision(
                    node=node, match_score=float(node_scores[i][node])))
                continue
            sims = base_sims[i].copy()
            routable = {n.index for n in self._routable_nodes()}
            for n in self.nodes:
                if n.index not in routable:
                    sims[n.index] = -np.inf
                else:
                    sims[n.index] -= self.balance_weight * n.queue_depth
                    pen = self.health_weight * (1.0 - n.health.ewma)
                    if pen:
                        sims[n.index] -= pen
            node = int(np.argmax(sims))
            decisions.append(ScheduleDecision(node=node,
                                              match_score=float(sims[node])))
        return decisions

    def _full_gen_latency(self) -> Optional[float]:
        """Speed-1.0 full-generation Eq. 8 latency — the normaliser of
        the score-mode latency penalty, constant per batch (``None``
        disables the term when policy/latency_model are unwired)."""
        if self.policy is None or self.latency_model is None:
            return None
        from repro.core.policy import Route
        return self.latency_model.latency(
            Route.TXT2IMG, self.policy.steps_full, node_speed=1.0)

    def _score_utilities(self, best_row: np.ndarray,
                         centroid_row: np.ndarray,
                         lat_full: Optional[float]) -> np.ndarray:
        """Score-mode routing utility for one request.

        ``best_row`` — best composite (Eq. 7) match per node; dominant
        term, so a node that can actually serve a HIT_RETURN/IMG2IMG
        reference wins.  ``centroid_row`` — Eq. 6 centroid similarities;
        the ``affinity_weight`` prior keeps novel prompts (best ~0
        everywhere) semantically clustered.  Queue depth pays
        ``balance_weight`` each; the latency term charges each node the
        Eq. 8 latency its best match would incur there (route thresholds
        from ``policy``, per-step time scaled by node speed), normalised
        by ``lat_full`` (:meth:`_full_gen_latency`).  Dead nodes are
        -inf.
        """
        util = (np.asarray(best_row, np.float64)
                + self.affinity_weight * np.asarray(centroid_row, np.float64))
        routable = {n.index for n in self._routable_nodes()}
        for n in self.nodes:
            if n.index not in routable:
                util[n.index] = -np.inf
                continue
            util[n.index] -= self.balance_weight * n.queue_depth
            pen = self.health_weight * (1.0 - n.health.ewma)
            if pen:
                util[n.index] -= pen
            if lat_full:
                route = self.policy.route(float(best_row[n.index]))
                lat = self.latency_model.latency(
                    route, self.policy.steps_for(route), node_speed=n.speed)
                util[n.index] -= self.latency_weight * lat / lat_full
        return util

    def complete(self, node: int) -> None:
        """Release the queue slot a prior ``schedule()`` call claimed.

        Strictly paired with the increment: history hits (node == -1) and
        out-of-range nodes are no-ops, and an underflow — ``complete``
        without a matching ``schedule`` increment — warns and leaves the
        depth at 0 instead of silently clamping (a clamp here masked
        double-release bugs)."""
        if not (0 <= node < len(self.nodes)):
            return
        if self.nodes[node].queue_depth <= 0:
            warnings.warn(
                f"queue-depth underflow on node {node}: complete() without "
                "a matching schedule() increment", RuntimeWarning)
            return
        self.nodes[node].queue_depth -= 1

    # -- history cache --------------------------------------------------------

    def _history_lookup(self, vec: np.ndarray) -> Optional[int]:
        if self._hist_vecs.shape[0] == 0:
            return None
        q = vec / max(np.linalg.norm(vec), 1e-12)
        sims = self._hist_vecs @ q
        i = int(np.argmax(sims))
        if sims[i] >= self.dedup_threshold:
            return self._hist_payloads[i]
        return None

    def count_history_hit(self) -> None:
        """Book a history hit resolved outside `schedule` — the batched
        serve path detects near-duplicates of *in-flight* batch members
        (whose results are not yet archived) and must keep the counter in
        lockstep with the sequential loop."""
        self._hist_hits += 1

    def uncount_prompt(self, prompt_key: int) -> None:
        """Roll back one `_prompt_counts` increment.  Sequential serve
        never counts a request that history-hits; when the batched path
        retroactively turns a scheduled request into an in-flight history
        hit, it undoes the count `schedule_batch` already applied."""
        c = self._prompt_counts.get(prompt_key)
        if c is not None:
            self._prompt_counts[prompt_key] = max(0, c - 1)

    def record_result(self, prompt_vec: np.ndarray, payload_id: int) -> None:
        q = prompt_vec / max(np.linalg.norm(prompt_vec), 1e-12)
        self._hist_vecs = np.concatenate([self._hist_vecs, q[None]])[-self.history_capacity:]
        self._hist_payloads = (self._hist_payloads + [payload_id])[-self.history_capacity:]

    def invalidate_payloads(self, payload_ids) -> None:
        """Cache-maintenance consistency (paper §IV-G: image files are
        removed synchronously): drop history entries whose blobs were
        evicted, else a history hit would dereference a deleted image."""
        doomed = set(int(p) for p in payload_ids)
        if not doomed or self._hist_vecs.shape[0] == 0:
            return
        keep = [i for i, p in enumerate(self._hist_payloads)
                if p not in doomed]
        self._hist_vecs = self._hist_vecs[keep]
        self._hist_payloads = [self._hist_payloads[i] for i in keep]

    # -- health / circuit breaker ------------------------------------------------

    def _check_node(self, node: int) -> None:
        if not (0 <= node < len(self.nodes)):
            raise UnknownNodeError(
                f"unknown node index {node} (fleet has {len(self.nodes)} "
                f"nodes; negative indices are rejected, not aliased)")

    def observe_fault(self, node: int, kind: str = "transient") -> None:
        """Record one fault observation against ``node`` (transient
        backend error, stall, corrupt blob).  Decays the health EWMA and
        advances the circuit breaker: ``breaker_threshold`` consecutive
        faults — or any fault while half-open — open it."""
        self._check_node(node)
        h = self.nodes[node].health
        h.ewma = (1.0 - self.health_alpha) * h.ewma
        h.consecutive_faults += 1
        if (h.state == "half_open"
                or h.consecutive_faults >= self.breaker_threshold):
            h.state = "open"
            h.cooldown = self.breaker_cooldown
            h.consecutive_faults = 0

    def observe_ok(self, node: int) -> None:
        """Record one successful serve: health recovers toward 1.0, the
        consecutive-fault streak resets, and a half-open breaker closes
        (the probe succeeded)."""
        self._check_node(node)
        h = self.nodes[node].health
        h.ewma += self.health_alpha * (1.0 - h.ewma)
        h.consecutive_faults = 0
        if h.state == "half_open":
            h.state = "closed"

    def _breaker_tick(self) -> None:
        """One scheduling round: open breakers count down their cooldown
        and transition to half-open (routable again, one strike allowed)
        when it expires."""
        for n in self.nodes:
            h = n.health
            if h.state == "open":
                h.cooldown -= 1
                if h.cooldown <= 0:
                    h.state = "half_open"

    def _routable_nodes(self) -> List[NodeInfo]:
        """Alive nodes minus open-breaker quarantine, in fleet order (so
        tie-breaks match the pre-health router bit-for-bit).  If EVERY
        alive node is quarantined, degrade to all alive nodes — serving
        beats refusing.  No alive nodes at all is a hard error."""
        alive = [n for n in self.nodes if n.alive]
        if not alive:
            raise RuntimeError("no alive nodes to route to")
        routable = [n for n in alive if n.health.state != "open"]
        return routable or alive

    # -- failures / elasticity --------------------------------------------------

    def mark_failed(self, node: int) -> None:
        self._check_node(node)
        self.nodes[node].alive = False

    def mark_alive(self, node: int) -> None:
        """Rejoin a previously failed node: alive, empty queue, fresh
        health (speed is a property of the hardware and survives)."""
        self._check_node(node)
        n = self.nodes[node]
        n.alive = True
        n.queue_depth = 0
        n.health = NodeHealth()

    def add_node(self, *, speed: float = 1.0) -> int:
        """Register one fresh node (graceful join): it starts alive with
        an empty queue and competes for routing immediately — its empty
        cache means a ~zero centroid/best-match, so traffic shifts to it
        through the load-balance term first and semantically once
        archives land.  Returns the new node index."""
        idx = len(self.nodes)
        self.nodes.append(NodeInfo(idx, speed=speed))
        return idx

    @property
    def history_hits(self) -> int:
        return self._hist_hits
