"""Jittable K-means — substrate of the storage classifier (paper §IV-C).

The paper clusters CLIP embeddings of the reference corpus with K-means
(Eq. 5) and stores each cluster on one edge node's vector DB.  We implement
Lloyd's algorithm as a ``lax.scan`` over iterations so it jits, shards
(points may be sharded over the data axis; the centroid update is a
reduction GSPMD turns into an all-reduce), and runs identically on CPU/TPU.

K-means++-style seeding is approximated with a deterministic farthest-point
sweep, which is reproducible under jit (no rejection sampling).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansState(NamedTuple):
    centroids: jax.Array  # (k, d)
    assignment: jax.Array  # (n,) int32
    inertia: jax.Array  # () — within-cluster sum of squared errors (Eq. 5)


def _pairwise_sqdist(x, c):
    """(n, d) x (k, d) -> (n, k) squared euclidean distances."""
    # |x - c|^2 = |x|^2 - 2 x.c + |c|^2 ; keeps the n*k*d contraction on the MXU
    x2 = jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    c2 = jnp.sum(jnp.square(c), axis=-1)
    return x2 - 2.0 * (x @ c.T) + c2[None, :]


def kmeans_assign(x, centroids):
    """Nearest-centroid assignment. Returns (assignment, sq_distance)."""
    d = _pairwise_sqdist(x, centroids)
    idx = jnp.argmin(d, axis=-1)
    return idx.astype(jnp.int32), jnp.min(d, axis=-1)


def _seed_farthest_point(x, k):
    """Deterministic farthest-point seeding (k-means++ flavoured)."""
    n = x.shape[0]

    def body(carry, _):
        cents, mind, count = carry
        nxt = jnp.argmax(mind)
        cents = cents.at[count].set(x[nxt])
        d = jnp.sum(jnp.square(x - x[nxt][None, :]), axis=-1)
        mind = jnp.minimum(mind, d)
        return (cents, mind, count + 1), None

    cents0 = jnp.zeros((k, x.shape[-1]), x.dtype).at[0].set(x[0])
    mind0 = jnp.sum(jnp.square(x - x[0][None, :]), axis=-1)
    (cents, _, _), _ = jax.lax.scan(body, (cents0, mind0, jnp.int32(1)),
                                    None, length=k - 1)
    del n
    return cents


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_fit(x, *, k: int, iters: int = 25) -> KMeansState:
    """Lloyd's algorithm. x: (n, d) float. Empty clusters keep their centroid."""
    x = x.astype(jnp.float32)
    cents0 = _seed_farthest_point(x, k)

    def step(cents, _):
        idx, dmin = kmeans_assign(x, cents)
        onehot = jax.nn.one_hot(idx, k, dtype=jnp.float32)  # (n, k)
        counts = jnp.sum(onehot, axis=0)  # (k,)
        sums = onehot.T @ x  # (k, d)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None],
                        cents)
        return new, jnp.sum(dmin)

    cents, inertias = jax.lax.scan(step, cents0, None, length=iters)
    idx, dmin = kmeans_assign(x, cents)
    return KMeansState(centroids=cents, assignment=idx, inertia=jnp.sum(dmin))


def cluster_sizes(assignment, k: int):
    return jnp.bincount(assignment, length=k)
