"""Generation-strategy policy (paper §IV-F, Algorithm 1, Fig. 7).

Composite similarity score  S = CLIPScore + PickScore  (Eq. 7), then:

    S  > hi  (0.5)        -> HIT_RETURN  : ship the cached image, 0 steps
    lo <= S <= hi (0.4..) -> IMG2IMG     : SDEdit from noised reference, K steps
    S  < lo  (0.4)        -> TXT2IMG     : full generation from noise, N steps

Both scores are normalised to [0, 1] before summing and the sum is halved,
so thresholds live on the paper's 0..1 scale. Thresholds are configurable —
benchmark fig15 sweeps them exactly like the paper's Figure 15.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable

import numpy as np


class Route(enum.Enum):
    HIT_RETURN = "hit_return"
    IMG2IMG = "img2img"
    TXT2IMG = "txt2img"


@dataclass
class GenerationPolicy:
    lo: float = 0.4
    hi: float = 0.5
    steps_full: int = 30   # N — text-to-image denoising steps
    steps_ref: int = 20    # K — image-to-image denoising steps (K < N)

    def composite_score(self, clip_score: float, pick_score: float) -> float:
        """Eq. 7 with both terms mapped to [0,1]; mean keeps S in [0,1]."""
        return 0.5 * (float(clip_score) + float(pick_score))

    def composite_scores(self, clip_scores: np.ndarray,
                         pick_scores: np.ndarray) -> np.ndarray:
        """Vectorised Eq. 7 over a candidate set — the serve pipeline's
        Score stage pairs this with ``Embedder.score_candidates`` so no
        per-candidate Python call survives on the hot path."""
        return 0.5 * (np.asarray(clip_scores, np.float64)
                      + np.asarray(pick_scores, np.float64))

    def route(self, score: float) -> Route:
        if score > self.hi:
            return Route.HIT_RETURN
        if score >= self.lo:
            return Route.IMG2IMG
        return Route.TXT2IMG

    def steps_for(self, route: Route) -> int:
        return {Route.HIT_RETURN: 0, Route.IMG2IMG: self.steps_ref,
                Route.TXT2IMG: self.steps_full}[route]


def select_reference(scores: np.ndarray) -> int:
    """argmax over the unioned candidate set (Algorithm 1 line 8)."""
    if scores.size == 0:
        return -1
    return int(np.argmax(scores))


def make_score_fn(embedder) -> Callable:
    """Build S_sim(P, I) from an embedding generator: CLIPScore uses the
    text/image cosine; PickScore uses the embedder's preference proxy."""

    def score(prompt_vec: np.ndarray, img_vec: np.ndarray, image=None) -> float:
        clip_s = float(np.clip((prompt_vec @ img_vec + 1.0) / 2.0, 0.0, 1.0))
        pick_s = float(embedder.pick_score(prompt_vec, img_vec, image))
        return clip_s, pick_s

    return score
