"""Generation-strategy policy (paper §IV-F, Algorithm 1, Fig. 7).

Composite similarity score  S = CLIPScore + PickScore  (Eq. 7), then:

    S  > hi  (0.5)        -> HIT_RETURN  : ship the cached image, 0 steps
    lo <= S <= hi (0.4..) -> IMG2IMG     : SDEdit from noised reference, K steps
    S  < lo  (0.4)        -> TXT2IMG     : full generation from noise, N steps

Both scores are normalised to [0, 1] before summing and the sum is halved,
so thresholds live on the paper's 0..1 scale. Thresholds are configurable —
benchmark fig15 sweeps them exactly like the paper's Figure 15.

Latent-depth schedule (beyond-paper, NIRVANA-style): when
``latent_depths`` is set, the binary img2img band refines into a DEPTH
schedule — the [lo, hi] band splits into ``len(latent_depths) + 1`` equal
sub-bands mapping match quality to a resume depth ``k`` (how many of the
K img2img chain steps an archived noised latent already absorbs): a weak
match resumes shallow (k = 0, the classic full img2img), a strong match
resumes deep and only runs ``K - k`` steps.  ``resume_depth`` is the
single home of that mapping.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, Tuple

import numpy as np


class Route(enum.Enum):
    HIT_RETURN = "hit_return"
    IMG2IMG = "img2img"
    TXT2IMG = "txt2img"


@dataclass
class GenerationPolicy:
    lo: float = 0.4
    hi: float = 0.5
    steps_full: int = 30   # N — text-to-image denoising steps
    steps_ref: int = 20    # K — image-to-image denoising steps (K < N)
    # resume depths of the latent-depth cache, ascending; () disables the
    # depth schedule (classic binary img2img/txt2img split)
    latent_depths: Tuple[int, ...] = ()

    def composite_score(self, clip_score: float, pick_score: float) -> float:
        """Eq. 7 with both terms mapped to [0,1]; mean keeps S in [0,1]."""
        return 0.5 * (float(clip_score) + float(pick_score))

    def composite_scores(self, clip_scores: np.ndarray,
                         pick_scores: np.ndarray) -> np.ndarray:
        """Vectorised Eq. 7 over a candidate set — the serve pipeline's
        Score stage pairs this with ``Embedder.score_candidates`` so no
        per-candidate Python call survives on the hot path."""
        return 0.5 * (np.asarray(clip_scores, np.float64)
                      + np.asarray(pick_scores, np.float64))

    def route(self, score: float) -> Route:
        if score > self.hi:
            return Route.HIT_RETURN
        if score >= self.lo:
            return Route.IMG2IMG
        return Route.TXT2IMG

    def steps_for(self, route: Route) -> int:
        return {Route.HIT_RETURN: 0, Route.IMG2IMG: self.steps_ref,
                Route.TXT2IMG: self.steps_full}[route]

    # -- latent-depth schedule (beyond-paper) -------------------------------

    def default_latent_depths(self) -> Tuple[int, ...]:
        """The archive depths k ∈ {K/4, K/2, 3K/4} of the latent-depth
        cache (K = ``steps_ref``), deduped and 0-free for tiny K."""
        k = self.steps_ref
        return tuple(sorted({k // 4, k // 2, (3 * k) // 4} - {0}))

    def resume_depth(self, score: float) -> int:
        """Map a composite score in the img2img band to a resume depth.

        The [lo, hi] band splits into ``len(latent_depths) + 1`` equal
        sub-bands over the depth levels ``(0,) + latent_depths``
        (ascending): score = lo resumes at depth 0 (full img2img), score
        >= hi resumes at the deepest archived level.  Sub-band boundaries
        belong to the DEEPER band (``frac·len(levels)`` floors, so an
        exact edge rounds up in depth).  With ``latent_depths == ()``
        every band score maps to depth 0 — the classic binary split."""
        if not self.latent_depths:
            return 0
        levels = (0,) + tuple(sorted(self.latent_depths))
        frac = (float(score) - self.lo) / max(self.hi - self.lo, 1e-12)
        frac = min(max(frac, 0.0), 1.0)
        return levels[min(int(frac * len(levels)), len(levels) - 1)]

    def steps_for_resume(self, k: int) -> int:
        """Denoising steps still to run when resuming from depth ``k``."""
        return max(self.steps_ref - int(k), 0)


def select_reference(scores: np.ndarray) -> int:
    """argmax over the unioned candidate set (Algorithm 1 line 8)."""
    if scores.size == 0:
        return -1
    return int(np.argmax(scores))


def make_score_fn(embedder) -> Callable:
    """Build S_sim(P, I) from an embedding generator: CLIPScore uses the
    text/image cosine; PickScore uses the embedder's preference proxy."""

    def score(prompt_vec: np.ndarray, img_vec: np.ndarray, image=None) -> float:
        clip_s = float(np.clip((prompt_vec @ img_vec + 1.0) / 2.0, 0.0, 1.0))
        pick_s = float(embedder.pick_score(prompt_vec, img_vec, image))
        return clip_s, pick_s

    return score
