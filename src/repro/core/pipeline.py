"""Staged serving pipeline — the Fig. 5 request path as explicit stages.

Every request batch flows through the same eight named, batch-first stages:

    Embed -> Schedule -> Retrieve -> Score -> Plan -> Generate
          -> Archive -> Finish

with a typed :class:`RequestState` carried per request (prompt, embedding,
schedule decision, retrieval rows, :class:`Plan`, image, result).  This is
the ONLY request path: ``CacheGenius.serve`` is a batch of one, so the
sequential and batched behaviours agree by construction.

Stage contracts (each stage sees the whole micro-batch):

* **Embed**     — prompt optimisation + ONE ``embed_text`` call.
* **Schedule**  — ONE ``RequestScheduler.schedule_batch`` (single history
  matmul, single node-representation similarity).  In score-aware routing
  mode (``system.routing == "score"``, the default with a cluster index)
  it ALSO issues the micro-batch's one cluster-wide device scan
  (``ClusterIndex.search_cluster_nodes``): every request's top-k on EVERY
  node feeds both the per-node best-composite routing matrix and —
  stashed on the state — the chosen node's retrieval candidates.
* **Retrieve**  — ONE fused ``ClusterIndex.search_batch`` device scan for
  the WHOLE micro-batch (all touched nodes, both dual-retrieval indexes,
  query→node masked); a no-op in score mode (the Schedule scan already
  produced every chosen node's rows, so Schedule+Retrieve = ONE scan
  total); per-node ``VectorDB.search_batch`` only as the no-cluster
  fallback.
* **Score**     — composite Eq. 7 scoring of every request's candidate set
  via ``Embedder.score_candidates`` — one vectorised matmul per request,
  never per-candidate Python ``clip_score``/``pick_score`` calls; lazily
  evaluated so requests the Plan stage coalesces never pay for it.
* **Plan**      — Algorithm 1 routing in submission order, coalescing
  near-duplicates of in-flight batch members onto one generation.  With
  the latent-depth cache enabled the binary img2img/txt2img split refines
  into a DEPTH schedule: a band request resumes the denoising chain from
  the deepest archived latent at or below ``policy.resume_depth(score)``
  (see :meth:`PlanStage._depth_plan`).
* **Generate**  — denoiser calls grouped by (node, workflow, steps) —
  resume plans additionally by depth — and issued through the batch-first
  :class:`GenerationBackend` protocol.
* **Archive**   — blob-store put + VDB insert in submission order, up to
  the batch's first interior maintenance crossing; later archives defer
  to the Finish stage so the sweep sees exactly the same cache state it
  would sequentially.
* **Finish**    — stats, Eq. 8 latency, exact-crossing maintenance,
  ``ServeResult``.

Semantics (pinned by the parity tests): scheduling and retrieval see the
cache state at batch entry (snapshot), archives land after generation in
submission order, and a batch of one is exactly the sequential loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import Route
from repro.core.scheduler import ScheduleDecision
from repro.utils import l2n, stable_hash


class TransientBackendError(RuntimeError):
    """A denoiser call failed in a way worth retrying (flaky accelerator,
    dropped RPC).  The Generate stage retries the group up to
    ``system.transient_retries`` times, charging each attempt to the
    node's health; the front-door dispatcher adds backoff on top."""


class CorruptReferenceError(RuntimeError):
    """An archived blob failed its checksum at hit time.  Raised by the
    Plan stage's verified fetches AFTER the corrupt entry has been purged
    (VDB slots evicted, blob deleted, history invalidated); the stage
    catches it and degrades the request to the txt2img miss path."""


# ---------------------------------------------------------------------------
# generation backend — batch-first protocol
# ---------------------------------------------------------------------------


class GenerationBackend:
    """Batch-first generation protocol.

    Subclasses implement the two REQUIRED batched entry points:

    ``txt2img_batch(prompts, steps, seeds) -> (B, H, W, 3)``
        One denoiser call for a whole same-step group.
    ``img2img_batch(prompts, references, steps, seeds) -> (B, H, W, 3)``
        Batched SDEdit over stacked references ``(B, H, W, 3)``.

    The scalar ``txt2img`` / ``img2img`` entry points derive automatically
    as a batch of one — override them only when a dedicated scalar path is
    cheaper (``DiffusionBackend`` does, to skip the batch plumbing).  A
    subclass that overrides ONLY the scalar methods (the old per-request
    surface) still works: the batched entry points fall back to a
    per-request loop over them.

    Migration note for pre-redesign callers: ``GenerationBackend`` used to
    be a dataclass of four optional callables.  Constructing
    ``GenerationBackend(txt2img=f, img2img=g, ...)`` still works — the
    callables are wrapped (see :class:`CallableBackend`), with missing
    batch callables falling back to a per-request loop, exactly the old
    serve-path fallback.
    """

    # legacy (txt2img, img2img, txt2img_batch, img2img_batch) callables;
    # the class-level default covers subclasses that skip __init__
    _fns: Tuple = (None, None, None, None)

    # latent-depth cache surface (optional): backends that can archive
    # noised intermediates of the img2img chain and resume denoising from
    # them flip this on and implement the two methods below
    supports_latent_resume: bool = False

    def __init__(self, txt2img=None, img2img=None, txt2img_batch=None,
                 img2img_batch=None):
        self._fns = (txt2img, img2img, txt2img_batch, img2img_batch)

    # -- required batched surface -------------------------------------------

    def txt2img_batch(self, prompts: Sequence[str], steps: int,
                      seeds: Sequence[int]) -> np.ndarray:
        fn_scalar, _, fn_batch, _ = self._fns
        if fn_batch is not None:
            return np.asarray(fn_batch(prompts, steps, seeds))
        if fn_scalar is None and type(self).txt2img is not \
                GenerationBackend.txt2img:
            # subclass migrated only the scalar surface: loop over it
            fn_scalar = self.txt2img
        if fn_scalar is not None:
            return np.stack([np.asarray(fn_scalar(p, steps, s))
                             for p, s in zip(prompts, seeds)])
        raise NotImplementedError(
            "GenerationBackend subclasses must implement txt2img_batch")

    def img2img_batch(self, prompts: Sequence[str], references: np.ndarray,
                      steps: int, seeds: Sequence[int]) -> np.ndarray:
        _, fn_scalar, _, fn_batch = self._fns
        if fn_batch is not None:
            return np.asarray(fn_batch(prompts, references, steps, seeds))
        if fn_scalar is None and type(self).img2img is not \
                GenerationBackend.img2img:
            fn_scalar = self.img2img
        if fn_scalar is not None:
            return np.stack([np.asarray(fn_scalar(p, r, steps, s))
                             for p, r, s in zip(prompts, references, seeds)])
        raise NotImplementedError(
            "GenerationBackend subclasses must implement img2img_batch")

    # -- derived scalar surface ---------------------------------------------

    def txt2img(self, prompt: str, steps: int, seed: int) -> np.ndarray:
        fn_scalar = self._fns[0]
        if fn_scalar is not None:
            return np.asarray(fn_scalar(prompt, steps, seed))
        return np.asarray(self.txt2img_batch([prompt], steps, [seed]))[0]

    def img2img(self, prompt: str, reference: np.ndarray, steps: int,
                seed: int) -> np.ndarray:
        fn_scalar = self._fns[1]
        if fn_scalar is not None:
            return np.asarray(fn_scalar(prompt, reference, steps, seed))
        return np.asarray(self.img2img_batch(
            [prompt], np.asarray(reference)[None], steps, [seed]))[0]

    # -- latent-depth cache surface (optional) --------------------------------

    def archive_latents_batch(self, images: np.ndarray,
                              seeds: Sequence[int],
                              depths: Sequence[int],
                              steps_total: int) -> np.ndarray:
        """Noised intermediates of each image's ``steps_total``-step
        img2img chain at every requested depth — shape
        ``(len(depths), B, ...)``.  The depth-k latent must equal what
        ``resume_batch(..., k=k)`` expects as its starting state, and the
        per-image noise draw must reuse the image's archive ``seed`` so
        resumed trajectories are reproducible."""
        raise NotImplementedError(
            "backend does not support latent archiving "
            "(supports_latent_resume is False)")

    def resume_batch(self, prompts: Sequence[str], latents: np.ndarray,
                     steps_total: int, k: int,
                     seeds: Sequence[int]) -> np.ndarray:
        """Resume the ``steps_total``-step img2img chain from depth ``k``
        (running ``steps_total - k`` denoising steps) for a stacked batch
        of archived latents — returns decoded images ``(B, H, W, 3)``.
        ``k == 0`` must reproduce ``img2img_batch`` exactly (same chain,
        same starting state)."""
        raise NotImplementedError(
            "backend does not support latent resume "
            "(supports_latent_resume is False)")


class CallableBackend(GenerationBackend):
    """Adapter: legacy per-request callables (plus optional batch callables)
    wrapped into the batch-first protocol.  Identical to constructing
    ``GenerationBackend`` with callables directly; the explicit name marks
    migration sites."""


# ---------------------------------------------------------------------------
# per-request state
# ---------------------------------------------------------------------------


@dataclass
class Plan:
    """Typed per-request execution plan (replaces the old anonymous dicts).

    ``kind`` is one of:

    * ``"alias"``   — coalesce onto in-flight batch member ``target``;
    * ``"history"`` — historical-query fast path, ``image`` already fetched;
    * ``"cached"``  — Algorithm 1 HIT_RETURN, ``image`` already fetched;
    * ``"gen"``     — run the denoiser (txt2img; img2img when ``ref`` is
      set; latent-depth resume when ``latent`` is set, running
      ``steps = K - resume_k`` remaining chain steps); ``fast`` marks the
      quality-priority fast path.
    """

    kind: str
    node: int = -1
    route: Optional[Route] = None
    steps: int = 0
    score: float = 0.0
    fast: Optional[str] = None
    ref: Optional[np.ndarray] = None
    target: int = -1
    image: Optional[np.ndarray] = None
    resume_k: int = 0                    # latent-depth resume depth
    latent: Optional[np.ndarray] = None  # archived noised latent (depth k)
    degraded: bool = False               # corrupt reference → miss path


@dataclass
class RequestState:
    """One request's state as it flows through the stages."""

    index: int                 # position in the micro-batch
    raw_prompt: str
    prompt: str                # optimised prompt (Generate conditions on it)
    seed: int
    quality_tier: bool
    clock: float               # logical arrival tick
    submitted_at: Optional[float] = None  # caller-clock submission instant
    admitted_at: float = 0.0   # perf_counter at pipeline entry
    # perf_counter at each stage's END, in stage order (every request in
    # the micro-batch gets its own copy — coalesced duplicates included)
    stage_ts: Dict[str, float] = field(default_factory=dict)
    pkey: int = 0              # stable prompt hash (priority fast path)
    pvec: Optional[np.ndarray] = None    # text embedding
    qvec: Optional[np.ndarray] = None    # L2-normalised pvec
    decision: Optional[ScheduleDecision] = None
    ret_scores: np.ndarray = field(default_factory=lambda: np.empty(0))
    ret_slots: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))
    retrieved: bool = False    # rows already filled (score-mode Schedule)
    best_slot: int = -1
    best_score: float = -1.0
    score_thunk: Optional[Callable[[], None]] = None
    plan: Optional[Plan] = None
    image: Optional[np.ndarray] = None
    archive_deferred: bool = False  # archive lands in Finish (post-crossing)
    result: Optional[object] = None      # ServeResult (set by Finish)


@dataclass
class BatchContext:
    """Shared per-micro-batch scratch handed to every stage."""

    system: object             # CacheGenius
    states: List[RequestState]
    t_wall0: float
    pvecs: Optional[np.ndarray] = None   # (B, 512) stacked text embeddings
    # step-level admission: (qvec, handle) of every earlier gen-plan
    # request that is still in flight or awaiting finalize — requests a
    # sequential loop would already have archived.  The Plan stage seeds
    # its coalescing set with these, encoding the out-of-batch handle as
    # a NEGATIVE alias target (-(handle + 1)); the step-level driver
    # resolves those aliases when the target's image lands.  None (the
    # group-mode default) leaves Plan's behaviour untouched.
    inflight: Optional[List[Tuple[np.ndarray, int]]] = None


# ---------------------------------------------------------------------------
# stages
# ---------------------------------------------------------------------------


def _composite_scores(system, pvec: np.ndarray,
                      ivecs: np.ndarray) -> np.ndarray:
    """One vectorised Eq. 7 evaluation of a candidate set (single home of
    the scalar-embedder fallback) — shared by score-mode Schedule routing
    and the Score stage so the two can never diverge."""
    score_fn = getattr(system.embedder, "score_candidates", None)
    if score_fn is not None:
        clips, picks = score_fn(pvec, ivecs)
    else:   # custom embedders without the vectorised entry point
        clips = np.array([system.embedder.clip_score(pvec, v)
                          for v in ivecs])
        picks = np.array([system.embedder.pick_score(pvec, v)
                          for v in ivecs])
    return system.policy.composite_scores(clips, picks)


class EmbedStage:
    name = "Embed"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        raw = [s.raw_prompt for s in ctx.states]
        if system.use_prompt_optimizer:
            for s in ctx.states:
                s.prompt = system.prompt_optimizer.optimize(s.raw_prompt)
        ctx.pvecs = system.embedder.embed_text(raw)     # one batched call
        qn = l2n(ctx.pvecs)
        for s, pv, qv in zip(ctx.states, ctx.pvecs, qn):
            s.pvec = pv
            s.qvec = qv
            s.pkey = stable_hash(s.raw_prompt, 1 << 62)


class ScheduleStage:
    """ONE routing pass for the whole micro-batch.

    Centroid mode: one ``RequestScheduler.schedule_batch`` call (single
    history matmul, single node-representation similarity).

    Score mode (``system.routing == "score"`` with a cluster index): the
    stage additionally issues the micro-batch's single cluster-wide
    device scan — ``ClusterIndex.search_cluster_nodes`` — so every
    request sees its top-k candidates on EVERY node.  Per-node best
    composite (Eq. 7) scores are computed with the same vectorised
    ``score_candidates`` path the Score stage uses and handed to
    ``schedule_batch(node_scores=...)``; the chosen node's candidate row
    (bit-identical to what a masked retrieval scan would return) is then
    stashed on the state, making the Retrieve stage a no-op.  Schedule +
    Retrieve therefore cost exactly ONE device scan per micro-batch,
    pinned by the call-count test in ``tests/test_scheduling_score.py``.
    The contract is mesh-transparent: with a sharded cluster index
    (``mesh_nodes > 1``) the same single call becomes one ``shard_map``
    launch whose per-device scans run concurrently — still one
    ``fused_scans`` tick, still bitwise-identical routing (pinned by
    ``tests/test_cluster_sharded.py``).
    """

    name = "Schedule"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        if not system.use_scheduler:
            for s in ctx.states:
                s.decision = ScheduleDecision(
                    node=int(s.clock) % len(system.dbs))
            return
        cluster = getattr(system, "cluster_index", None)
        node_rows = None
        node_best = None
        best_details = None
        if getattr(system, "routing", "centroid") == "score" \
                and cluster is not None:
            node_rows = cluster.search_cluster_nodes(ctx.pvecs, system.topk)
            node_best, best_details = self._node_best_scores(
                system, ctx, node_rows)
        decisions = system.scheduler.schedule_batch(
            ctx.pvecs, system.dbs,
            quality_tiers=[s.quality_tier for s in ctx.states],
            prompt_keys=[s.pkey for s in ctx.states],
            node_scores=node_best)
        for s, d in zip(ctx.states, decisions):
            s.decision = d
            if node_rows is not None and d.fast_path is None:
                s.ret_scores, s.ret_slots = node_rows[s.index][d.node]
                s.retrieved = True
                # routing already composite-scored the chosen node's
                # candidates — reuse its argmax so the Score stage never
                # re-scores them (one scoring matmul per request, total)
                picked = best_details[s.index].get(d.node)
                if picked is not None:
                    s.best_slot, s.best_score = picked
                db = system.dbs[d.node]
                db.query_count += 1       # same accounting as a masked scan

    @staticmethod
    def _node_best_scores(system, ctx: BatchContext, node_rows):
        """Score-mode routing input: a (B, nodes) matrix of each
        request's best composite Eq. 7 score per node (0.0 where a node
        holds no valid candidate), plus per-request ``{node: (slot,
        score)}`` argmax details so the chosen node's best is reused
        downstream instead of re-scored.  One vectorised
        ``score_candidates`` call per request over ALL nodes' candidates;
        embedders without the vectorised entry point fall back to scalar
        calls via the shared :func:`_composite_scores` helper."""
        n_nodes = len(system.dbs)
        best = np.zeros((len(ctx.states), n_nodes))
        details: List[Dict[int, Tuple[int, float]]] = \
            [{} for _ in ctx.states]
        for s in ctx.states:
            spans = []
            cand_vecs = []
            for node in range(n_nodes):
                _, slots = node_rows[s.index][node]
                cand_vecs.append(system.dbs[node].img_vecs[slots])
                spans.append(len(slots))
            if not sum(spans):
                continue
            comp = _composite_scores(system, s.pvec,
                                     np.concatenate(cand_vecs))
            off = 0
            for node, n in enumerate(spans):
                if n:
                    j = int(np.argmax(comp[off:off + n]))
                    slot = int(node_rows[s.index][node][1][j])
                    score = float(comp[off + j])
                    best[s.index, node] = score
                    details[s.index][node] = (slot, score)
                off += n
        return best, details


class RetrieveStage:
    """ONE fused device scan per micro-batch: all retrieval-path queries
    against all touched node slabs through the cluster's device-resident
    index (``ClusterIndex.search_batch`` with the query→node mask) —
    never a per-node Python loop, never a host→device slab copy.  Under
    score-aware routing the Schedule stage's cluster-wide scan already
    filled every chosen node's rows (``state.retrieved``), so this stage
    issues NOTHING — Schedule+Retrieve collapse to one scan.  The scan
    is mesh-transparent: a sharded index (``mesh_nodes > 1``) serves the
    identical call from per-device node shards with bitwise-equal
    results.  Systems without a cluster index (custom stage lists,
    standalone fleets) fall back to the per-node ``VectorDB.search_batch``
    grouping."""

    name = "Retrieve"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        members = [s for s in ctx.states
                   if s.decision.fast_path is None and not s.retrieved]
        if not members:
            return
        cluster = getattr(system, "cluster_index", None)
        if cluster is not None:
            idxs = [m.index for m in members]
            nodes = [m.decision.node for m in members]
            rows = cluster.search_batch(ctx.pvecs[idxs], nodes, system.topk)
            for m, (scores, slots) in zip(members, rows):
                m.ret_scores, m.ret_slots = scores, slots
            return
        by_node: Dict[int, List[RequestState]] = {}
        for m in members:
            by_node.setdefault(m.decision.node, []).append(m)
        for node, group in by_node.items():
            idxs = [m.index for m in group]
            rows = system.dbs[node].search_batch(ctx.pvecs[idxs], system.topk)
            for m, (scores, slots) in zip(group, rows):
                m.ret_scores, m.ret_slots = scores, slots


class ScoreStage:
    """Attach a lazy, vectorised Eq. 7 scorer to every retrieval-path
    request.  Evaluation is ONE ``score_candidates`` matmul per request —
    never per-candidate Python ``clip_score``/``pick_score`` calls — and
    is deferred to the Plan walk: whether a request coalesces onto an
    in-flight batch member is only decidable there, and coalesced
    requests must not pay for scoring (the pre-pipeline loop checked
    dedup before scoring too).  The candidate snapshot is unchanged by
    the deferral: Plan only touches access stats, archives land later.

    Score-mode requests arrive already scored: routing composite-scored
    every node's candidates at schedule time, and the chosen node's
    argmax was stashed as ``best_slot``/``best_score`` — this stage
    attaches no thunk for them (one scoring matmul per request, total).
    """

    name = "Score"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        for s in ctx.states:
            if s.decision.fast_path is not None or len(s.ret_slots) == 0:
                continue
            if s.best_slot >= 0:
                continue    # score-mode Schedule already picked the best
            s.score_thunk = self._make_thunk(system, s)

    @staticmethod
    def _make_thunk(system, s: RequestState):
        def evaluate() -> None:
            db = system.dbs[s.decision.node]
            comp = _composite_scores(system, s.pvec, db.img_vecs[s.ret_slots])
            j = int(np.argmax(comp))
            s.best_slot = int(s.ret_slots[j])
            s.best_score = float(comp[j])
            s.score_thunk = None

        return evaluate


class PlanStage:
    """Algorithm 1 routing in submission order.  Near-duplicates of
    in-flight (will-archive) batch members coalesce onto that member's
    generation — exactly the history fast path the sequential loop takes
    once the earlier result is recorded.

    Every blob this stage fetches (history image, cached return, img2img
    reference, archived latent) goes through a verified fetch: a blob
    whose bytes no longer match the CRC recorded at archive time is
    PURGED (VDB slots evicted — journaled like any eviction — blob
    deleted, scheduler history invalidated, a fault charged to the owning
    node's health) and the request DEGRADES to the full txt2img miss path
    — a correct image at full step cost, never a result conditioned on
    garbage (``Plan.degraded`` marks these for the stats)."""

    name = "Plan"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        pending_vecs: List[np.ndarray] = []
        pending_req: List[int] = []
        if ctx.inflight:
            # step-level admission: earlier unfinalized gen requests join
            # the coalescing set first (they precede this batch in
            # submission order), with negative-encoded handles as targets
            for qv, handle in ctx.inflight:
                pending_vecs.append(qv)
                pending_req.append(-(int(handle) + 1))
        for s in ctx.states:
            pend_sim, pend_j = -np.inf, -1
            if pending_vecs:
                sims = np.stack(pending_vecs) @ s.qvec
                pj = int(np.argmax(sims))
                pend_sim, pend_j = float(sims[pj]), pending_req[pj]
            try:
                self._plan_one(system, s, pend_sim, pend_j)
            except CorruptReferenceError:
                self._degrade(system, s)
            if s.plan.kind == "gen":
                pending_vecs.append(s.qvec)
                pending_req.append(s.index)

    def _plan_one(self, system, s: RequestState, pend_sim: float,
                  pend_j: int) -> None:
        """Set ``s.plan`` for one request (the Algorithm 1 walk body).
        Raises :class:`CorruptReferenceError` if any blob it needs fails
        verification — the caller degrades the request."""
        d = s.decision
        if d.fast_path == "history":
            if pend_sim > d.match_score:   # later history entry wins
                s.plan = Plan(kind="alias", target=pend_j)
            else:
                s.plan = Plan(kind="history", image=self._fetch_payload(
                    system, int(d.history_payload)))
            return
        if (system.use_scheduler
                and pend_sim >= system.scheduler.dedup_threshold):
            # sequential serve would history-hit the in-flight record
            system.scheduler.count_history_hit()
            system.scheduler.uncount_prompt(s.pkey)
            s.plan = Plan(kind="alias", target=pend_j)
            return
        node = d.node
        if d.fast_path == "priority":
            s.plan = Plan(kind="gen", node=node, route=Route.TXT2IMG,
                          steps=system.policy.steps_full,
                          fast="priority", score=0.0)
            return
        if s.score_thunk is not None:
            s.score_thunk()
        db = system.dbs[node]
        route = (system.policy.route(s.best_score) if s.best_slot >= 0
                 else Route.TXT2IMG)
        steps = system.policy.steps_for(route)
        if route is not Route.TXT2IMG:
            plan = self._depth_plan(system, s, db, node, route)
            if plan is not None:
                s.plan = plan
                return
        if route is Route.HIT_RETURN:
            s.plan = Plan(kind="cached", node=node, score=s.best_score,
                          image=self._fetch_slot(system, db, s.best_slot,
                                                 s.clock))
        elif route is Route.IMG2IMG:
            s.plan = Plan(kind="gen", node=node, route=route, steps=steps,
                          score=s.best_score,
                          ref=self._fetch_slot(system, db, s.best_slot,
                                               s.clock))
        else:
            s.plan = Plan(kind="gen", node=node, route=route, steps=steps,
                          score=s.best_score)

    # -- verified fetches / degraded mode -------------------------------------

    @staticmethod
    def _fetch_payload(system, payload: int) -> np.ndarray:
        """Blob fetch with verify-on-hit: checksum-failing blobs are
        quarantined and the fetch raises instead of returning bytes."""
        store = system.blob_store
        verify = getattr(store, "verify", None)
        if verify is not None and not verify(payload):
            PlanStage._quarantine(system, payload)
            raise CorruptReferenceError(
                f"archived blob {payload} failed its checksum")
        return store.get(payload)

    @staticmethod
    def _fetch_slot(system, db, slot: int, clock: float) -> np.ndarray:
        """Verified fetch of a VDB slot's blob; marks the access (exactly
        the pre-verify behaviour) only once the bytes check out."""
        payload = int(db.payload_ids[slot])
        store = system.blob_store
        verify = getattr(store, "verify", None)
        if verify is not None and not verify(payload):
            PlanStage._quarantine(system, payload)
            raise CorruptReferenceError(
                f"archived blob {payload} failed its checksum")
        db.mark_access(np.array([slot]), clock)
        return store.get(payload)

    @staticmethod
    def _quarantine(system, payload: int) -> None:
        """Purge one checksum-failing blob everywhere it is referenced:
        evict its VDB slots (journaled like any eviction, cluster rows
        invalidated by the eviction observer), delete the blob, drop it
        from scheduler history, and charge a fault to the owning node's
        health.  After this no path can ever serve the bytes."""
        owner = -1
        for node, db in enumerate(getattr(system, "dbs", ())):
            slots = np.flatnonzero(db.valid & (db.payload_ids == payload))
            if len(slots):
                if owner < 0:
                    owner = node
                db.evict_slots(slots)
        system.blob_store.delete(payload)
        if getattr(system, "use_scheduler", False):
            system.scheduler.invalidate_payloads([payload])
            if owner >= 0:
                system.scheduler.observe_fault(owner, kind="corrupt")
        stats = getattr(system, "stats", None)
        if stats is not None:
            stats.corrupt_hits += 1

    @staticmethod
    def _degrade(system, s: RequestState) -> None:
        """Corrupt reference detected mid-plan: serve the request through
        the full txt2img miss path (correct image, full step cost).  The
        corrupt entry was already purged by :meth:`_quarantine`."""
        node = s.decision.node
        if node < 0:    # history fast path carries no node
            if getattr(system, "use_scheduler", False):
                node = max(system.scheduler._routable_nodes(),
                           key=lambda n: n.speed).index
            else:
                node = int(s.clock) % len(system.dbs)
        s.plan = Plan(kind="gen", node=node, route=Route.TXT2IMG,
                      steps=system.policy.steps_full, score=0.0,
                      degraded=True)

    @staticmethod
    def _depth_plan(system, s: RequestState, db, node: int,
                    route: Route) -> Optional[Plan]:
        """Latent-depth refinement of a HIT_RETURN/IMG2IMG route.

        The matched slot's ``source_id`` groups all entries archived from
        the same finished image — the image itself (depth -1) plus its
        noised latents (depth k).  HIT_RETURN ships the finished image
        when it survives eviction, else resumes from the DEEPEST sibling
        latent.  An img2img-band request maps its composite score to a
        desired depth (``policy.resume_depth``) and resumes from the
        deepest archived latent at or below it; with only deeper latents
        left it resumes from the shallowest one (conservative overshoot —
        still fewer steps than full img2img), and with only the finished
        image left it falls back to the classic SDEdit plan (return
        ``None``).  Returns ``None`` whenever the depth schedule is off,
        the backend cannot resume, or the slot carries no depth metadata —
        the caller then runs the classic Algorithm 1 plan unchanged."""
        if not getattr(system, "latent_depths", ()):
            return None
        if not getattr(system.backend, "supports_latent_resume", False):
            return None
        src = int(db.source_id[s.best_slot])
        if src < 0:
            return None
        sib = np.flatnonzero(db.valid & (db.source_id == src))
        lat = {int(db.depth[i]): int(i) for i in sib if db.depth[i] >= 0}
        fin = [int(i) for i in sib if db.depth[i] < 0]
        # retrieval can argmax ANY sibling row (latents share the finished
        # image's vectors), so the classic fallback is only safe when the
        # matched slot itself is a finished image — otherwise build the
        # equivalent plan here against the finished sibling explicitly
        matched_finished = int(db.depth[s.best_slot]) < 0

        def resume(k: int, slot: int) -> Plan:
            return Plan(kind="gen", node=node, route=Route.IMG2IMG,
                        steps=system.policy.steps_for_resume(k),
                        score=s.best_score, resume_k=k,
                        latent=PlanStage._fetch_slot(system, db, slot,
                                                     s.clock))

        if route is Route.HIT_RETURN:
            if fin:
                if matched_finished:
                    return None         # classic cached return
                slot = fin[0]
                return Plan(kind="cached", node=node, score=s.best_score,
                            image=PlanStage._fetch_slot(system, db, slot,
                                                        s.clock))
            if not lat:
                return None
            k = max(lat)                # strongest match → resume deepest
            return resume(k, lat[k])
        # IMG2IMG band: depth schedule
        if not lat:
            return None                 # only the finished image survives
        desired = system.policy.resume_depth(s.best_score)
        usable = [k for k in lat if k <= desired]
        if usable:
            k = max(usable)
        elif fin:
            # classic img2img beats overshooting a too-deep latent
            if matched_finished:
                return None
            slot = fin[0]
            return Plan(kind="gen", node=node, route=Route.IMG2IMG,
                        steps=system.policy.steps_for(Route.IMG2IMG),
                        score=s.best_score,
                        ref=PlanStage._fetch_slot(system, db, slot,
                                                  s.clock))
        else:
            k = min(lat)                # overshoot: shallowest latent left
        return resume(k, lat[k])


class GenerateStage:
    """One padded backend call per (node, workflow, steps) group; latent
    resumes additionally group by depth (same AOT bucket family — one
    compiled program per (resume depth, steps, batch bucket)).

    Every backend call runs through :meth:`_call`: a
    :class:`TransientBackendError` is retried up to
    ``system.transient_retries`` times, with each failed attempt charged
    to the group's node health (``scheduler.observe_fault``) and each
    success clearing the streak (``observe_ok``) — fault-free runs keep
    health at exactly 1.0, so routing stays bit-identical."""

    name = "Generate"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        txt_groups: Dict[tuple, List[RequestState]] = {}
        img_groups: Dict[tuple, List[RequestState]] = {}
        res_groups: Dict[tuple, List[RequestState]] = {}
        for s in ctx.states:
            if s.plan.kind != "gen":
                continue
            if s.plan.latent is not None:
                res_groups.setdefault(
                    (s.plan.node, s.plan.resume_k, s.plan.steps),
                    []).append(s)
                continue
            grp = img_groups if s.plan.ref is not None else txt_groups
            grp.setdefault((s.plan.node, s.plan.steps), []).append(s)
        for (node, steps), members in txt_groups.items():
            out = self._call(system, node, system.backend.txt2img_batch,
                             [m.prompt for m in members], steps,
                             [m.seed for m in members])
            for j, m in enumerate(members):
                m.image = np.asarray(out[j])
        for (node, steps), members in img_groups.items():
            refs = np.stack([m.plan.ref for m in members])
            out = self._call(system, node, system.backend.img2img_batch,
                             [m.prompt for m in members], refs, steps,
                             [m.seed for m in members])
            for j, m in enumerate(members):
                m.image = np.asarray(out[j])
        for (node, k, steps), members in res_groups.items():
            lats = np.stack([m.plan.latent for m in members])
            out = self._call(system, node, system.backend.resume_batch,
                             [m.prompt for m in members], lats, steps + k, k,
                             [m.seed for m in members])
            for j, m in enumerate(members):
                m.image = np.asarray(out[j])

    @staticmethod
    def _call(system, node: int, fn, *args) -> np.ndarray:
        """One backend call with transient-fault retry and health
        bookkeeping; the final failed attempt re-raises so no request is
        ever silently dropped."""
        retries = getattr(system, "transient_retries", 0)
        sched = (system.scheduler
                 if getattr(system, "use_scheduler", False) else None)
        attempt = 0
        while True:
            try:
                out = np.asarray(fn(*args))
            except TransientBackendError:
                if sched is not None and 0 <= node < len(sched.nodes):
                    sched.observe_fault(node, kind="transient")
                stats = getattr(system, "stats", None)
                if stats is not None:
                    stats.transient_retries += 1
                attempt += 1
                if attempt > retries:
                    raise
                continue
            if sched is not None and 0 <= node < len(sched.nodes):
                sched.observe_ok(node)
            return out


def _do_archive(system, s: RequestState) -> None:
    """The one archive call (blob put + VDB insert + history record) —
    shared by the Archive stage and the Finish stage's deferred flush."""
    system._archive(s.raw_prompt, s.pvec, s.image, s.plan.node,
                    t=s.clock, seed=s.seed)


class ArchiveStage:
    """Blob-store put + VDB insert in submission order (blob ids / history
    order match the sequential loop exactly).

    Exact-crossing maintenance support: archives land eagerly only up to
    the batch's first INTERIOR ``maintenance_interval`` crossing (a
    request count that is a multiple of the interval, with later requests
    still in the batch).  Requests past that boundary mark
    ``archive_deferred`` and flush inside the Finish stage's per-request
    result loop — so the eviction sweep at crossing r sees exactly the
    archives of requests 1..r, the same cache state the sequential loop
    produces, for ANY batch partitioning of the trace."""

    name = "Archive"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        interval = system.maintenance_interval
        req_no = system.stats.requests      # results not yet recorded
        boundary = None                     # index of first interior crossing
        for i in range(len(ctx.states) - 1):
            if (req_no + i + 1) % interval == 0:
                boundary = i
                break
        for i, s in enumerate(ctx.states):
            if s.plan.kind != "gen":
                continue
            if boundary is not None and i > boundary:
                s.archive_deferred = True
                continue
            _do_archive(system, s)


class FinishStage:
    """Stats, Eq. 8 latency, exact-crossing maintenance, ``ServeResult``.

    Maintenance fires at EXACT request-count crossings: the result loop
    walks the batch in submission order, flushing each request's deferred
    archive (see :class:`ArchiveStage`) before recording its result, and
    runs the eviction sweep the moment the request counter hits a
    ``maintenance_interval`` multiple — splitting result recording at the
    boundary.  The sweep at crossing r therefore sees exactly the
    archives of requests 1..r regardless of how the trace was partitioned
    into micro-batches, so intervals SMALLER than the batch size keep
    their sequential cadence too (earlier revisions coalesced sweeps at
    the group boundary and needed interval >= max_batch — the old
    ROADMAP caveat).  Remaining divergence from the sequential loop is
    confined to the batch-entry snapshot: retrieval and access marking
    inside one batch cannot see a mid-batch sweep that already happened
    sequentially.

    Wall-clock accounting: each request reports the micro-batch's total
    wall time divided by the batch size (batch-amortised per-request
    cost); the batch total itself is appended to
    ``ServeStats.batch_wall_latencies``.  The total is taken AFTER the
    result loop AND its interleaved maintenance sweeps, so sweeps stay
    inside the measurement; results and stats are back-filled with the
    final share.

    The TRUE per-request accounting (``stage_walls`` / ``wall_total`` /
    ``queue_delay``) is back-filled by the ``ServePipeline.run`` driver
    from the per-stage timestamps once the last stage returns — the
    amortised ``wall_latency`` stays only as the legacy throughput share.
    """

    name = "Finish"

    def run(self, ctx: BatchContext) -> None:
        system = ctx.system
        n = len(ctx.states)
        interval = system.maintenance_interval
        wall = 0.0          # back-filled once the batch total is known
        for s in ctx.states:
            if s.archive_deferred:
                _do_archive(system, s)
                s.archive_deferred = False
            p = s.plan
            if p.kind == "alias":
                s.image = ctx.states[p.target].image
                s.result = system._finish(
                    s.image, Route.HIT_RETURN, -1, 1.0, wall,
                    steps=0, retrieved=False, fast="history")
            elif p.kind == "history":
                s.image = p.image
                s.result = system._finish(
                    s.image, Route.HIT_RETURN, -1, 1.0, wall,
                    steps=0, retrieved=False, fast="history")
            elif p.kind == "gen" and p.fast == "priority":
                s.result = system._finish(
                    s.image, Route.TXT2IMG, p.node, 0.0, wall,
                    steps=p.steps, retrieved=False, fast="priority")
            elif p.kind == "cached":
                s.image = p.image
                s.result = system._finish(
                    s.image, Route.HIT_RETURN, p.node, p.score, wall,
                    steps=0)
            else:
                s.result = system._finish(
                    s.image, p.route, p.node, p.score, wall,
                    steps=p.steps,
                    resumed_from=(p.resume_k if p.latent is not None
                                  else -1),
                    degraded=p.degraded)
            # exact crossing: sweep the moment the counter hits a multiple
            if system.stats.requests % interval == 0:
                system.maintain()
        t_batch = time.perf_counter() - ctx.t_wall0
        wall = t_batch / n
        system.stats.batch_wall_latencies.append(t_batch)
        system.stats.wall_latencies[-n:] = [wall] * n
        for s in ctx.states:
            s.result.wall_latency = wall


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------


DEFAULT_STAGES = (EmbedStage, ScheduleStage, RetrieveStage, ScoreStage,
                  PlanStage, GenerateStage, ArchiveStage, FinishStage)


class ServePipeline:
    """Ordered stage list + the micro-batch driver.

    ``run`` admits the batch (ticks the system clock, builds one
    :class:`RequestState` per request), pushes the whole batch through
    every stage in order, and returns the states with ``result`` set.

    Timing contract: every state records ``admitted_at`` (pipeline entry)
    and ``stage_ts[name]`` (stage end) on the ``time.perf_counter`` clock,
    so per-stage wall times are real measurements, not the batch-amortised
    share.  After the last stage the driver back-fills each result's
    ``stage_walls`` (per-stage durations), ``wall_total`` (admission to
    Finish), and — when the caller supplied ``submitted_ats`` on the same
    clock — ``queue_delay`` (submission to admission).  Stages run at
    batch granularity, so batch members share stage boundaries; what is
    per-request is the existence of the full timestamp trail (coalesced
    duplicates included) and the queue delay.
    """

    def __init__(self, stages: Optional[Sequence] = None):
        self.stages = list(stages) if stages is not None else \
            [cls() for cls in DEFAULT_STAGES]

    @property
    def stage_names(self) -> List[str]:
        return [st.name for st in self.stages]

    def run(self, system, prompts: Sequence[str], *,
            seeds: Optional[Sequence[int]] = None,
            quality_tiers: Optional[Sequence[bool]] = None,
            submitted_ats: Optional[Sequence[float]] = None,
            ) -> List[RequestState]:
        n = len(prompts)
        if n == 0:
            return []
        t0 = time.perf_counter()
        seeds = list(seeds) if seeds is not None else [0] * n
        tiers = (list(quality_tiers) if quality_tiers is not None
                 else [False] * n)
        subs = (list(submitted_ats) if submitted_ats is not None
                else [None] * n)
        states = [RequestState(index=i, raw_prompt=str(p), prompt=str(p),
                               seed=seeds[i], quality_tier=tiers[i],
                               clock=system.clock + i + 1,
                               submitted_at=subs[i], admitted_at=t0)
                  for i, p in enumerate(prompts)]
        system.clock += n
        ctx = BatchContext(system=system, states=states, t_wall0=t0)
        for stage in self.stages:
            stage.run(ctx)
            ts = time.perf_counter()
            for s in states:
                s.stage_ts[stage.name] = ts
        # back-fill per-request timing onto the finished results
        last = self.stages[-1].name
        for s in states:
            if s.result is None:       # custom stage list without a Finish
                continue
            prev = t0
            walls: Dict[str, float] = {}
            for name in self.stage_names:
                walls[name] = s.stage_ts[name] - prev
                prev = s.stage_ts[name]
            s.result.stage_walls = walls
            s.result.wall_total = s.stage_ts[last] - s.admitted_at
            if s.submitted_at is not None:
                s.result.queue_delay = s.admitted_at - s.submitted_at
        return states

    # -- step-level split: admit now, generate over many boundaries, -----------
    #    finalize per slot in submission order

    def _stage_index(self, name: str) -> int:
        for i, st in enumerate(self.stages):
            if st.name == name:
                return i
        raise ValueError(
            f"stage {name!r} not in pipeline {self.stage_names} — the "
            "step-level split needs the default Generate/Archive/Finish "
            "stage shape")

    def run_admission(self, system, prompts: Sequence[str], *,
                      seeds: Optional[Sequence[int]] = None,
                      quality_tiers: Optional[Sequence[bool]] = None,
                      submitted_ats: Optional[Sequence[float]] = None,
                      inflight: Optional[List[Tuple[np.ndarray, int]]] = None,
                      ) -> List[RequestState]:
        """Run every stage BEFORE Generate (Embed..Plan) for a fresh
        admission group and return the planned states.

        This is the front half of :meth:`run` for the step-level serving
        engine: each state leaves with its ``plan`` set (clock ticked,
        Embed..Plan timestamps stamped) but no image/result — generation
        happens over many step boundaries in the caller's slot engine, and
        Archive/Finish land per slot via :meth:`finalize`.  ``inflight``
        seeds the Plan stage's coalescing set with earlier unfinalized gen
        requests (see :class:`BatchContext`)."""
        n = len(prompts)
        if n == 0:
            return []
        gen_i = self._stage_index("Generate")
        t0 = time.perf_counter()
        seeds = list(seeds) if seeds is not None else [0] * n
        tiers = (list(quality_tiers) if quality_tiers is not None
                 else [False] * n)
        subs = (list(submitted_ats) if submitted_ats is not None
                else [None] * n)
        states = [RequestState(index=i, raw_prompt=str(p), prompt=str(p),
                               seed=seeds[i], quality_tier=tiers[i],
                               clock=system.clock + i + 1,
                               submitted_at=subs[i], admitted_at=t0)
                  for i, p in enumerate(prompts)]
        system.clock += n
        ctx = BatchContext(system=system, states=states, t_wall0=t0,
                           inflight=inflight)
        for stage in self.stages[:gen_i]:
            stage.run(ctx)
            ts = time.perf_counter()
            for s in states:
                s.stage_ts[stage.name] = ts
        return states

    def finalize(self, system, state: RequestState) -> RequestState:
        """Run Archive + Finish for ONE retired request (the back half of
        the step-level split) and back-fill its per-request timing.

        The caller must have set ``state.image`` for gen plans (the slot
        engine's decode) and resolved negative alias targets into
        ``history`` plans.  A singleton batch has no interior maintenance
        boundary, so the Archive stage lands the blob/VDB insert eagerly
        and the Finish stage sweeps at the exact request-count crossing —
        calling this in submission order reproduces the sequential loop's
        (archive, sweep) sequence exactly.

        Timing is stamped PER SLOT, never per group: Embed..Plan carry the
        admission-time stamps, Generate the retirement stamp (filled at
        finalize start if the driver didn't reach it — cached/history/alias
        plans), Archive/Finish land here, and ``stage_walls`` /
        ``wall_total`` / ``queue_delay`` are derived from this slot's own
        trail — retirement order never smears one slot's walls onto
        another's."""
        arch_i = self._stage_index("Archive")
        t0 = time.perf_counter()
        for name in self.stage_names[:arch_i]:
            state.stage_ts.setdefault(name, t0)
        ctx = BatchContext(system=system, states=[state], t_wall0=t0)
        for stage in self.stages[arch_i:]:
            stage.run(ctx)
            ts = time.perf_counter()
            state.stage_ts[stage.name] = ts
        if state.result is not None:
            prev = state.admitted_at
            walls: Dict[str, float] = {}
            for name in self.stage_names:
                walls[name] = state.stage_ts[name] - prev
                prev = state.stage_ts[name]
            state.result.stage_walls = walls
            state.result.wall_total = (state.stage_ts[self.stages[-1].name]
                                       - state.admitted_at)
            if state.submitted_at is not None:
                state.result.queue_delay = state.admitted_at - state.submitted_at
        return state
