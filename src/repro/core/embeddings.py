"""Embedding generator (paper §IV-B): dual-modal 512-d embeddings.

The paper uses CLIP.  This container is offline (no pretrained weights), so
two backends implement the same interface:

``ProxyClipEmbedder``
    Deterministic CLIP stand-in.  Images are embedded with fixed random
    Fourier features of a downsampled thumbnail.  Text is embedded by
    *rendering the caption's semantics to a canonical thumbnail* (the
    synthetic corpus's captions are parseable) and embedding that render —
    which gives exactly the property CLIP provides: text and images of the
    same concept land close in one space.  Used by default in benchmarks —
    fully deterministic, no training.

``BertProxyEmbedder``
    Text-only hashed bag-of-words embedder with NO cross-modal alignment —
    the paper's BERT baseline (Table V).  Text-text similarity works;
    text-image similarity is near chance, reproducing the paper's ordering.

``TowerEmbedder``
    A real dual-tower (tiny ViT + text transformer from ``repro.models``)
    trained contrastively on the synthetic corpus; exercised in
    ``examples/train_clip_tower.py`` and the integration tests.

All embeddings are L2-normalised (paper: "L2-normalized and mapped into a
512-dimensional latent space").
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.utils import stable_hash

EMBED_DIM = 512


def _l2n(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


class _RandomFeatures:
    """Fixed random Fourier feature map: x -> cos(Wx + b), deterministic.

    ``bandwidth`` controls the implied RBF kernel width: larger values
    decorrelate dissimilar inputs faster (cos-sim ~ exp(-bw^2 |x-y|^2 / 2d)).
    """

    def __init__(self, in_dim: int, out_dim: int, seed: int, *, bandwidth: float = 1.0):
        rng = np.random.default_rng(seed)
        self.w = rng.normal(0, bandwidth / np.sqrt(in_dim),
                            (in_dim, out_dim)).astype(np.float32)
        self.b = rng.uniform(0, 2 * np.pi, (out_dim,)).astype(np.float32)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        return np.cos(x @ self.w + self.b)


class ProxyClipEmbedder:
    """Deterministic CLIP proxy aligned through canonical renders.

    The 512-d embedding is a weighted concatenation of two random-feature
    channels:

      * **appearance** — RFF of the color thumbnail,
      * **structure**  — RFF of a color-invariant foreground mask
        (deviation from the median background), capturing the paper's
        observation that *structural* similarity (layout/shape) is what
        makes a reference image valuable, independent of semantics.

    Channel weights are calibrated so that, under the synthetic corpus:
    same-scene pairs score ≈0.95+, same-structure/different-appearance
    pairs land in the paper's img2img band [0.4, 0.5], unrelated pairs
    fall well below 0.4.
    """

    name = "clip-proxy"
    dim = EMBED_DIM

    def __init__(self, render_fn: Callable[[str], np.ndarray], *,
                 thumb: int = 16, seed: int = 7, bandwidth: float = 8.0,
                 w_appearance: float = 0.65, w_structure: float = 0.35):
        # bandwidth=8.0 calibrated so Eq. 7 composite scores land on the
        # paper's Figure-7 bands: identical scene ~1.0 (direct-return,
        # > 0.5), same-structure/different-appearance ~0.42 (the img2img
        # band [0.4, 0.5]), unrelated ~0.04 (< 0.4, full generation).
        """render_fn: caption -> (H, W, 3) float image in [-1, 1] — the
        canonical render of the caption's semantics (data.synthetic)."""
        self.render_fn = render_fn
        self.thumb = thumb
        half = EMBED_DIM // 2
        self.feat_app = _RandomFeatures(thumb * thumb * 3, half, seed,
                                        bandwidth=bandwidth)
        self.feat_struct = _RandomFeatures(thumb * thumb, EMBED_DIM - half,
                                           seed + 1, bandwidth=bandwidth)
        self.w_app = float(w_appearance)
        self.w_struct = float(w_structure)
        self._anchor: Optional[np.ndarray] = None

    # -- modality encoders ---------------------------------------------------

    def _thumbnail(self, img: np.ndarray) -> np.ndarray:
        h, w = img.shape[:2]
        t = self.thumb
        ys = (np.arange(t) * h) // t
        xs = (np.arange(t) * w) // t
        return img[np.ix_(ys, xs)]

    def embed_image(self, images: np.ndarray) -> np.ndarray:
        """images: (N, H, W, 3) in [-1, 1] -> (N, 512)."""
        images = np.asarray(images, np.float32)
        if images.ndim == 3:
            images = images[None]
        thumbs = np.stack([self._thumbnail(im) for im in images])  # (N,t,t,3)
        flat = thumbs.reshape(len(images), -1)
        # structure channel: foreground = deviation from per-image median color
        med = np.median(thumbs.reshape(len(images), -1, 3), axis=1)  # (N,3)
        dev = np.linalg.norm(thumbs - med[:, None, None, :], axis=-1)  # (N,t,t)
        struct = (dev > 0.35).astype(np.float32).reshape(len(images), -1)
        fa = _l2n(self.feat_app(flat)) * np.sqrt(self.w_app)
        fs = _l2n(self.feat_struct(struct)) * np.sqrt(self.w_struct)
        return _l2n(np.concatenate([fa, fs], axis=-1))

    def embed_text(self, prompts: Sequence[str]) -> np.ndarray:
        if isinstance(prompts, str):
            prompts = [prompts]
        renders = np.stack([self.render_fn(p) for p in prompts])
        return self.embed_image(renders)

    # -- scores ----------------------------------------------------------------

    def set_corpus_anchor(self, img_vecs: np.ndarray) -> None:
        """Aesthetic anchor = corpus mean (PickScore preference proxy)."""
        self._anchor = _l2n(np.mean(img_vecs, axis=0))

    def clip_score(self, txt_vec: np.ndarray, img_vec: np.ndarray) -> float:
        """Raw cosine clipped to [0, 1] — the paper's CLIPScore is 100·cos;
        we keep [0,1] so Eq. 7 thresholds (0.4/0.5) compare directly."""
        return float(self.score_candidates(txt_vec,
                                           np.asarray(img_vec)[None])[0][0])

    def pick_score(self, txt_vec: np.ndarray, img_vec: np.ndarray,
                   image: Optional[np.ndarray] = None) -> float:
        """Preference proxy: prompt alignment blended with closeness to the
        corpus aesthetic anchor (stands in for the learned PickScore)."""
        return float(self.score_candidates(txt_vec,
                                           np.asarray(img_vec)[None])[1][0])

    def score_candidates(self, txt_vec: np.ndarray, img_vecs: np.ndarray,
                         ) -> tuple:
        """Vectorised serve-path scoring: CLIPScore and PickScore for a
        whole candidate set in one matmul (ROADMAP: batched composite
        scoring).  Returns ``(clip_scores, pick_scores)``, each ``(K,)``.
        This is the single home of the Eq. 7 score math — the scalar
        ``clip_score`` / ``pick_score`` entry points are K=1 wrappers."""
        img_vecs = np.atleast_2d(np.asarray(img_vecs, np.float32))
        txt_vec = np.asarray(txt_vec, np.float32)
        align = np.clip(img_vecs @ txt_vec, 0.0, 1.0)
        anchor = getattr(self, "_anchor", None)
        if anchor is not None:
            aesthetic = np.clip(img_vecs @ anchor, 0.0, 1.0)
        else:
            aesthetic = align
        pick = np.clip(0.8 * align + 0.2 * aesthetic, 0.0, 1.0)
        return align, pick


class BertProxyEmbedder:
    """Hashed bag-of-words text embedder — the Table V BERT baseline.

    Shares the image encoder with a ProxyClipEmbedder when provided (the
    'BERT text + CLIP image' row); otherwise images are embedded with an
    independent (misaligned) random projection (the 'BERT only' row).
    """

    name = "bert-proxy"
    dim = EMBED_DIM

    def __init__(self, *, seed: int = 11, image_encoder=None):
        self.seed = seed
        self.image_encoder = image_encoder
        self._rows: dict[int, np.ndarray] = {}
        self._img_features = _RandomFeatures(16 * 16 * 3, EMBED_DIM, seed + 1)
        self._anchor = None

    def _word_row(self, word: str) -> np.ndarray:
        wid = stable_hash(word.lower(), 1 << 30)
        if wid not in self._rows:
            rng = np.random.default_rng(wid ^ self.seed)
            self._rows[wid] = rng.normal(0, 1, (EMBED_DIM,)).astype(np.float32)
        return self._rows[wid]

    def embed_text(self, prompts: Sequence[str]) -> np.ndarray:
        if isinstance(prompts, str):
            prompts = [prompts]
        out = np.zeros((len(prompts), EMBED_DIM), np.float32)
        for i, p in enumerate(prompts):
            words = [w for w in p.replace(",", " ").split() if w]
            if words:
                out[i] = np.sum([self._word_row(w) for w in words], axis=0)
        return _l2n(out)

    def embed_image(self, images: np.ndarray) -> np.ndarray:
        if self.image_encoder is not None:
            return self.image_encoder.embed_image(images)
        images = np.asarray(images, np.float32)
        if images.ndim == 3:
            images = images[None]
        t = 16
        flats = []
        for im in images:
            h, w = im.shape[:2]
            ys = (np.arange(t) * h) // t
            xs = (np.arange(t) * w) // t
            flats.append(im[np.ix_(ys, xs)].reshape(-1))
        return _l2n(self._img_features(np.stack(flats)))

    def set_corpus_anchor(self, img_vecs: np.ndarray) -> None:
        self._anchor = _l2n(np.mean(img_vecs, axis=0))

    clip_score = ProxyClipEmbedder.clip_score
    pick_score = ProxyClipEmbedder.pick_score
    score_candidates = ProxyClipEmbedder.score_candidates


class TowerEmbedder:
    """Trained dual-tower embedder; see examples/train_clip_tower.py."""

    name = "tower"
    dim = EMBED_DIM

    def __init__(self, params, apply_text, apply_image):
        self.params = params
        self._apply_text = apply_text
        self._apply_image = apply_image
        self._anchor = None

    def embed_text(self, prompts) -> np.ndarray:
        return _l2n(np.asarray(self._apply_text(self.params, prompts)))

    def embed_image(self, images) -> np.ndarray:
        return _l2n(np.asarray(self._apply_image(self.params, np.asarray(images))))

    def set_corpus_anchor(self, img_vecs: np.ndarray) -> None:
        self._anchor = _l2n(np.mean(img_vecs, axis=0))

    clip_score = ProxyClipEmbedder.clip_score
    pick_score = ProxyClipEmbedder.pick_score
    score_candidates = ProxyClipEmbedder.score_candidates
