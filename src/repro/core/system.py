"""CacheGenius orchestrator — one staged, batch-first request path (Fig. 5).

Every request — sequential or batched — flows through the SAME explicit
pipeline (``repro.core.pipeline.ServePipeline``):

    Embed -> Schedule -> Retrieve -> Score -> Plan -> Generate
          -> Archive -> Finish

``serve`` is a batch of one; ``serve_batch`` is the same pipeline over a
micro-batch, so sequential/batched parity holds by construction.  Per
request the pipeline carries a typed ``RequestState``:

    index, raw_prompt, prompt (optimised), seed, quality_tier, clock,
    pkey, pvec/qvec (text embedding), decision (ScheduleDecision),
    ret_scores/ret_slots (dual-retrieval rows), best_slot/best_score,
    plan (typed Plan: alias | history | cached | gen), image, result.

Stage map onto the paper: Embed = prompt-optimizer + embedding-generator
(§IV-B/C), Schedule = request scheduler with history/priority fast paths
(§IV-E), Retrieve+Score+Plan = dual ANN retrieval and Algorithm 1 routing
(Eq. 7), Generate = {cached | SDEdit img2img K steps | txt2img N steps},
Archive = blob store + VDB insert, Finish = Eq. 8 latency/cost accounting
and the periodic LCU sweep (Algorithm 2).

Retrieval engine (PR 4): construction builds a
``repro.core.cluster_index.ClusterIndex`` over the node fleet — the
cluster's cache state lives device-resident as stacked
``(2, nodes, capacity, dim)`` img/txt slabs updated incrementally by
every VDB ``add``/``evict`` (one build-time upload, zero steady-state
slab copies), and the Retrieve stage answers each micro-batch with ONE
fused masked scan across all touched nodes (``use_cluster_index=False``
restores the per-node loop).

Score-aware scheduling (PR 5): with ``routing="score"`` (the default
when a cluster index exists) the Schedule stage issues the micro-batch's
single cluster-wide scan (``ClusterIndex.search_cluster_nodes``) so
every request is routed on its TRUE best composite (Eq. 7) match on
every node — blended with the centroid-affinity prior, queue-depth load
penalty and the Eq. 8 expected-latency term — and the chosen node's
candidate rows are reused by the Retrieve stage (Schedule+Retrieve = ONE
device scan per micro-batch).  ``routing="centroid"`` keeps the paper's
Eq. 6 node-representation baseline, which also remains the automatic
fallback when no cluster index is attached.

Latent-depth cache (PR 6, beyond-paper): with ``latent_depths`` set the
Archive stage stores noised intermediates of each finished image's
img2img chain at depths k ∈ {K/4, K/2, 3K/4} (one stacked ``VectorDB``
insert carrying host-side ``depth``/``source_id`` metadata — device
slabs and fused scans are untouched), and the Plan stage maps the
composite Eq. 7 score to a resume depth (``policy.resume_depth``):
strong band matches resume deep and run only K - k steps through the
backend's ``resume_batch``.  Latents and finished images compete under
the same ``C_max`` via the eviction policy's per-depth utility discount.

Backend protocol migration (for external callers of ``GenerationBackend``):
it is no longer a dataclass of four optional callables but a batch-first
base class — subclass it and implement ``txt2img_batch`` /
``img2img_batch``; scalar ``txt2img`` / ``img2img`` derive automatically
as a batch of one.  Constructing ``GenerationBackend(txt2img=f, ...)``
with the old callables still works: they are wrapped by the
``CallableBackend`` adapter (missing batch callables fall back to a
per-request loop).  ``DiffusionBackend`` now IS a ``GenerationBackend``;
its ``as_generation_backend()`` survives as a no-op compatibility shim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster_index import ClusterIndex
from repro.core.latency_model import CostModel, LatencyModel
from repro.core.lcu import EvictionPolicy, LCUPolicy
from repro.core.pipeline import (CallableBackend, GenerationBackend, Plan,
                                 RequestState, ServePipeline)
from repro.core.policy import GenerationPolicy, Route
from repro.core.prompt_optimizer import PromptOptimizer
from repro.core.scheduler import NodeInfo, RequestScheduler
from repro.core.storage_classifier import StorageClassifier
from repro.core.vdb import BlobStore, VectorDB

__all__ = ["CacheGenius", "CallableBackend", "GenerationBackend", "Plan",
           "RequestState", "Route", "ServePipeline", "ServeResult",
           "ServeStats"]


@dataclass
class ServeResult:
    image: np.ndarray
    route: Route
    node: int
    score: float
    latency: float            # Eq. 8 modelled latency
    wall_latency: float       # batch-amortised measured wall-clock on this host
    steps: int
    fast_path: Optional[str] = None
    # latent-depth cache: depth the denoising chain resumed from (-1 =
    # classic path, k >= 0 = resumed from an archived depth-k latent and
    # ran only steps = K - k chain steps)
    resumed_from: int = -1
    # true per-request accounting from the pipeline's per-stage timestamps
    # (back-filled by ServePipeline.run; see its timing contract):
    queue_delay: float = 0.0  # submission -> pipeline admission (caller clock)
    wall_total: float = 0.0   # pipeline admission -> Finish, measured
    stage_walls: Dict[str, float] = field(default_factory=dict)
    # degraded mode: the matched reference failed its checksum and the
    # request was served through the full txt2img miss path instead
    degraded: bool = False


@dataclass
class ServeStats:
    route_counts: Dict[str, int] = field(default_factory=dict)
    latencies: List[float] = field(default_factory=list)
    wall_latencies: List[float] = field(default_factory=list)
    # one entry per served micro-batch: that batch's TOTAL wall-clock.
    # Per-request ``wall_latencies`` are batch-amortised (total / batch
    # size), so sum(wall_latencies) ~= sum(batch_wall_latencies).
    batch_wall_latencies: List[float] = field(default_factory=list)
    scores: List[float] = field(default_factory=list)
    requests: int = 0
    cache_hits: int = 0        # HIT_RETURN + history fast path
    reference_hits: int = 0    # IMG2IMG
    total_steps: int = 0       # denoising steps actually executed
    latent_resumes: int = 0    # requests resumed from an archived latent
    # fault-domain accounting (repro.core.pipeline verified fetches /
    # transient retry; repro.faults chaos harness)
    corrupt_hits: int = 0      # checksum-failing blobs caught at hit time
    degraded_serves: int = 0   # requests degraded to the txt2img miss path
    transient_retries: int = 0  # failed backend attempts that were retried

    def record(self, r: ServeResult) -> None:
        self.requests += 1
        key = r.fast_path or r.route.value
        self.route_counts[key] = self.route_counts.get(key, 0) + 1
        self.latencies.append(r.latency)
        self.wall_latencies.append(r.wall_latency)
        self.scores.append(r.score)
        self.total_steps += r.steps
        if r.resumed_from >= 0:
            self.latent_resumes += 1
        if r.degraded:
            self.degraded_serves += 1
        if r.route is Route.HIT_RETURN or r.fast_path == "history":
            self.cache_hits += 1
        elif r.route is Route.IMG2IMG:
            self.reference_hits += 1

    @property
    def hit_rate(self) -> float:
        """Any outcome that avoided full-noise generation counts as a hit."""
        useful = self.cache_hits + self.reference_hits
        return useful / max(self.requests, 1)

    @property
    def mean_steps(self) -> float:
        """Mean denoising steps executed per request — the latent-depth
        cache's headline metric (lower = more work skipped)."""
        return self.total_steps / max(self.requests, 1)


class CacheGenius:
    def __init__(self, *, embedder, dbs: Sequence[VectorDB], blob_store: BlobStore,
                 backend: GenerationBackend,
                 classifier: Optional[StorageClassifier] = None,
                 policy: Optional[GenerationPolicy] = None,
                 latency_model: Optional[LatencyModel] = None,
                 cost_model: Optional[CostModel] = None,
                 eviction: Optional[EvictionPolicy] = None,
                 prompt_optimizer: Optional[PromptOptimizer] = None,
                 node_speeds: Optional[Sequence[float]] = None,
                 cache_capacity: Optional[int] = None,
                 maintenance_interval: int = 200,
                 topk: int = 8,
                 transient_retries: int = 2,
                 use_scheduler: bool = True,
                 use_prompt_optimizer: bool = True,
                 use_cluster_index: bool = True,
                 mesh_nodes: int = 1,
                 routing: str = "score",
                 latent_depths=None,
                 pipeline: Optional[ServePipeline] = None):
        if routing not in ("score", "centroid"):
            raise ValueError(
                f"routing must be 'score' or 'centroid', got {routing!r}")
        self.embedder = embedder
        self.dbs = list(dbs)
        self.blob_store = blob_store
        self.backend = backend
        self.classifier = classifier
        self.policy = policy or GenerationPolicy()
        self.latency_model = latency_model or LatencyModel()
        self.cost_model = cost_model or CostModel()
        self.eviction = eviction or LCUPolicy()
        self.prompt_optimizer = prompt_optimizer or PromptOptimizer()
        speeds = list(node_speeds or [1.0] * len(self.dbs))
        self.scheduler = RequestScheduler(
            nodes=[NodeInfo(i, speed=s) for i, s in enumerate(speeds)])
        self.cache_capacity = cache_capacity or sum(db.capacity for db in self.dbs)
        self.maintenance_interval = maintenance_interval
        self.topk = topk
        # how many times the Generate stage retries a backend call that
        # raised TransientBackendError before letting it propagate
        self.transient_retries = int(transient_retries)
        self.use_scheduler = use_scheduler
        self.use_prompt_optimizer = use_prompt_optimizer
        # device-resident cross-node retrieval engine: the fleet's cache
        # state lives on device (ONE build-time upload, incremental row
        # updates from every add/evict) and the Schedule/Retrieve stages
        # issue ONE fused scan per micro-batch across all touched nodes.
        # mesh_nodes > 1 shards the slabs over a 1-D "nodes" device mesh
        # (each device scans only its local node shard; results stay
        # bitwise identical) and is preserved across every re-stack
        # (join/fail/rejoin).
        self.mesh_nodes = int(mesh_nodes)
        self.cluster_index = (
            ClusterIndex.from_dbs(self.dbs, mesh_nodes=self.mesh_nodes)
            if use_cluster_index and self.dbs else None)
        # routing="score" (default): the Schedule stage routes on each
        # request's TRUE best composite match per node from the cluster
        # scan, blended with load + expected latency; "centroid" is the
        # Eq. 6 baseline and the automatic no-cluster-index fallback.
        self.routing = routing
        # latent-depth cache: archive noised img2img intermediates at these
        # chain depths alongside each finished image and let the Plan stage
        # resume denoising from them.  None/() = off (classic binary
        # split); True = the policy's default {K/4, K/2, 3K/4} schedule.
        if latent_depths is None or latent_depths == ():
            self.latent_depths = ()
        elif latent_depths is True:
            self.latent_depths = self.policy.default_latent_depths()
        else:
            depths = tuple(sorted({int(k) for k in latent_depths}))
            if any(not 0 < k < self.policy.steps_ref for k in depths):
                raise ValueError(
                    f"latent_depths must satisfy 0 < k < steps_ref="
                    f"{self.policy.steps_ref}, got {depths}")
            self.latent_depths = depths
        self.policy.latent_depths = self.latent_depths
        self.scheduler.policy = self.policy
        self.scheduler.latency_model = self.latency_model
        self.pipeline = pipeline or ServePipeline()
        self.stats = ServeStats()
        self.clock = 0.0

    # ------------------------------------------------------------------ serve

    def serve(self, prompt: str, *, seed: int = 0, quality_tier: bool = False,
              ) -> ServeResult:
        """Serve one request: a batch of one through the staged pipeline
        (pre-pipeline compatibility signature)."""
        return self.serve_batch([prompt], seeds=[seed],
                                quality_tiers=[quality_tier])[0]

    def serve_batch(self, prompts: Sequence[str], *,
                    seeds: Optional[Sequence[int]] = None,
                    quality_tiers: Optional[Sequence[bool]] = None,
                    submitted_ats: Optional[Sequence[float]] = None,
                    ) -> List[ServeResult]:
        """Serve a micro-batch through one pass of the staged pipeline.

        Amortisation vs. a request-at-a-time loop (see
        ``repro.core.pipeline`` for the per-stage contracts):

        * ONE ``embed_text`` call for every prompt in the batch;
        * ONE ``RequestScheduler.schedule_batch`` (single history matmul,
          single node-representation similarity);
        * ONE ``VectorDB.search_batch`` per node touched by the batch;
        * ONE vectorised ``score_candidates`` matmul per request (no
          per-candidate Python scoring calls);
        * denoiser calls grouped by (node, workflow, steps) and executed
          as single batched ``GenerationBackend`` calls.

        Semantics: scheduling and retrieval see the cache state at batch
        entry (snapshot), and archives land after generation.  Requests
        whose prompt near-duplicates an earlier in-batch request that will
        archive are coalesced onto that request's result — exactly the
        history fast path the sequential loop takes once the earlier
        result is recorded.  A batched drain therefore matches a
        sequential loop whenever distinct in-batch prompts do not interact
        through freshly archived images (the parity tests pin this on a
        fixed Zipf trace).  Results come back in submission order.

        ``submitted_ats`` (optional, ``time.perf_counter`` clock) lets the
        caller stamp when each request was submitted; each result's
        ``queue_delay`` then reports the time actually waited before the
        pipeline admitted it.  Results always carry ``wall_total`` and
        per-stage ``stage_walls`` from the pipeline timestamps.
        """
        states = self.pipeline.run(self, prompts, seeds=seeds,
                                   quality_tiers=quality_tiers,
                                   submitted_ats=submitted_ats)
        return [s.result for s in states]

    # ------------------------------------------------------------- internals

    def _archive(self, prompt: str, pvec: np.ndarray, img: np.ndarray,
                 node: int, *, t: Optional[float] = None,
                 seed: int = 0) -> None:
        """Store the generated image to NFS (blob store) + insert into VDB.

        With the latent-depth cache on (and a backend that supports it),
        the finished image's noised img2img intermediates at every
        configured depth are archived alongside it in the SAME
        ``VectorDB.add`` call — one stacked insert, so the device slab /
        cluster row update stays one batched write.  Latent rows share
        the finished image's embedding vectors (retrieval matches the
        image semantics; depth only changes where the chain resumes) and
        carry ``depth``/``source_id`` metadata host-side."""
        pid = self.blob_store.put(img)
        ivec = self.embedder.embed_image(img[None])[0]
        t = self.clock if t is None else t
        depths = self.latent_depths
        if depths and getattr(self.backend, "supports_latent_resume", False):
            lat = self.backend.archive_latents_batch(
                np.asarray(img)[None], [seed], depths,
                self.policy.steps_ref)
            lat_pids = [self.blob_store.put(np.asarray(lat[j][0]))
                        for j in range(len(depths))]
            rows = 1 + len(depths)
            self.dbs[node].add(
                np.repeat(ivec[None], rows, axis=0),
                np.repeat(pvec[None], rows, axis=0),
                np.array([pid, *lat_pids]), t,
                depths=np.array([-1, *depths], np.int64),
                source_ids=np.full((rows,), pid, np.int64))
        else:
            self.dbs[node].add(ivec[None], pvec[None], np.array([pid]), t)
        self.scheduler.record_result(pvec, pid)

    def _finish(self, img, route, node, score, wall, *, steps, retrieved=True,
                fast=None, resumed_from=-1, degraded=False) -> ServeResult:
        speed = (self.scheduler.nodes[node].speed if 0 <= node < len(self.dbs)
                 else max(n.speed for n in self.scheduler.nodes))
        lat = self.latency_model.latency(route, steps, node_speed=speed,
                                         scheduled=self.use_scheduler,
                                         retrieved=retrieved,
                                         resumed=resumed_from >= 0)
        gpu_s = steps * self.latency_model.t_step / max(speed, 1e-9)
        self.cost_model.charge(max(node, 0), gpu_s,
                               vdb_seconds=self.latency_model.t_retrieve if retrieved else 0.0)
        res = ServeResult(image=img, route=route, node=node, score=score,
                          latency=lat, wall_latency=wall,
                          steps=steps, fast_path=fast,
                          resumed_from=resumed_from, degraded=degraded)
        self.stats.record(res)
        return res

    def maintain(self) -> Dict[int, np.ndarray]:
        """Run the eviction policy across all node VDBs (Algorithm 2)."""
        evicted = self.eviction.maintain(self.dbs, self.cache_capacity)
        all_payloads = []
        for _, payloads in evicted.items():
            for p in payloads:
                self.blob_store.delete(int(p))
                all_payloads.append(int(p))
        # keep the historical-query cache consistent with the blob store
        self.scheduler.invalidate_payloads(all_payloads)
        return evicted

    def fail_node(self, node: int) -> None:
        """GRACEFUL edge-node failure: reassign its VDB shard, stop
        routing to it.

        Hardened edges (pinned by tests): an unknown node index raises
        :class:`repro.core.scheduler.UnknownNodeError`; failing an
        already-dead node is a NO-OP (a second call must not re-run the
        classifier reassignment, which would shrink its centroids
        again); failing the last alive node raises ``RuntimeError`` —
        an empty fleet cannot serve."""
        self.scheduler._check_node(node)
        if not self.scheduler.nodes[node].alive:
            return
        if sum(n.alive for n in self.scheduler.nodes) == 1:
            raise RuntimeError(
                f"cannot fail node {node}: it is the last alive node")
        self.scheduler.mark_failed(node)
        if self.classifier is not None:
            alive = [n.index for n in self.scheduler.nodes if n.alive]
            self.classifier.reassign_failed_node(self.dbs, node, self.clock,
                                                 survivors=alive)

    def crash_node(self, node: int) -> VectorDB:
        """HARD crash: the node stops routing and its in-memory cache is
        LOST — unlike :meth:`fail_node`, nothing is reassigned (a crash
        takes its data down with it; durability comes from the node's
        :class:`repro.core.journal.CacheJournal`, if one was attached).
        The node's ``VectorDB`` is swapped for a fresh empty one and the
        cluster slabs are re-stacked.  Returns the dead db (diagnostic
        surface — e.g. to compare against a journal replay)."""
        self.scheduler._check_node(node)
        if not self.scheduler.nodes[node].alive:
            raise RuntimeError(f"node {node} is already dead")
        if sum(n.alive for n in self.scheduler.nodes) == 1:
            raise RuntimeError(
                f"cannot crash node {node}: it is the last alive node")
        self.scheduler.mark_failed(node)
        old = self.dbs[node]
        old.detach_journal()
        fresh = VectorDB(old.dim, old.capacity, name=old.name,
                         use_pallas=old.use_pallas, interpret=old.interpret)
        if self.cluster_index is not None:
            old.unregister_cluster(self.cluster_index)
        self.dbs[node] = fresh
        self._restack_cluster()
        return old

    def rejoin_node(self, node: int,
                    db: Optional[VectorDB] = None) -> None:
        """Rejoin a failed/crashed node through the join-path machinery
        (scheduler slot revived, cluster slabs re-stacked via
        ``ClusterIndex.from_dbs`` — ONE upload, same as :meth:`join_node`).

        ``db`` replaces the node's current ``VectorDB`` before rejoining —
        the durability path hands a ``CacheJournal.replay`` result here so
        the node comes back with its pre-crash cache instead of cold.
        ``None`` rejoins with whatever the node holds (empty after a
        crash, its old shard after a graceful fail)."""
        self.scheduler._check_node(node)
        if self.scheduler.nodes[node].alive:
            raise RuntimeError(f"node {node} is alive — nothing to rejoin")
        if db is not None:
            cur = self.dbs[node]
            if (db.dim, db.capacity) != (cur.dim, cur.capacity):
                raise ValueError(
                    f"replacement db shape ({db.dim}, {db.capacity}) != "
                    f"node {node} shape ({cur.dim}, {cur.capacity})")
            if self.cluster_index is not None:
                cur.unregister_cluster(self.cluster_index)
            self.dbs[node] = db
        self.scheduler.mark_alive(node)
        self._restack_cluster()

    def _restack_cluster(self) -> None:
        """Rebuild the device-resident cluster slabs from the fleet's
        current numpy state (one upload; see :meth:`join_node`)."""
        if self.cluster_index is None:
            return
        for d in self.dbs:
            d.unregister_cluster(self.cluster_index)
        self.cluster_index = ClusterIndex.from_dbs(
            self.dbs, mesh_nodes=self.mesh_nodes)

    def join_node(self, *, speed: float = 1.0,
                  capacity: Optional[int] = None) -> int:
        """Graceful node JOIN: grow the fleet by one fresh, empty node.

        The new node gets its own ``VectorDB`` (``capacity`` defaults to
        node 0's), a scheduler slot at ``speed``, and a share of the
        fleet cache budget (``cache_capacity`` grows by the new node's
        capacity).  The device-resident ``ClusterIndex`` slabs are
        fixed-shape ``(2, nodes, capacity, dim)``, so a join re-stacks
        them once from the fleet's numpy state (ONE upload — the same
        cost as construction; steady-state incremental updates resume
        immediately after).  Safe between micro-batches: routing reads
        the fleet only at batch admission, so callers (e.g. the
        front-door dispatcher) apply joins at group boundaries.

        Returns the new node's index.  The storage classifier's K-means
        centroids are left untouched — the joined node earns its
        semantic identity from the archives routed to it.
        """
        if not self.dbs:
            raise RuntimeError("cannot join a node into an empty fleet")
        ref = self.dbs[0]
        cap = int(capacity) if capacity is not None else ref.capacity
        if cap < 1:
            raise ValueError(f"capacity must be >= 1, got {cap}")
        node = len(self.dbs)
        db = VectorDB(ref.dim, cap, name=f"node{node}",
                      use_pallas=ref.use_pallas, interpret=ref.interpret)
        self.dbs.append(db)
        self.scheduler.add_node(speed=speed)
        self.cache_capacity += cap
        self._restack_cluster()
        return node

    @property
    def total_size(self) -> int:
        return sum(db.size for db in self.dbs)
