"""CacheGenius orchestrator — the end-to-end request path of Fig. 5.

request -> prompt-optimizer -> embedding-generator -> request-scheduler
        -> VDB dual retrieval on the chosen node -> Algorithm 1 routing
        -> {return cached | SDEdit img2img (K steps) | txt2img (N steps)}
        -> archive result to blob store + VDB insert -> periodic LCU sweep

The denoising backends are injected (``GenerationBackend``) so the same
orchestrator drives the tiny CPU DiT in benchmarks, the SD1.5-class UNet in
the examples, and a ShapeDtypeStruct-only stub in the dry-run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.embeddings import ProxyClipEmbedder
from repro.core.latency_model import CostModel, LatencyModel
from repro.core.lcu import EvictionPolicy, LCUPolicy
from repro.core.policy import GenerationPolicy, Route
from repro.core.prompt_optimizer import PromptOptimizer
from repro.core.scheduler import NodeInfo, RequestScheduler, ScheduleDecision
from repro.core.storage_classifier import StorageClassifier
from repro.core.vdb import BlobStore, VectorDB
from repro.utils import l2n, stable_hash


@dataclass
class GenerationBackend:
    """txt2img(prompt, steps, seed) / img2img(prompt, reference, steps, seed)
    both return an (H, W, 3) float image in [-1, 1].

    The optional batched entry points take parallel lists and return a
    stacked (B, H, W, 3) array; when absent, the batched serve path falls
    back to a per-request loop (scheduling/retrieval amortisation still
    applies, only the denoiser runs unbatched)."""

    txt2img: Callable[[str, int, int], np.ndarray]
    img2img: Callable[[str, np.ndarray, int, int], np.ndarray]
    txt2img_batch: Optional[Callable[[Sequence[str], int, Sequence[int]],
                                     np.ndarray]] = None
    img2img_batch: Optional[Callable[[Sequence[str], np.ndarray, int,
                                      Sequence[int]], np.ndarray]] = None


@dataclass
class ServeResult:
    image: np.ndarray
    route: Route
    node: int
    score: float
    latency: float            # Eq. 8 modelled latency
    wall_latency: float       # measured wall-clock on this host
    steps: int
    fast_path: Optional[str] = None


@dataclass
class ServeStats:
    route_counts: Dict[str, int] = field(default_factory=dict)
    latencies: List[float] = field(default_factory=list)
    wall_latencies: List[float] = field(default_factory=list)
    scores: List[float] = field(default_factory=list)
    requests: int = 0
    cache_hits: int = 0        # HIT_RETURN + history fast path
    reference_hits: int = 0    # IMG2IMG

    def record(self, r: ServeResult) -> None:
        self.requests += 1
        key = r.fast_path or r.route.value
        self.route_counts[key] = self.route_counts.get(key, 0) + 1
        self.latencies.append(r.latency)
        self.wall_latencies.append(r.wall_latency)
        self.scores.append(r.score)
        if r.route is Route.HIT_RETURN or r.fast_path == "history":
            self.cache_hits += 1
        elif r.route is Route.IMG2IMG:
            self.reference_hits += 1

    @property
    def hit_rate(self) -> float:
        """Any outcome that avoided full-noise generation counts as a hit."""
        useful = self.cache_hits + self.reference_hits
        return useful / max(self.requests, 1)


class CacheGenius:
    def __init__(self, *, embedder, dbs: Sequence[VectorDB], blob_store: BlobStore,
                 backend: GenerationBackend,
                 classifier: Optional[StorageClassifier] = None,
                 policy: Optional[GenerationPolicy] = None,
                 latency_model: Optional[LatencyModel] = None,
                 cost_model: Optional[CostModel] = None,
                 eviction: Optional[EvictionPolicy] = None,
                 prompt_optimizer: Optional[PromptOptimizer] = None,
                 node_speeds: Optional[Sequence[float]] = None,
                 cache_capacity: Optional[int] = None,
                 maintenance_interval: int = 200,
                 topk: int = 8,
                 use_scheduler: bool = True,
                 use_prompt_optimizer: bool = True):
        self.embedder = embedder
        self.dbs = list(dbs)
        self.blob_store = blob_store
        self.backend = backend
        self.classifier = classifier
        self.policy = policy or GenerationPolicy()
        self.latency_model = latency_model or LatencyModel()
        self.cost_model = cost_model or CostModel()
        self.eviction = eviction or LCUPolicy()
        self.prompt_optimizer = prompt_optimizer or PromptOptimizer()
        speeds = list(node_speeds or [1.0] * len(self.dbs))
        self.scheduler = RequestScheduler(
            nodes=[NodeInfo(i, speed=s) for i, s in enumerate(speeds)])
        self.cache_capacity = cache_capacity or sum(db.capacity for db in self.dbs)
        self.maintenance_interval = maintenance_interval
        self.topk = topk
        self.use_scheduler = use_scheduler
        self.use_prompt_optimizer = use_prompt_optimizer
        self.stats = ServeStats()
        self.clock = 0.0

    # ------------------------------------------------------------------ serve

    def serve(self, prompt: str, *, seed: int = 0, quality_tier: bool = False,
              ) -> ServeResult:
        t_wall0 = time.perf_counter()
        self.clock += 1.0
        raw_prompt = prompt
        if self.use_prompt_optimizer:
            prompt = self.prompt_optimizer.optimize(prompt)
        pvec = self.embedder.embed_text([raw_prompt])[0]
        pkey = stable_hash(raw_prompt, 1 << 62)

        if self.use_scheduler:
            decision = self.scheduler.schedule(
                pvec, self.dbs, quality_tier=quality_tier, prompt_key=pkey)
        else:
            decision = ScheduleDecision(node=int(self.clock) % len(self.dbs))

        # fast path: historical query cache — reuse the archived image
        if decision.fast_path == "history":
            img = self.blob_store.get(decision.history_payload)
            res = self._finish(img, Route.HIT_RETURN, -1, 1.0, t_wall0,
                               steps=0, retrieved=False, fast="history")
            return res

        node = decision.node
        db = self.dbs[node]

        # quality-priority fast path: forced full-quality txt2img, no retrieval
        if decision.fast_path == "priority":
            steps = self.policy.steps_full
            img = self.backend.txt2img(prompt, steps, seed)
            self._archive(raw_prompt, pvec, img, node)
            self.scheduler.complete(node)
            return self._finish(img, Route.TXT2IMG, node, 0.0, t_wall0,
                                steps=steps, retrieved=False, fast="priority")

        # dual ANN retrieval + composite scoring (Algorithm 1)
        scores, slots = db.search(pvec, self.topk)
        best_slot, best_score = -1, -1.0
        for sc, sl in zip(scores, slots):
            ivec = db.img_vecs[sl]
            clip_s = self.embedder.clip_score(pvec, ivec)
            pick_s = self.embedder.pick_score(pvec, ivec)
            s = self.policy.composite_score(clip_s, pick_s)
            if s > best_score:
                best_score, best_slot = s, int(sl)

        route = self.policy.route(best_score) if best_slot >= 0 else Route.TXT2IMG
        steps = self.policy.steps_for(route)

        if route is Route.HIT_RETURN:
            db.mark_access(np.array([best_slot]), self.clock)
            img = self.blob_store.get(int(db.payload_ids[best_slot]))
        elif route is Route.IMG2IMG:
            db.mark_access(np.array([best_slot]), self.clock)
            ref = self.blob_store.get(int(db.payload_ids[best_slot]))
            img = self.backend.img2img(prompt, ref, steps, seed)
            self._archive(raw_prompt, pvec, img, node)
        else:
            img = self.backend.txt2img(prompt, steps, seed)
            self._archive(raw_prompt, pvec, img, node)

        self.scheduler.complete(node)
        if self.stats.requests % self.maintenance_interval == self.maintenance_interval - 1:
            self.maintain()
        return self._finish(img, route, node, best_score, t_wall0, steps=steps)

    # ------------------------------------------------------- batched serve

    def serve_batch(self, prompts: Sequence[str], *,
                    seeds: Optional[Sequence[int]] = None,
                    quality_tiers: Optional[Sequence[bool]] = None,
                    ) -> List[ServeResult]:
        """Serve a micro-batch of requests through one pass of the stack.

        Amortisation vs. the sequential loop:

        * ONE ``embed_text`` call for every prompt in the batch;
        * ONE ``RequestScheduler.schedule_batch`` (single history matmul,
          single node-representation similarity);
        * ONE ``VectorDB.search_batch`` per node touched by the batch;
        * denoiser calls grouped by (node, workflow, steps) and executed
          as single padded batched backend calls when the backend exposes
          ``txt2img_batch`` / ``img2img_batch``.

        Semantics: scheduling and retrieval see the cache state at batch
        entry (snapshot), and archives land after generation.  Requests
        whose prompt near-duplicates an earlier in-batch request that will
        archive are coalesced onto that request's result — exactly the
        history fast path the sequential loop takes once the earlier
        result is recorded.  A batched drain therefore matches the
        sequential loop whenever distinct in-batch prompts do not interact
        through freshly archived images (the parity tests pin this on a
        fixed Zipf trace).  Results come back in submission order.
        """
        n = len(prompts)
        if n == 0:
            return []
        t_wall0 = time.perf_counter()
        seeds = list(seeds) if seeds is not None else [0] * n
        tiers = list(quality_tiers) if quality_tiers is not None else [False] * n
        clocks = [self.clock + i + 1 for i in range(n)]
        self.clock += n
        raw = [str(p) for p in prompts]
        opt = ([self.prompt_optimizer.optimize(p) for p in raw]
               if self.use_prompt_optimizer else raw)
        pvecs = self.embedder.embed_text(raw)          # one batched call
        qn = l2n(pvecs)
        pkeys = [stable_hash(p, 1 << 62) for p in raw]

        if self.use_scheduler:
            decisions = self.scheduler.schedule_batch(
                pvecs, self.dbs, quality_tiers=tiers, prompt_keys=pkeys)
        else:
            decisions = [ScheduleDecision(node=int(c) % len(self.dbs))
                         for c in clocks]

        # one batched VDB scan per node touched by normal-path requests
        by_node: Dict[int, List[int]] = {}
        for i, d in enumerate(decisions):
            if d.fast_path is None:
                by_node.setdefault(d.node, []).append(i)
        retrieved: Dict[int, tuple] = {}
        for node, idxs in by_node.items():
            rows = self.dbs[node].search_batch(pvecs[idxs], self.topk)
            for i, r in zip(idxs, rows):
                retrieved[i] = r

        # in-order planning: route each request, coalescing near-duplicates
        # of in-flight (will-archive) batch members onto one generation
        plans: List[dict] = [None] * n  # type: ignore[list-item]
        pending_vecs: List[np.ndarray] = []
        pending_req: List[int] = []
        for i in range(n):
            d = decisions[i]
            pend_sim, pend_j = -np.inf, -1
            if pending_vecs:
                sims = np.stack(pending_vecs) @ qn[i]
                pj = int(np.argmax(sims))
                pend_sim, pend_j = float(sims[pj]), pending_req[pj]
            if d.fast_path == "history":
                if pend_sim > d.match_score:  # later history entry wins argmax
                    plans[i] = {"kind": "alias", "target": pend_j}
                else:
                    plans[i] = {"kind": "history",
                                "image": self.blob_store.get(d.history_payload)}
                continue
            if self.use_scheduler and pend_sim >= self.scheduler.dedup_threshold:
                # sequential serve would history-hit the in-flight record
                self.scheduler.count_history_hit()
                self.scheduler.uncount_prompt(pkeys[i])
                plans[i] = {"kind": "alias", "target": pend_j}
                continue
            node = d.node
            if d.fast_path == "priority":
                plans[i] = {"kind": "gen", "node": node, "route": Route.TXT2IMG,
                            "steps": self.policy.steps_full, "fast": "priority",
                            "score": 0.0, "ref": None}
                pending_vecs.append(qn[i])
                pending_req.append(i)
                continue
            db = self.dbs[node]
            scores, slots = retrieved[i]
            best_slot, best_score = -1, -1.0
            for sc, sl in zip(scores, slots):
                ivec = db.img_vecs[sl]
                clip_s = self.embedder.clip_score(pvecs[i], ivec)
                pick_s = self.embedder.pick_score(pvecs[i], ivec)
                s = self.policy.composite_score(clip_s, pick_s)
                if s > best_score:
                    best_score, best_slot = s, int(sl)
            route = (self.policy.route(best_score) if best_slot >= 0
                     else Route.TXT2IMG)
            steps = self.policy.steps_for(route)
            if route is Route.HIT_RETURN:
                db.mark_access(np.array([best_slot]), clocks[i])
                plans[i] = {"kind": "cached", "node": node, "score": best_score,
                            "image": self.blob_store.get(
                                int(db.payload_ids[best_slot]))}
            elif route is Route.IMG2IMG:
                db.mark_access(np.array([best_slot]), clocks[i])
                plans[i] = {"kind": "gen", "node": node, "route": route,
                            "steps": steps, "fast": None, "score": best_score,
                            "ref": self.blob_store.get(
                                int(db.payload_ids[best_slot]))}
                pending_vecs.append(qn[i])
                pending_req.append(i)
            else:
                plans[i] = {"kind": "gen", "node": node, "route": route,
                            "steps": steps, "fast": None, "score": best_score,
                            "ref": None}
                pending_vecs.append(qn[i])
                pending_req.append(i)

        # grouped generation: one padded backend call per (node, kind, steps)
        images: Dict[int, np.ndarray] = {}
        txt_groups: Dict[tuple, List[int]] = {}
        img_groups: Dict[tuple, List[int]] = {}
        for i in range(n):
            p = plans[i]
            if p["kind"] != "gen":
                continue
            grp = img_groups if p["ref"] is not None else txt_groups
            grp.setdefault((p["node"], p["steps"]), []).append(i)
        for (node, steps), idxs in txt_groups.items():
            g_prompts = [opt[i] for i in idxs]
            g_seeds = [seeds[i] for i in idxs]
            if self.backend.txt2img_batch is not None:
                out = np.asarray(self.backend.txt2img_batch(
                    g_prompts, steps, g_seeds))
                for j, i in enumerate(idxs):
                    images[i] = np.asarray(out[j])
            else:
                for i in idxs:
                    images[i] = self.backend.txt2img(opt[i], steps, seeds[i])
        for (node, steps), idxs in img_groups.items():
            refs = np.stack([plans[i]["ref"] for i in idxs])
            if self.backend.img2img_batch is not None:
                out = np.asarray(self.backend.img2img_batch(
                    [opt[i] for i in idxs], refs, steps,
                    [seeds[i] for i in idxs]))
                for j, i in enumerate(idxs):
                    images[i] = np.asarray(out[j])
            else:
                for i in idxs:
                    images[i] = self.backend.img2img(
                        opt[i], plans[i]["ref"], steps, seeds[i])

        # archive in submission order (blob ids / history order match the
        # sequential loop exactly)
        for i in range(n):
            if plans[i]["kind"] == "gen":
                self._archive(raw[i], pvecs[i], images[i], plans[i]["node"],
                              t=clocks[i])

        # finish in submission order: stats, latency model, maintenance
        results: List[ServeResult] = []
        for i in range(n):
            p = plans[i]
            if p["kind"] == "alias":
                results.append(self._finish(
                    images[p["target"]], Route.HIT_RETURN, -1, 1.0, t_wall0,
                    steps=0, retrieved=False, fast="history"))
            elif p["kind"] == "history":
                results.append(self._finish(
                    p["image"], Route.HIT_RETURN, -1, 1.0, t_wall0,
                    steps=0, retrieved=False, fast="history"))
            elif p["kind"] == "gen" and p["fast"] == "priority":
                results.append(self._finish(
                    images[i], Route.TXT2IMG, p["node"], 0.0, t_wall0,
                    steps=p["steps"], retrieved=False, fast="priority"))
            else:
                if (self.stats.requests % self.maintenance_interval
                        == self.maintenance_interval - 1):
                    self.maintain()
                if p["kind"] == "cached":
                    results.append(self._finish(
                        p["image"], Route.HIT_RETURN, p["node"], p["score"],
                        t_wall0, steps=0))
                else:
                    results.append(self._finish(
                        images[i], p["route"], p["node"], p["score"],
                        t_wall0, steps=p["steps"]))
        return results

    # ------------------------------------------------------------- internals

    def _archive(self, prompt: str, pvec: np.ndarray, img: np.ndarray,
                 node: int, *, t: Optional[float] = None) -> None:
        """Store the generated image to NFS (blob store) + insert into VDB."""
        pid = self.blob_store.put(img)
        ivec = self.embedder.embed_image(img[None])[0]
        self.dbs[node].add(ivec[None], pvec[None], np.array([pid]),
                           self.clock if t is None else t)
        self.scheduler.record_result(pvec, pid)

    def _finish(self, img, route, node, score, t_wall0, *, steps, retrieved=True,
                fast=None) -> ServeResult:
        speed = (self.scheduler.nodes[node].speed if 0 <= node < len(self.dbs)
                 else max(n.speed for n in self.scheduler.nodes))
        lat = self.latency_model.latency(route, steps, node_speed=speed,
                                         scheduled=self.use_scheduler,
                                         retrieved=retrieved)
        gpu_s = steps * self.latency_model.t_step / max(speed, 1e-9)
        self.cost_model.charge(max(node, 0), gpu_s,
                               vdb_seconds=self.latency_model.t_retrieve if retrieved else 0.0)
        res = ServeResult(image=img, route=route, node=node, score=score,
                          latency=lat, wall_latency=time.perf_counter() - t_wall0,
                          steps=steps, fast_path=fast)
        self.stats.record(res)
        return res

    def maintain(self) -> Dict[int, np.ndarray]:
        """Run the eviction policy across all node VDBs (Algorithm 2)."""
        evicted = self.eviction.maintain(self.dbs, self.cache_capacity)
        all_payloads = []
        for _, payloads in evicted.items():
            for p in payloads:
                self.blob_store.delete(int(p))
                all_payloads.append(int(p))
        # keep the historical-query cache consistent with the blob store
        self.scheduler.invalidate_payloads(all_payloads)
        return evicted

    def fail_node(self, node: int) -> None:
        """Edge-node failure: reassign its VDB shard, stop routing to it."""
        self.scheduler.mark_failed(node)
        if self.classifier is not None:
            self.classifier.reassign_failed_node(self.dbs, node, self.clock)

    @property
    def total_size(self) -> int:
        return sum(db.size for db in self.dbs)
