"""Cache-maintenance policies (paper §IV-G, Algorithm 2).

LCU — Least Correlation Used — scores every cached vector by its euclidean
distance to the *current* semantic centre of its node's VDB and evicts the
farthest ("semantic outliers carry mixed concepts of limited reference
value").  LRU / LFU / FIFO are implemented on the same interface as the
paper's baselines (Fig. 19).

All policies operate across the fleet of node VDBs at once, exactly like
Algorithm 2: build one global list, sort by the policy key, pop until the
total size fits ``C_max``.

Per-depth utility (the latent-depth cache): noised-latent entries and
finished images compete under the SAME ``C_max``, but a deep latent is
cheap to store relative to the denoising steps it saves — so
``EvictionPolicy.maintain`` discounts every entry's eviction score by
``depth_weight · (depth / max_depth)`` of the policy's own score spread
(scale-free, so it composes with LCU distances, LFU counts and LRU/FIFO
clocks alike).  Finished images (depth -1) are untouched; with no latent
entries in the fleet the scores are bit-identical to the undepthed sort.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.vdb import VectorDB


class EvictionPolicy:
    name = "base"

    # eviction-score discount per unit of normalised resume depth: deep
    # latents save the most denoising steps per cached row, so they are
    # protected proportionally (0 disables per-depth utility entirely)
    depth_weight: float = 0.25

    def scores(self, db: VectorDB) -> np.ndarray:
        """Higher score = evicted earlier. Only valid slots are consulted."""
        raise NotImplementedError

    def depth_scores(self, db: VectorDB, depth_norm: int) -> np.ndarray:
        """Policy scores with the per-depth utility discount applied.

        The discount is ``depth_weight · (depth / depth_norm) · spread``
        where ``spread`` is the policy's own valid-score range on this db
        (1.0 when all scores tie, so depth still breaks ties) — scale-free
        across policies.  Finished images (depth < 0) and fleets with no
        latent entries (``depth_norm <= 0``) get the raw scores."""
        s = self.scores(db)
        if depth_norm <= 0 or self.depth_weight <= 0.0:
            return s
        finite = db.valid & np.isfinite(s)
        if not finite.any():
            return s
        spread = float(s[finite].max() - s[finite].min()) or 1.0
        frac = np.where(db.depth > 0, db.depth / float(depth_norm), 0.0)
        return np.where(finite, s - self.depth_weight * spread * frac, s)

    def maintain(self, dbs: Sequence[VectorDB], c_max: int,
                 ) -> Dict[int, np.ndarray]:
        """Algorithm 2: evict across all nodes until total size <= c_max.

        Returns {node_index: evicted payload ids}.
        """
        depth_norm = max((int(db.depth[db.valid].max(initial=-1))
                          for db in dbs), default=-1)
        entries: List[Tuple[float, int, int]] = []  # (score, node, slot)
        total = 0
        for ni, db in enumerate(dbs):
            total += db.size
            s = self.depth_scores(db, depth_norm)
            for slot in np.flatnonzero(db.valid):
                entries.append((float(s[slot]), ni, int(slot)))
        if total <= c_max:
            return {}
        entries.sort(key=lambda e: e[0], reverse=True)  # farthest first
        n_evict = total - c_max
        doomed: Dict[int, List[int]] = {}
        for score, ni, slot in entries[:n_evict]:
            doomed.setdefault(ni, []).append(slot)
        # one evict_slots call per node (one device validity update per
        # node when the db is a ClusterIndex view, not one per slot)
        return {ni: dbs[ni].evict_slots(np.array(slots, np.int64))
                          .astype(np.int64)
                for ni, slots in doomed.items()}


class LCUPolicy(EvictionPolicy):
    """Least Correlation Used: distance-to-centroid outlier eviction."""

    name = "LCU"

    def scores(self, db: VectorDB) -> np.ndarray:
        mu = db.centroid()
        d = np.linalg.norm(db.img_vecs - mu[None, :], axis=-1)
        return np.where(db.valid, d, -np.inf)


class LRUPolicy(EvictionPolicy):
    name = "LRU"

    def scores(self, db: VectorDB) -> np.ndarray:
        # least-recently-used = oldest last_access evicted first
        return np.where(db.valid, -db.last_access, -np.inf)


class LFUPolicy(EvictionPolicy):
    name = "LFU"

    def scores(self, db: VectorDB) -> np.ndarray:
        # equal-count ties break toward evicting the OLDER insert: counts
        # are integers >= 1 apart, and the bounded recency term lives in
        # [0, 0.5), so it reorders ties without ever flipping a count
        # ordering (newest rows no longer lose a tie to stale ones)
        t = np.maximum(db.insert_time, 0.0)
        recency = 0.5 * t / (1.0 + t)
        return np.where(db.valid,
                        -db.access_count.astype(np.float64) - recency,
                        -np.inf)


class FIFOPolicy(EvictionPolicy):
    name = "FIFO"

    def scores(self, db: VectorDB) -> np.ndarray:
        return np.where(db.valid, -db.insert_time, -np.inf)


POLICIES = {p.name: p for p in (LCUPolicy(), LRUPolicy(), LFUPolicy(), FIFOPolicy())}
