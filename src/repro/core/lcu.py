"""Cache-maintenance policies (paper §IV-G, Algorithm 2).

LCU — Least Correlation Used — scores every cached vector by its euclidean
distance to the *current* semantic centre of its node's VDB and evicts the
farthest ("semantic outliers carry mixed concepts of limited reference
value").  LRU / LFU / FIFO are implemented on the same interface as the
paper's baselines (Fig. 19).

All policies operate across the fleet of node VDBs at once, exactly like
Algorithm 2: build one global list, sort by the policy key, pop until the
total size fits ``C_max``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.vdb import VectorDB


class EvictionPolicy:
    name = "base"

    def scores(self, db: VectorDB) -> np.ndarray:
        """Higher score = evicted earlier. Only valid slots are consulted."""
        raise NotImplementedError

    def maintain(self, dbs: Sequence[VectorDB], c_max: int,
                 ) -> Dict[int, np.ndarray]:
        """Algorithm 2: evict across all nodes until total size <= c_max.

        Returns {node_index: evicted payload ids}.
        """
        entries: List[Tuple[float, int, int]] = []  # (score, node, slot)
        total = 0
        for ni, db in enumerate(dbs):
            total += db.size
            s = self.scores(db)
            for slot in np.flatnonzero(db.valid):
                entries.append((float(s[slot]), ni, int(slot)))
        if total <= c_max:
            return {}
        entries.sort(key=lambda e: e[0], reverse=True)  # farthest first
        n_evict = total - c_max
        doomed: Dict[int, List[int]] = {}
        for score, ni, slot in entries[:n_evict]:
            doomed.setdefault(ni, []).append(slot)
        # one evict_slots call per node (one device validity update per
        # node when the db is a ClusterIndex view, not one per slot)
        return {ni: dbs[ni].evict_slots(np.array(slots, np.int64))
                          .astype(np.int64)
                for ni, slots in doomed.items()}


class LCUPolicy(EvictionPolicy):
    """Least Correlation Used: distance-to-centroid outlier eviction."""

    name = "LCU"

    def scores(self, db: VectorDB) -> np.ndarray:
        mu = db.centroid()
        d = np.linalg.norm(db.img_vecs - mu[None, :], axis=-1)
        return np.where(db.valid, d, -np.inf)


class LRUPolicy(EvictionPolicy):
    name = "LRU"

    def scores(self, db: VectorDB) -> np.ndarray:
        # least-recently-used = oldest last_access evicted first
        return np.where(db.valid, -db.last_access, -np.inf)


class LFUPolicy(EvictionPolicy):
    name = "LFU"

    def scores(self, db: VectorDB) -> np.ndarray:
        return np.where(db.valid, -db.access_count.astype(np.float64), -np.inf)


class FIFOPolicy(EvictionPolicy):
    name = "FIFO"

    def scores(self, db: VectorDB) -> np.ndarray:
        return np.where(db.valid, -db.insert_time, -np.inf)


POLICIES = {p.name: p for p in (LCUPolicy(), LRUPolicy(), LFUPolicy(), FIFOPolicy())}
