"""Latency (Eq. 8) and $ cost models.

    L_i = t_retrieve + x_i * t_return + y_i * (t_noise + K * t_step)
                     + z_i * N * t_step

with exactly one of x, y, z set per request (direct return / img2img /
txt2img).  ``t_step`` is per-node (heterogeneous GPUs in the paper; on TPU
we derive it from the roofline terms of the compiled denoise step).

The cost model mirrors the paper's AutoDL accounting: GPU-hours at per-node
rates + a flat VDB rate, aggregated over a task stream (Fig. 17).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

from repro.core.policy import Route


@dataclass
class LatencyModel:
    t_retrieve: float = 0.050   # VDB query
    t_return: float = 0.020     # ship cached image to the user
    t_noise: float = 0.005      # SDEdit forward noising (Eq. 4)
    t_step: float = 0.060       # per denoising step (node-speed scaled)
    t_schedule: float = 0.002   # Eq. 6 node matching
    t_embed: float = 0.008      # CLIP encode of the prompt

    def latency(self, route: Route, steps: int, *, node_speed: float = 1.0,
                scheduled: bool = True, retrieved: bool = True) -> float:
        t = self.t_embed + (self.t_schedule if scheduled else 0.0)
        t += self.t_retrieve if retrieved else 0.0
        step = self.t_step / max(node_speed, 1e-9)
        if route is Route.HIT_RETURN:
            return t + self.t_return
        if route is Route.IMG2IMG:
            return t + self.t_noise + steps * step
        return t + steps * step

    @classmethod
    def from_roofline(cls, step_seconds: float, *, retrieve_seconds: float = 0.01,
                      ) -> "LatencyModel":
        """Build a TPU latency model from the dry-run's per-step roofline time."""
        return cls(t_retrieve=retrieve_seconds, t_step=step_seconds,
                   t_noise=step_seconds * 0.05, t_return=0.005)


@dataclass
class CostModel:
    """Per-hour rates (paper's AutoDL numbers, $/h)."""

    gpu_rates: Sequence[float] = (0.28, 0.28, 0.23, 0.084)  # 4090D, 4090D, 3090, 2070S
    vdb_rate: float = 0.12
    accumulated_gpu_s: Dict[int, float] = field(default_factory=dict)
    vdb_busy_s: float = 0.0

    def charge(self, node: int, gpu_seconds: float, vdb_seconds: float = 0.0) -> None:
        self.accumulated_gpu_s[node] = self.accumulated_gpu_s.get(node, 0.0) + gpu_seconds
        self.vdb_busy_s += vdb_seconds

    def total_cost(self, *, vdb_wall_s: Optional[float] = None) -> float:
        gpu = sum(self.gpu_rates[n % len(self.gpu_rates)] * s / 3600.0
                  for n, s in self.accumulated_gpu_s.items())
        vdb_s = self.vdb_busy_s if vdb_wall_s is None else vdb_wall_s
        return gpu + self.vdb_rate * vdb_s / 3600.0
