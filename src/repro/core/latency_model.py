"""Latency (Eq. 8) and $ cost models.

    L_i = t_retrieve + x_i * t_return + y_i * (t_noise + K * t_step)
                     + z_i * N * t_step

with exactly one of x, y, z set per request (direct return / img2img /
txt2img).  ``t_step`` is per-node (heterogeneous GPUs in the paper; on TPU
we derive it from the roofline terms of the compiled denoise step).

Per-depth extension (the latent-depth cache): an img2img request resumed
from an archived depth-k latent replaces ``t_noise`` (the latent is
pre-noised at archive time) with ``t_latent`` (fetching the latent blob)
and runs only the remaining chain:

    L_k = t_retrieve + t_latent + (K - k) * t_step

so ``latency(Route.IMG2IMG, K - k, resumed=True)`` prices depth k.

The cost model mirrors the paper's AutoDL accounting: GPU-hours at per-node
rates + a flat VDB rate, aggregated over a task stream (Fig. 17).  Fleets
larger than the rate vector must pass explicit per-node rates — only the
paper's default 4-node AutoDL vector recycles by modulo.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

from repro.core.policy import Route


@dataclass
class LatencyModel:
    t_retrieve: float = 0.050   # VDB query
    t_return: float = 0.020     # ship cached image to the user
    t_noise: float = 0.005      # SDEdit forward noising (Eq. 4)
    t_latent: float = 0.015     # fetch an archived depth-k latent blob
    t_step: float = 0.060       # per denoising step (node-speed scaled)
    t_schedule: float = 0.002   # Eq. 6 node matching
    t_embed: float = 0.008      # CLIP encode of the prompt

    def latency(self, route: Route, steps: int, *, node_speed: float = 1.0,
                scheduled: bool = True, retrieved: bool = True,
                resumed: bool = False) -> float:
        t = self.t_embed + (self.t_schedule if scheduled else 0.0)
        t += self.t_retrieve if retrieved else 0.0
        step = self.t_step / max(node_speed, 1e-9)
        if route is Route.HIT_RETURN:
            return t + self.t_return
        if route is Route.IMG2IMG:
            if resumed:
                # per-depth Eq. 8: the archived latent is already noised, so
                # t_noise is replaced by the latent fetch and only the
                # remaining K - k steps run (callers pass steps = K - k)
                return t + self.t_latent + steps * step
            return t + self.t_noise + steps * step
        return t + steps * step

    @classmethod
    def from_roofline(cls, step_seconds: float, *, retrieve_seconds: float = 0.01,
                      ) -> "LatencyModel":
        """Build a TPU latency model from the dry-run's per-step roofline time."""
        return cls(t_retrieve=retrieve_seconds, t_step=step_seconds,
                   t_noise=step_seconds * 0.05, t_return=0.005)


# the paper's 4-node AutoDL fleet — the ONLY rate vector that silently
# recycles by modulo for larger fleets (backwards compatibility with the
# paper's experiments; any custom vector must cover every node explicitly)
_DEFAULT_GPU_RATES = (0.28, 0.28, 0.23, 0.084)  # 4090D, 4090D, 3090, 2070S


@dataclass
class CostModel:
    """Per-hour rates (paper's AutoDL numbers, $/h)."""

    gpu_rates: Sequence[float] = _DEFAULT_GPU_RATES
    vdb_rate: float = 0.12
    accumulated_gpu_s: Dict[int, float] = field(default_factory=dict)
    vdb_busy_s: float = 0.0

    def _rate(self, node: int) -> float:
        rates = tuple(self.gpu_rates)
        if 0 <= node < len(rates):
            return rates[node]
        if rates == _DEFAULT_GPU_RATES:
            return rates[node % len(rates)]
        raise ValueError(
            f"node {node} has no rate in gpu_rates (len {len(rates)}); "
            "pass one rate per node for fleets larger than the paper's "
            "default 4-node AutoDL configuration")

    def charge(self, node: int, gpu_seconds: float, vdb_seconds: float = 0.0) -> None:
        self._rate(node)  # validate eagerly, not at total_cost time
        self.accumulated_gpu_s[node] = self.accumulated_gpu_s.get(node, 0.0) + gpu_seconds
        self.vdb_busy_s += vdb_seconds

    def total_cost(self, *, vdb_wall_s: Optional[float] = None) -> float:
        gpu = sum(self._rate(n) * s / 3600.0
                  for n, s in self.accumulated_gpu_s.items())
        vdb_s = self.vdb_busy_s if vdb_wall_s is None else vdb_wall_s
        return gpu + self.vdb_rate * vdb_s / 3600.0
