"""In-framework vector database (paper's pgvector equivalent, §IV-C/§IV-F).

One ``VectorDB`` instance per edge node.  Each entry carries BOTH the image
embedding and the caption/text embedding (the paper's dual ANN retrieval,
Algorithm 1 lines 2-3), plus the bookkeeping the eviction policies need
(insert time, access counts, last access).

Storage layout is a fixed-capacity slab of numpy arrays with a validity
mask — the HOST source of truth for snapshot/restore, eviction and the
storage classifier.  Search is device-side: a standalone db runs a jitted
masked matmul + top-k (or the Pallas ``vdb_topk`` kernel with
``use_pallas=True``); a db registered with a
:class:`repro.core.cluster_index.ClusterIndex` is a per-node VIEW over
the cluster's device-resident stacked slabs — every ``add``/``evict``
pushes an incremental row update, and ``search``/``search_batch``
delegate to the fused cross-node scan (no per-call host→device slab
copies).  Semantics are identical either way, pinned by parity tests
against the jnp oracle here.

``payload_ids`` are opaque ints pointing into a :class:`BlobStore` (the
paper's NFS layer).
"""
from __future__ import annotations

import zlib
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import next_pow2

# The Pallas kernel uses a large-negative sentinel instead of -inf; treat
# anything at or below it as "masked" when unioning candidate sets.
_SCORE_FLOOR = -1e29


class BlobStore:
    """The shared image store (paper: 500GB NFS PersistentVolume).

    Every ``put`` records the blob's CRC32 so hits can be verified before
    a cached/reference image is ever conditioned on (``verify`` — the
    Plan stage's verify-on-hit path; see ``repro.core.pipeline``).
    ``corrupt`` is the deterministic chaos surface: it perturbs the
    stored pixels WITHOUT refreshing the checksum, modelling silent NFS
    bit-rot that only a verify-on-hit can catch."""

    def __init__(self):
        self._blobs: Dict[int, np.ndarray] = {}
        self._sums: Dict[int, int] = {}
        self._next = 0

    def put(self, blob: np.ndarray) -> int:
        bid = self._next
        self._next += 1
        blob = np.asarray(blob)
        self._blobs[bid] = blob
        self._sums[bid] = zlib.crc32(blob.tobytes())
        return bid

    def get(self, bid: int) -> np.ndarray:
        return self._blobs[bid]

    def delete(self, bid: int) -> None:
        self._blobs.pop(bid, None)
        self._sums.pop(bid, None)

    def verify(self, bid: int) -> bool:
        """True iff the blob exists and its bytes still match the
        checksum recorded at ``put`` time."""
        blob = self._blobs.get(bid)
        if blob is None:
            return False
        return zlib.crc32(blob.tobytes()) == self._sums.get(bid)

    def corrupt(self, bid: int, rng: Optional[np.random.Generator] = None,
                ) -> None:
        """Deterministically damage a stored blob in place (chaos/test
        surface): a seeded perturbation of its pixels, leaving the
        recorded checksum stale so ``verify`` fails."""
        blob = self._blobs.get(bid)
        if blob is None:
            return
        rng = rng or np.random.default_rng(bid)
        noisy = np.asarray(blob, np.float32).copy()
        flat = noisy.reshape(-1)
        idx = rng.integers(0, flat.size, size=max(1, flat.size // 16))
        flat[idx] += rng.standard_normal(len(idx)).astype(np.float32) * 8.0
        self._blobs[bid] = noisy.reshape(np.shape(blob))

    def __len__(self) -> int:
        return len(self._blobs)

    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._blobs.values())


@partial(jax.jit, static_argnames=("k",))
def _masked_topk(query, db, valid, k: int):
    """Cosine top-k of `query` (d,) against `db` (cap, d) under mask."""
    scores = db @ query  # vectors are L2-normalised at insert
    scores = jnp.where(valid, scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


@partial(jax.jit, static_argnames=("k",))
def _masked_topk_batch(queries, db, valid, k: int):
    scores = queries @ db.T
    scores = jnp.where(valid[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


def _l2n(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def _union_topk(score_rows: Sequence[np.ndarray],
                slot_rows: Sequence[np.ndarray],
                ) -> Tuple[np.ndarray, np.ndarray]:
    """De-duplicate the union of per-index top-k rows, keeping the best
    score per slot and dropping masked candidates (±inf or the Pallas
    large-negative sentinel).

    Fully vectorised (no per-candidate Python loop): one lexsort groups
    candidates by slot with scores descending, so the first entry of each
    group IS the best score for that slot (ties keep the earliest row —
    the img index before txt — matching the old strict ``>`` dict
    update); a final stable sort restores descending-score order with
    slot-ascending tie-break.
    """
    if score_rows:
        scores = np.concatenate(
            [np.asarray(s, np.float32).ravel() for s in score_rows])
        slots = np.concatenate(
            [np.asarray(s).ravel() for s in slot_rows]).astype(np.int64)
    else:
        scores = np.empty((0,), np.float32)
        slots = np.empty((0,), np.int64)
    keep = np.isfinite(scores) & (scores > _SCORE_FLOOR)
    scores, slots = scores[keep], slots[keep]
    if scores.size == 0:
        return np.empty((0,), np.float32), np.empty((0,), np.int64)
    order = np.lexsort((-scores, slots))        # slot asc, score desc, stable
    slots_s, scores_s = slots[order], scores[order]
    first = np.ones(len(slots_s), bool)
    first[1:] = slots_s[1:] != slots_s[:-1]     # best entry per slot
    slots_u, scores_u = slots_s[first], scores_s[first]
    out = np.argsort(-scores_u, kind="stable")  # desc; ties -> slot asc
    return scores_u[out], slots_u[out]


class VectorDB:
    """Fixed-capacity dual-index vector DB for one edge node."""

    def __init__(self, dim: int, capacity: int, *, name: str = "node",
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None):
        self.dim = dim
        self.capacity = capacity
        self.name = name
        self.use_pallas = use_pallas
        # None = backend-aware (compile on TPU, interpret elsewhere);
        # threaded through to the Pallas kernels and the ClusterIndex
        self.interpret = interpret
        self.img_vecs = np.zeros((capacity, dim), np.float32)
        self.txt_vecs = np.zeros((capacity, dim), np.float32)
        self.valid = np.zeros((capacity,), bool)
        self.insert_time = np.full((capacity,), -1.0, np.float64)
        self.last_access = np.full((capacity,), -1.0, np.float64)
        self.access_count = np.zeros((capacity,), np.int64)
        self.payload_ids = np.full((capacity,), -1, np.int64)
        # latent-depth cache metadata (host-side slab columns; the fused
        # device scans never consume them, so scans stay one-launch):
        # ``depth`` = resume depth of a noised-latent entry, -1 for a
        # finished image; ``source_id`` groups every entry archived from
        # one generation (the finished image's payload id)
        self.depth = np.full((capacity,), -1, np.int64)
        self.source_id = np.full((capacity,), -1, np.int64)
        self.query_count = 0
        # running centroid (sum of valid img vectors + count), maintained
        # on every mutation so centroid() is O(dim), not O(capacity*dim)
        self._cent_sum = np.zeros((dim,), np.float64)
        self._cent_count = 0
        # ClusterIndex views over this node's slab (usually 0 or 1)
        self._clusters: List[Tuple[object, int]] = []
        # durability journal (repro.core.journal) — every mutation below
        # records its RAW arguments before the slab changes
        self._journal = None

    # -- durability journal -------------------------------------------------

    def attach_journal(self, journal) -> None:
        """Attach a :class:`repro.core.journal.CacheJournal`: every
        ``add`` / ``evict_slots`` / ``mark_access`` appends one WAL
        record (raw call arguments) BEFORE mutating the slab, so a crash
        at any instant replays to exactly the pre-crash state."""
        self._journal = journal
        journal.bind(self)

    def detach_journal(self):
        j, self._journal = self._journal, None
        return j

    # -- cluster registration ----------------------------------------------

    def register_cluster(self, cluster, node: int) -> None:
        """Attach a ClusterIndex view; future mutations push incremental
        device row updates, and searches delegate to the fused scan.
        EVERY registered cluster receives updates (two systems sharing a
        fleet each keep their own index in sync — including a sharded
        and an unsharded index side by side, as the parity tests do; on
        a mesh-sharded index the donated scatter routes each row to the
        node's owning shard); drop indexes you are done with via
        :meth:`unregister_cluster` or they stay live."""
        self._clusters = [(c, n) for c, n in self._clusters
                          if c is not cluster] + [(cluster, node)]

    def unregister_cluster(self, cluster) -> None:
        self._clusters = [(c, n) for c, n in self._clusters
                          if c is not cluster]

    def _cluster_update(self, slots: np.ndarray) -> None:
        for cluster, node in self._clusters:
            cluster.update_rows(node, slots, self.img_vecs[slots],
                                self.txt_vecs[slots])

    def _cluster_invalidate(self, slots: np.ndarray) -> None:
        for cluster, node in self._clusters:
            cluster.invalidate_rows(node, slots)

    # -- mutation ----------------------------------------------------------

    def add(self, img_vecs: np.ndarray, txt_vecs: np.ndarray,
            payload_ids: np.ndarray, t: float, *,
            depths: Optional[np.ndarray] = None,
            source_ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert a batch; overwrite oldest entries if full (FIFO pressure
        valve — the real policy runs via :mod:`repro.core.lcu`).

        ``depths``/``source_ids`` carry the latent-depth cache metadata:
        depth -1 (the default) marks a finished image, k >= 0 a noised
        latent resumable at chain depth k; ``source_ids`` defaults to
        ``payload_ids`` (every finished image is its own source)."""
        if self._journal is not None:   # WAL: raw args, before mutation
            self._journal.record_add(img_vecs, txt_vecs, payload_ids, t,
                                     depths, source_ids)
        img_vecs = _l2n(np.atleast_2d(np.asarray(img_vecs, np.float32)))
        txt_vecs = _l2n(np.atleast_2d(np.asarray(txt_vecs, np.float32)))
        payload_ids = np.atleast_1d(np.asarray(payload_ids, np.int64))
        depths = (np.full(payload_ids.shape, -1, np.int64) if depths is None
                  else np.atleast_1d(np.asarray(depths, np.int64)))
        source_ids = (payload_ids if source_ids is None
                      else np.atleast_1d(np.asarray(source_ids, np.int64)))
        n = img_vecs.shape[0]
        if n > self.capacity:    # oversized insert: only the NEWEST
            drop = n - self.capacity         # capacity rows land (FIFO)
            img_vecs = img_vecs[drop:]
            txt_vecs = txt_vecs[drop:]
            payload_ids = payload_ids[drop:]
            depths = depths[drop:]
            source_ids = source_ids[drop:]
            n = self.capacity
        free = np.flatnonzero(~self.valid)
        if len(free) < n:  # overwrite the oldest VALID entries only
            valid_slots = np.flatnonzero(self.valid)
            oldest = valid_slots[np.argsort(self.insert_time[valid_slots])]
            free = np.concatenate([free, oldest[: n - len(free)]])
        slots = free[:n]     # free ∪ oldest-valid are disjoint: no dupes
        # running centroid: overwritten live rows leave, new rows enter
        live = slots[self.valid[slots]]
        if len(live):
            self._cent_sum -= self.img_vecs[live].sum(axis=0)
            self._cent_count -= len(live)
        self.img_vecs[slots] = img_vecs
        self.txt_vecs[slots] = txt_vecs
        self.valid[slots] = True
        self.insert_time[slots] = t
        self.last_access[slots] = t
        # fresh entries start at 1, not 0: insertion IS one use.  At 0 a
        # just-inserted row tied as most-evictable under LFU, so a sweep
        # right after insertion evicted the newest rows first and the
        # cache could never learn.
        self.access_count[slots] = 1
        self.payload_ids[slots] = payload_ids
        self.depth[slots] = depths
        self.source_id[slots] = source_ids
        self._cent_sum += self.img_vecs[slots].sum(axis=0)
        self._cent_count += len(slots)
        self._cluster_update(slots)
        return slots

    def evict_slots(self, slots: np.ndarray) -> np.ndarray:
        """Invalidate slots; returns the payload ids to delete from the blob
        store (the paper synchronously removes image files for consistency)."""
        if self._journal is not None:
            self._journal.record_evict(slots)
        slots = np.atleast_1d(np.asarray(slots))
        payloads = self.payload_ids[slots].copy()
        uniq = np.unique(slots)
        live = uniq[self.valid[uniq]]
        if len(live):
            self._cent_sum -= self.img_vecs[live].sum(axis=0)
            self._cent_count -= len(live)
        self.valid[slots] = False
        self.payload_ids[slots] = -1
        self.depth[slots] = -1
        self.source_id[slots] = -1
        self._cluster_invalidate(uniq)
        return payloads

    def mark_access(self, slots: np.ndarray, t: float) -> None:
        if self._journal is not None:
            self._journal.record_access(slots, t)
        slots = np.atleast_1d(np.asarray(slots))
        self.access_count[slots] += 1
        self.last_access[slots] = t

    # -- search ------------------------------------------------------------

    def search(self, query_vec: np.ndarray, k: int,
               *, index: str = "both") -> Tuple[np.ndarray, np.ndarray]:
        """Dual ANN retrieval (Algorithm 1 lines 2-4).

        Returns (scores, slots) of up to 2k unioned candidates (or k when a
        single index is selected); invalid slots get score=-inf.
        """
        self.query_count += 1
        q = _l2n(np.asarray(query_vec, np.float32).reshape(-1))
        k = min(k, self.capacity)
        if self._clusters:
            # cluster view: the slab is device-resident — fused masked
            # scan instead of re-uploading numpy arrays
            cluster, node = self._clusters[-1]
            return cluster.search_batch(q[None], [node], k, index=index,
                                        count_queries=False)[0]
        if self.use_pallas:
            from repro.kernels.vdb_topk import vdb_topk as kernel_topk
            searcher = lambda db: kernel_topk(  # noqa: E731
                jnp.asarray(q)[None], jnp.asarray(db), jnp.asarray(self.valid),
                k, interpret=self.interpret)
            out = []
            if index in ("img", "both"):
                s, i = searcher(self.img_vecs)
                out.append((np.asarray(s)[0], np.asarray(i)[0]))
            if index in ("txt", "both"):
                s, i = searcher(self.txt_vecs)
                out.append((np.asarray(s)[0], np.asarray(i)[0]))
        else:
            out = []
            if index in ("img", "both"):
                s, i = _masked_topk(jnp.asarray(q), jnp.asarray(self.img_vecs),
                                    jnp.asarray(self.valid), k)
                out.append((np.asarray(s), np.asarray(i)))
            if index in ("txt", "both"):
                s, i = _masked_topk(jnp.asarray(q), jnp.asarray(self.txt_vecs),
                                    jnp.asarray(self.valid), k)
                out.append((np.asarray(s), np.asarray(i)))
        return _union_topk([o[0] for o in out], [o[1] for o in out])

    def search_batch(self, query_vecs: np.ndarray, k: int,
                     *, index: str = "both",
                     ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Multi-query dual ANN retrieval — one device scan for the whole
        micro-batch.

        When this db is a ClusterIndex view the scan runs against the
        device-resident stacked slab (no host→device copies).  Standalone,
        the jnp oracle routes through :func:`_masked_topk_batch` (a single
        (Q, cap) masked matmul + top-k); the Pallas path feeds the full
        (Q, D) query block to ``repro.kernels.vdb_topk.vdb_topk``, whose
        grid already streams the database once for all queries.

        Returns one ``(scores, slots)`` pair per query, each identical in
        meaning to :meth:`search` (deduped union across indexes, invalid
        slots dropped, scores descending).
        """
        Q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        b = Q.shape[0]
        self.query_count += b
        if b == 0:
            return []
        if self._clusters:
            cluster, node = self._clusters[-1]
            return cluster.search_batch(Q, [node] * b, min(k, self.capacity),
                                        index=index, count_queries=False)
        Qn = _l2n(Q)
        # pad the query block to a power-of-two bucket: micro-batch sizes
        # vary per node per drain, and an unpadded (Q, D) shape would
        # re-trace/compile the scan for every distinct Q
        bucket = next_pow2(b)
        if bucket != b:
            Qn = np.concatenate(
                [Qn, np.zeros((bucket - b, Qn.shape[1]), np.float32)])
        k = min(k, self.capacity)
        indexes = []
        if index in ("img", "both"):
            indexes.append(self.img_vecs)
        if index in ("txt", "both"):
            indexes.append(self.txt_vecs)
        per_index = []
        if self.use_pallas:
            from repro.kernels.vdb_topk import vdb_topk as kernel_topk
            for vecs in indexes:
                s, i = kernel_topk(jnp.asarray(Qn), jnp.asarray(vecs),
                                   jnp.asarray(self.valid), k,
                                   interpret=self.interpret)
                per_index.append((np.asarray(s), np.asarray(i)))
        else:
            for vecs in indexes:
                s, i = _masked_topk_batch(jnp.asarray(Qn), jnp.asarray(vecs),
                                          jnp.asarray(self.valid), k)
                per_index.append((np.asarray(s), np.asarray(i)))
        return [_union_topk([s[row] for s, _ in per_index],
                            [i[row] for _, i in per_index])
                for row in range(b)]

    # -- stats -------------------------------------------------------------

    @property
    def size(self) -> int:
        return int(self.valid.sum())

    def centroid(self) -> np.ndarray:
        """Node representation vector = mean of stored image vectors (§IV-E).

        O(dim): served from the running sum/count maintained on every
        ``add``/``evict_slots`` (float64 accumulation; recomputed — i.e.
        invalidated — on ``restore``), so ``schedule_batch`` no longer
        pays an O(capacity·dim) reduction per node per micro-batch."""
        if self._cent_count <= 0:
            return np.zeros((self.dim,), np.float32)
        return (self._cent_sum / self._cent_count).astype(np.float32)

    def _recompute_centroid(self) -> None:
        """Rebuild the running centroid from the slab (restore / any
        out-of-band mutation of ``img_vecs``/``valid``)."""
        self._cent_count = int(self.valid.sum())
        self._cent_sum = (self.img_vecs[self.valid].astype(np.float64)
                          .sum(axis=0) if self._cent_count
                          else np.zeros((self.dim,), np.float64))

    def snapshot(self) -> dict:
        """Serializable state (for checkpoint / node-failure recovery)."""
        return {
            "img_vecs": self.img_vecs.copy(), "txt_vecs": self.txt_vecs.copy(),
            "valid": self.valid.copy(), "insert_time": self.insert_time.copy(),
            "last_access": self.last_access.copy(),
            "access_count": self.access_count.copy(),
            "payload_ids": self.payload_ids.copy(),
            "depth": self.depth.copy(), "source_id": self.source_id.copy(),
        }

    @classmethod
    def restore(cls, dim: int, capacity: int, state: dict, **kw) -> "VectorDB":
        db = cls(dim, capacity, **kw)
        for k_, v in state.items():
            setattr(db, k_, v.copy())
        db._recompute_centroid()    # cache is invalid for the new slab
        return db
