"""The paper's primary contribution: CacheGenius.

Semantic-aware classified storage (K-means over CLIP embeddings → per-node
VDBs), request scheduling by prompt/node-centroid similarity, the hybrid
generation policy of Algorithm 1 (direct-return / image-to-image /
text-to-image by composite similarity score), and the LCU cache-maintenance
policy of Algorithm 2.
"""
from repro.core.kmeans import kmeans_fit, kmeans_assign  # noqa: F401
from repro.core.vdb import VectorDB  # noqa: F401
from repro.core.policy import GenerationPolicy, Route  # noqa: F401
from repro.core.lcu import (  # noqa: F401
    EvictionPolicy, LCUPolicy, LRUPolicy, LFUPolicy, FIFOPolicy,
)
from repro.core.scheduler import RequestScheduler  # noqa: F401
from repro.core.storage_classifier import StorageClassifier  # noqa: F401
from repro.core.latency_model import LatencyModel, CostModel  # noqa: F401
from repro.core.system import CacheGenius  # noqa: F401
