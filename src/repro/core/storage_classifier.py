"""Storage classifier (paper §IV-C): cluster the corpus, one cluster per node.

K-means over the corpus *image* embeddings (the paper clusters both
modalities, observes high cross-modal consistency — Fig. 6b — and picks the
image-vector clustering for placement); cluster i's vectors are inserted
into edge node i's VDB.  The classifier also owns the fitted centroids so
that (a) the request scheduler can route by centroid similarity and (b) a
failed node's shard can be reassigned to the nearest surviving centroid.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans_assign, kmeans_fit
from repro.core.vdb import VectorDB


class StorageClassifier:
    def __init__(self, n_nodes: int, *, iters: int = 25):
        self.n_nodes = n_nodes
        self.iters = iters
        self.centroids: Optional[np.ndarray] = None  # (n_nodes, d)
        # node index owning each centroid row — failures drop rows, so
        # after the first reassignment row i is NOT node i anymore
        self.centroid_nodes: List[int] = list(range(n_nodes))
        self.modal_consistency: Optional[float] = None

    def fit(self, img_vecs: np.ndarray, txt_vecs: Optional[np.ndarray] = None,
            ) -> np.ndarray:
        """Cluster image vectors into n_nodes clusters; returns assignment.

        If text vectors are given, also measures image/text cluster
        consistency (the paper's Fig. 6b argument for using image vectors).
        """
        state = kmeans_fit(jnp.asarray(img_vecs), k=self.n_nodes, iters=self.iters)
        self.centroids = np.asarray(state.centroids)
        self.centroid_nodes = list(range(self.n_nodes))
        assignment = np.asarray(state.assignment)
        if txt_vecs is not None:
            t_state = kmeans_fit(jnp.asarray(txt_vecs), k=self.n_nodes,
                                 iters=self.iters)
            self.modal_consistency = _cluster_agreement(
                assignment, np.asarray(t_state.assignment), self.n_nodes)
        return assignment

    def assign(self, img_vecs: np.ndarray) -> np.ndarray:
        assert self.centroids is not None, "fit() first"
        idx, _ = kmeans_assign(jnp.asarray(img_vecs, jnp.float32),
                               jnp.asarray(self.centroids))
        return np.asarray(idx)

    def build_node_dbs(self, img_vecs: np.ndarray, txt_vecs: np.ndarray,
                       payload_ids: np.ndarray, *, capacity_per_node: int,
                       use_pallas: bool = False, t0: float = 0.0,
                       ) -> List[VectorDB]:
        """Fit + materialise the per-node VDBs (data-preprocessing phase)."""
        assignment = self.fit(img_vecs, txt_vecs)
        dbs = []
        for ni in range(self.n_nodes):
            db = VectorDB(img_vecs.shape[-1], capacity_per_node,
                          name=f"node{ni}", use_pallas=use_pallas)
            sel = np.flatnonzero(assignment == ni)
            if sel.size:
                # Respect capacity at build time; the LCU policy maintains it after.
                sel = sel[:capacity_per_node]
                db.add(img_vecs[sel], txt_vecs[sel], payload_ids[sel], t=t0)
            dbs.append(db)
        return dbs

    def reassign_failed_node(self, dbs: Sequence[VectorDB], failed: int,
                             t: float,
                             survivors: Optional[Sequence[int]] = None,
                             ) -> None:
        """Node-failure recovery: move the failed node's entries to the
        nearest surviving centroid's VDB and drop the failed centroid.

        ``centroid_nodes`` maps centroid rows back to node indices —
        failures drop rows, so after one failure row i no longer belongs
        to node i and a second failure must look its row up.  ``survivors``
        restricts receivers (callers pass the ALIVE fleet so entries are
        never reassigned onto an earlier casualty); default: every node
        that still owns a centroid row, minus ``failed``."""
        assert self.centroids is not None
        db = dbs[failed]
        if survivors is None:
            survivors = [n for n in self.centroid_nodes if n != failed]
        surv_rows = [r for r, n in enumerate(self.centroid_nodes)
                     if n in set(survivors) and n != failed]
        surv_nodes = [self.centroid_nodes[r] for r in surv_rows]
        if not surv_nodes:
            return
        surv_cents = self.centroids[surv_rows]
        sel = np.flatnonzero(db.valid)
        if sel.size:
            idx, _ = kmeans_assign(jnp.asarray(db.img_vecs[sel]),
                                   jnp.asarray(surv_cents))
            idx = np.asarray(idx)
            for j, ni in enumerate(surv_nodes):
                pick = sel[idx == j]
                if pick.size:
                    dbs[ni].add(db.img_vecs[pick], db.txt_vecs[pick],
                                db.payload_ids[pick], t=t)
            db.evict_slots(sel)
        keep = [r for r, n in enumerate(self.centroid_nodes) if n != failed]
        self.centroids = self.centroids[keep]
        self.centroid_nodes = [self.centroid_nodes[r] for r in keep]


def _cluster_agreement(a: np.ndarray, b: np.ndarray, k: int) -> float:
    """Best-match overlap between two clusterings (greedy Hungarian-ish)."""
    conf = np.zeros((k, k), np.int64)
    for i, j in zip(a, b):
        conf[i, j] += 1
    total = len(a)
    agree = 0
    used = set()
    for i in np.argsort(-conf.max(axis=1)):
        j = int(np.argmax(np.where(np.isin(np.arange(k), list(used)),
                                   -1, conf[i])))
        used.add(j)
        agree += conf[i, j]
    return agree / max(total, 1)
