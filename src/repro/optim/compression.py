"""Int8 gradient compression with error feedback.

Used on the cross-pod data-parallel reduction path (pod-to-pod DCI links
are the scarce bandwidth at 512+ chips): gradients are quantised to int8
with a per-tensor scale before the pod-level reduction and dequantised
after; the quantisation residual is carried into the next step (error
feedback), which keeps SGD/Adam convergence unbiased in expectation.

In the pjit training steps the cross-pod reduction is implicit (GSPMD
inserts it), so this module is the OPT-IN building block for a
shard_map-based DP synchronisation path at deploy time rather than a
default: quantise -> reduce the (payload, scale) pair over the ``pod``
axis -> dequantise, carrying the residual. Its convergence contract
(bounded one-shot error, mean-converging under error feedback) is
property-tested in tests/test_optim.py.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    error: PyTree  # residual carried to the next step


def compression_init(grads: PyTree) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                     grads))


def compress_grads(grads: PyTree, state: CompressionState,
                   ) -> Tuple[PyTree, PyTree, CompressionState]:
    """Returns (int8 payload, scales, new_state).  payload+scales are what
    crosses the wire; caller dequantises with decompress_grads."""

    def comp(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        err = g32 - q.astype(jnp.float32) * scale
        return q, scale, err

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    q = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    err = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return q, s, CompressionState(error=err)


def decompress_grads(payload: PyTree, scales: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales)


def compressed_bytes(payload: PyTree) -> int:
    return sum(l.size for l in jax.tree_util.tree_leaves(payload))
