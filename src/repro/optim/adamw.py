"""Functional AdamW with decoupled weight decay and global-norm clipping.

Moments are stored in fp32 regardless of the (possibly bf16) param dtype;
under the dry-run partitioning the moments inherit the parameter sharding
plus optional ZeRO-style sharding of the moments over the data axis
(see runtime/partition.py — "zero" rules).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    m: PyTree
    v: PyTree
    count: jax.Array


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree,
                 cfg: AdamWConfig, *, lr_scale: jax.Array | float = 1.0,
                 ) -> Tuple[PyTree, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            step = step + cfg.weight_decay * p32
        return (p32 - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    # Serialize large-leaf updates (optimization_barrier chain) so the
    # scheduler reuses one leaf's fp32 temps instead of keeping every
    # leaf's chain live simultaneously — Σ-leaves vs max-leaf peak memory
    # on the multi-billion-parameter archs.
    out = []
    token = None
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        if token is not None and p.size > (1 << 20):
            # value-level no-op dependency (see adafactor.py): serializes
            # large-leaf update chains so their fp32 temps are reused.
            zero = jnp.minimum(jnp.abs(token[(0,) * token.ndim]), 0).astype(g.dtype)
            g = g + zero
        o = upd(g, m, v, p)
        out.append(o)
        if p.size > (1 << 20):
            token = o[0]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(new_m, new_v, count), metrics
