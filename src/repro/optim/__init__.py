"""Optimizer stack: AdamW, LR schedules, grad clipping, int8 compression."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.compression import compress_grads, decompress_grads, CompressionState  # noqa: F401
