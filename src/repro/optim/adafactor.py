"""Adafactor (Shazeer & Stern, 2018) — memory-factored second moment.

Used for the 400B-class MoE arch where full fp32 Adam moments cannot fit a
single 256-chip v5e pod (400B × 8 bytes of moments = 3.2 TB > the pod's
4 TB HBM once params/grads/activations join).  Factoring the second moment
of every rank≥2 parameter into row/col statistics cuts moment memory from
4·N bytes to ~4·N/min(dims), and ``beta1=0`` (the T5/PaLM setting) drops
the first moment entirely:

    params bf16 (2·N) + factored v (≈0) + grad accum bf16 (2·N) ≈ 4·N bytes,

which fits 400B on 256 chips with room for activations.

The update-clipping (RMS-scaled) and relative-step logic follow the paper;
learning-rate scheduling plugs in via ``lr_scale`` exactly like AdamW.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdafactorConfig(NamedTuple):
    lr: float = 1e-2
    decay_rate: float = 0.8          # beta2_t = 1 - t^-decay_rate
    beta1: float = 0.0               # 0 → no first moment (memory-free)
    eps1: float = 1e-30              # regulariser inside rsqrt
    eps2: float = 1e-3               # lr floor relative to param RMS
    clip_threshold: float = 1.0      # update RMS clipping
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128


class _FactoredMoment(NamedTuple):
    row: jax.Array                   # (..., d_row)  mean over cols
    col: jax.Array                   # (..., d_col)  mean over rows


class AdafactorState(NamedTuple):
    v: PyTree                        # _FactoredMoment or full array per leaf
    m: Optional[PyTree]              # first moment (None when beta1 == 0)
    count: jax.Array


def _should_factor(shape, cfg: AdafactorConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= cfg.min_dim_size_to_factor
            and shape[-2] >= cfg.min_dim_size_to_factor)


def adafactor_init(params: PyTree, cfg: AdafactorConfig = AdafactorConfig()
                   ) -> AdafactorState:
    def init_v(p):
        if _should_factor(p.shape, cfg):
            return _FactoredMoment(
                row=jnp.zeros(p.shape[:-1], jnp.float32),
                col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    v = jax.tree_util.tree_map(init_v, params)
    m = (jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
         if cfg.beta1 > 0 else None)
    return AdafactorState(v=v, m=m, count=jnp.zeros((), jnp.int32))


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)))


def adafactor_update(grads: PyTree, state: AdafactorState, params: PyTree,
                     cfg: AdafactorConfig, *, lr_scale: jax.Array | float = 1.0,
                     ) -> Tuple[PyTree, AdafactorState, dict]:
    count = state.count + 1
    t = count.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)
    lr = cfg.lr * lr_scale

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state.v)
    flat_m = treedef.flatten_up_to(state.m) if state.m is not None else [None] * len(flat_p)

    new_p, new_v, new_m = [], [], []
    sq_gnorm = 0.0
    token = None
    for p, g, v, m in zip(flat_p, flat_g, flat_v, flat_m):
        if token is not None and p.size > (1 << 20):
            # Serialize large-leaf updates: without a dependency chain the
            # scheduler overlaps every leaf's fp32 temp chain and peak
            # memory grows with Σ leaves instead of max leaf (measured:
            # ~20 GB of co-live optimizer temps per chip at 400B).
            # optimization_barrier is IGNORED by CPU buffer assignment, so
            # this is a true value-level dependency that is numerically a
            # no-op: min(|token₀|, 0) ≡ 0.
            zero = jnp.minimum(jnp.abs(token[(0,) * token.ndim]), 0).astype(g.dtype)
            g = g + zero
        # Memory discipline (the 400B arch lives or dies on this): never
        # materialise a full-size fp32 copy that a fused broadcast can
        # replace.  rsqrt(row ⊗ col) = rsqrt(row) ⊗ rsqrt(col), so the
        # rank-1 preconditioner is applied as two BROADCAST multiplies —
        # `pre` itself never exists.  ``g`` stays in its storage dtype;
        # squares/reductions convert inside fusions.
        gf = g.astype(jnp.float32)  # fuses into each consumer below
        sq_gnorm = sq_gnorm + jnp.sum(jnp.square(gf))
        if isinstance(v, _FactoredMoment):
            g2_row = jnp.mean(jnp.square(gf), axis=-1) + cfg.eps1
            g2_col = jnp.mean(jnp.square(gf), axis=-2) + cfg.eps1
            row = beta2 * v.row + (1 - beta2) * g2_row
            col = beta2 * v.col + (1 - beta2) * g2_col
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            r_row = jax.lax.rsqrt(
                jnp.maximum(row / jnp.maximum(row_mean, cfg.eps1), cfg.eps1))
            r_col = jax.lax.rsqrt(jnp.maximum(col, cfg.eps1))
            update = gf * r_row[..., None] * r_col[..., None, :]
            v_new = _FactoredMoment(row=row, col=col)
        else:
            v_full = beta2 * v + (1 - beta2) * (jnp.square(gf) + cfg.eps1)
            update = gf * jax.lax.rsqrt(jnp.maximum(v_full, cfg.eps1))
            v_new = v_full
        # update clipping: bound the update RMS at clip_threshold
        denom = jnp.maximum(1.0, _rms(update) / cfg.clip_threshold)
        if m is not None:
            m = cfg.beta1 * m + (1 - cfg.beta1) * (update / denom)
            update, denom = m, 1.0
            new_m.append(m)
        # parameter-scale-relative step size
        alpha = lr * jnp.maximum(_rms(p.astype(jnp.float32)), cfg.eps2)
        scale_ = alpha / denom
        decay = (lr * cfg.weight_decay) if (cfg.weight_decay and p.ndim >= 2) \
            else 0.0
        out = (p.astype(jnp.float32) * (1.0 - decay)
               - scale_ * update).astype(p.dtype)
        new_p.append(out)
        new_v.append(v_new)
        if p.size > (1 << 20):
            token = out

    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    v_out = jax.tree_util.tree_unflatten(treedef, new_v)
    m_out = (jax.tree_util.tree_unflatten(treedef, new_m)
             if state.m is not None else None)
    metrics = {"grad_norm": jnp.sqrt(sq_gnorm), "lr": lr}
    return params_out, AdafactorState(v_out, m_out, count), metrics
