"""Gateway: the client-facing async front door over the serving engine.

This is the production shape of the paper's §V deployment — what turns
the repo's trace generators into "one client among many".  A
:class:`Gateway` owns the three front-door pieces and wires them to a
``ServingEngine``:

    clients ──submit()──> FrontDoorQueue ──Dispatcher (worker thread)──>
        ServingEngine.serve_group ──> ResultStore ──> ResultHandle

* ``submit`` runs admission control synchronously (token-bucket quota +
  global backpressure bound, both typed errors) and returns a
  :class:`ResultHandle` immediately — clients ``await
  handle.wait_async()`` (asyncio) or ``handle.wait()`` (threads) and
  fetch pixels from the result store on demand.  No HTTP framework is
  required: the gateway IS the API surface, stdlib-only, and a FastAPI/
  aiohttp wrapper would be a ~20-line adapter over ``submit``.
* SLA tiers (``premium``/``standard``/``batch`` by default) give strict
  dequeue priority with deadline-based escalation; per-tenant token
  buckets bound each tenant's accepted rate; weighted fair share keeps
  any one tenant from starving the rest (all in
  ``repro.frontdoor.queue``).
* ``join_node`` / ``leave_node`` change fleet capacity mid-run,
  gracefully: ops apply at the next step-group boundary, in-queue jobs
  reroute, nothing accepted is lost.

Everything is wall-clock (``time.perf_counter``): unlike
``ServingEngine.run``'s virtual timeline, concurrent clients experience
real queueing against real service walls.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence, Tuple

from repro.frontdoor.dispatcher import Dispatcher
from repro.frontdoor.queue import (DEFAULT_TIERS, FrontDoorQueue, Job,
                                   TierSpec, TokenBucket)
from repro.frontdoor.results import (MemoryResultStore, ResultHandle,
                                     ResultStore)
from repro.runtime.serving import ServingEngine, tenant_tier_stats

__all__ = ["Gateway"]


class Gateway:
    """Async multi-tenant serving gateway (see the module docstring).

    ``quotas`` maps tenant -> ``(rate, burst)`` token-bucket parameters
    (tenants without an entry are unmetered); ``tenant_weights`` sets
    fair-share weights (default 1.0 each).  ``store=None`` uses the
    in-memory result store; pass a ``FileResultStore`` to offload
    finished images to disk.
    """

    def __init__(self, engine: ServingEngine, *,
                 tiers: Sequence[TierSpec] = DEFAULT_TIERS,
                 max_depth: int = 256,
                 quotas: Optional[Dict[str, Tuple[float, float]]] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 fair: bool = True,
                 store: Optional[ResultStore] = None):
        self.engine = engine
        self.store: ResultStore = store if store is not None \
            else MemoryResultStore()
        buckets = {t: TokenBucket(rate, burst)
                   for t, (rate, burst) in (quotas or {}).items()}
        self.queue = FrontDoorQueue(tiers=tiers, max_depth=max_depth,
                                    quotas=buckets,
                                    tenant_weights=tenant_weights,
                                    fair=fair)
        self.dispatcher = Dispatcher(engine, self.queue, self.store)

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Gateway":
        """Start the dispatcher worker.  Jobs may be submitted before
        ``start`` — they queue up and the first group admits them."""
        self.dispatcher.start()
        return self

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop serving.  ``drain=True`` finishes every accepted job
        first; ``drain=False`` fails still-queued handles with
        ``GatewayClosedError``."""
        self.dispatcher.stop(drain=drain, timeout=timeout)

    def __enter__(self) -> "Gateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=not any(exc))

    # -- the client surface -------------------------------------------------

    def submit(self, prompt: str, *, tenant: str = "default",
               tier: str = "standard", seed: int = 0,
               quality_tier: Optional[bool] = None) -> ResultHandle:
        """Admission-control one request; returns its completion handle.

        Raises ``ValueError`` (unknown tier), ``QuotaExceededError``
        (tenant over quota; carries ``retry_after``) or
        ``BackpressureError`` (queue full) — the typed rejections clients
        key their backoff on.  ``quality_tier=None`` derives the
        scheduler priority flag from the tier (premium ⇒ True).
        """
        job = Job(tenant=tenant, tier=tier, prompt=prompt, seed=seed,
                  quality_tier=quality_tier)
        handle = ResultHandle(job.job_id, self.store)
        job.handle = handle
        self.queue.submit(job, now=time.perf_counter())
        return handle

    async def submit_async(self, prompt: str, **kw) -> ResultHandle:
        """`submit` for asyncio clients.  Admission control is pure
        in-memory bookkeeping (no blocking I/O), so it runs inline on
        the event loop."""
        return self.submit(prompt, **kw)

    # -- capacity control ---------------------------------------------------

    def leave_node(self, node: int) -> None:
        """Gracefully drain ``node`` out of the fleet (next boundary)."""
        self.dispatcher.leave_node(node)

    def join_node(self, *, speed: float = 1.0,
                  capacity: Optional[int] = None) -> None:
        """Grow the fleet by one fresh node (next boundary)."""
        self.dispatcher.join_node(speed=speed, capacity=capacity)

    # -- observability ------------------------------------------------------

    def stats(self) -> Dict:
        """Operational snapshot: queue depth + admission tallies, groups
        served, and per-(tenant, tier) queue-delay / wall-latency
        percentiles over everything completed so far."""
        qs = self.queue.stats
        return {
            "queued": len(self.queue),
            "accepted": qs.accepted,
            "dispatched": qs.dispatched,
            "rejected_quota": qs.rejected_quota,
            "rejected_backpressure": qs.rejected_backpressure,
            "escalations": qs.escalations,
            "accepted_by_tenant": dict(qs.accepted_by_tenant),
            "rejected_by_tenant": dict(qs.rejected_by_tenant),
            "groups_served": self.dispatcher.groups_served,
            "jobs_served": self.dispatcher.jobs_served,
            "per_tenant_tier": tenant_tier_stats(self.engine.completed),
            "stored_results": len(self.store),
        }
