"""SLA-tier job queue: per-tenant quotas, weighted fair share, escalation.

This is the deterministic core of the front door (the asyncio gateway and
the worker-thread dispatcher are thin wrappers around it).  One queue
holds every accepted-but-not-yet-admitted job, organised as a FIFO deque
per (tier, tenant):

* **SLA tiers** — strict priority levels (:data:`DEFAULT_TIERS`:
  ``premium`` > ``standard`` > ``batch``), each with an SLA deadline.
  :meth:`FrontDoorQueue.next_batch` always serves the highest non-empty
  tier first, so one group boundary is the longest a premium job ever
  waits behind batch traffic.
* **Deadline-based escalation** — a job that has waited past its tier's
  ``escalate_after`` is promoted one level (joining the tail of the
  higher tier's per-tenant deque), so lower tiers degrade to
  "eventually served" instead of "starved" under sustained premium
  overload.  ``math.inf`` disables escalation for a tier.
* **Weighted fair share across tenants** — within the chosen tier,
  tenants are picked by start-time fair queueing: each tenant carries a
  virtual time advanced by ``1 / weight`` per dequeued job, and the
  lowest virtual time (ties broken by tenant name) goes first.  A tenant
  that floods the queue only advances its own virtual time, so a quiet
  tenant's next job is always near the front — the no-starvation
  property ``tests/test_frontdoor.py`` pins.
* **Admission control** — :meth:`FrontDoorQueue.submit` REJECTS instead
  of buffering unboundedly: a per-tenant token bucket (rate + burst)
  raises :class:`QuotaExceededError` when the tenant is over quota, and
  a global ``max_depth`` bound raises :class:`BackpressureError` when
  the whole queue is full.  Both are typed so gateway clients can
  distinguish "you specifically are over quota (retry after
  ``retry_after``)" from "the system is saturated".

All methods take an explicit ``now`` (seconds on any monotonic clock),
which keeps every policy decision replayable in tests; the queue is
internally locked so the gateway (submitting) and the dispatcher worker
thread (dequeuing) can share it.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

__all__ = ["BackpressureError", "DEFAULT_TIERS", "FrontDoorQueue", "Job",
           "QuotaExceededError", "TierSpec", "TokenBucket"]


# ---------------------------------------------------------------------------
# typed backpressure errors
# ---------------------------------------------------------------------------


class BackpressureError(RuntimeError):
    """The queue refused a job because the system is saturated.

    Carries enough context for a client to back off sensibly: the
    ``tenant``/``tier`` it tried to submit to, the queue ``depth`` at
    rejection, and the configured ``bound``.
    """

    def __init__(self, msg: str, *, tenant: str, tier: str,
                 depth: int, bound: int):
        super().__init__(msg)
        self.tenant = tenant
        self.tier = tier
        self.depth = depth
        self.bound = bound


class QuotaExceededError(BackpressureError):
    """The TENANT is over its token-bucket quota (the system may be
    idle).  ``retry_after`` is the seconds until the bucket refills one
    token — the natural client back-off interval."""

    def __init__(self, msg: str, *, tenant: str, tier: str, depth: int,
                 bound: int, retry_after: float):
        super().__init__(msg, tenant=tenant, tier=tier, depth=depth,
                         bound=bound)
        self.retry_after = retry_after


# ---------------------------------------------------------------------------
# tiers and quotas
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TierSpec:
    """One SLA tier.  ``level`` orders tiers (0 = most urgent, served
    first).  ``deadline`` is the tier's SLA target (seconds from submit;
    informational — stamped onto each job).  ``escalate_after`` is the
    wait after which a queued job is promoted one level (defaults to the
    deadline; ``math.inf`` = never escalate)."""

    name: str
    level: int
    deadline: float
    escalate_after: Optional[float] = None

    @property
    def escalation_wait(self) -> float:
        return (self.deadline if self.escalate_after is None
                else self.escalate_after)


DEFAULT_TIERS: Tuple[TierSpec, ...] = (
    TierSpec("premium", 0, deadline=1.0, escalate_after=math.inf),
    TierSpec("standard", 1, deadline=4.0),
    TierSpec("batch", 2, deadline=math.inf, escalate_after=30.0),
)


class TokenBucket:
    """Classic token bucket: ``burst`` capacity, ``rate`` tokens/second.
    ``try_take`` consumes one token if available; refill is computed
    lazily from the caller-supplied ``now`` (no wall-clock reads here, so
    quota decisions are replayable)."""

    def __init__(self, rate: float, burst: float):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate and burst must be > 0, "
                             f"got rate={rate}, burst={burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last: Optional[float] = None

    def _refill(self, now: float) -> None:
        if self._last is not None and now > self._last:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._last) * self.rate)
        self._last = now if self._last is None else max(self._last, now)

    def try_take(self, now: float) -> bool:
        self._refill(now)
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def time_until_token(self, now: float) -> float:
        """Seconds until one token is available (0 if already)."""
        self._refill(now)
        if self.tokens >= 1.0:
            return 0.0
        return (1.0 - self.tokens) / self.rate


# ---------------------------------------------------------------------------
# jobs
# ---------------------------------------------------------------------------

_job_counter = [0]
_job_counter_lock = threading.Lock()


def _next_job_id() -> int:
    with _job_counter_lock:
        _job_counter[0] += 1
        return _job_counter[0]


@dataclass
class Job:
    """One accepted generation request travelling through the front door.

    ``quality_tier`` maps the SLA tier onto the scheduler's existing
    quality-aware priority fast path (``fast_path="priority"`` in
    ``repro.core.scheduler``): ``None`` derives it from the tier (level 0
    = premium ⇒ True), an explicit bool wins.  ``deadline`` is absolute
    (``submitted_at + tier.deadline``).  The dispatcher fills
    ``admitted_at``/``finished_at``; the gateway attaches the completion
    handle.
    """

    tenant: str
    tier: str
    prompt: str
    seed: int = 0
    quality_tier: Optional[bool] = None
    submitted_at: float = 0.0
    deadline: float = math.inf
    job_id: int = field(default_factory=_next_job_id)
    # effective tier after deadline escalations (starts == tier)
    effective_tier: str = ""
    escalations: int = 0
    admitted_at: float = -1.0
    finished_at: float = -1.0
    handle: object = None

    def __post_init__(self):
        if not self.effective_tier:
            self.effective_tier = self.tier


# ---------------------------------------------------------------------------
# the queue
# ---------------------------------------------------------------------------


@dataclass
class QueueStats:
    accepted: int = 0
    dispatched: int = 0
    rejected_quota: int = 0
    rejected_backpressure: int = 0
    escalations: int = 0
    # per-tenant accepted/rejected tallies for the fairness reports
    accepted_by_tenant: Dict[str, int] = field(default_factory=dict)
    rejected_by_tenant: Dict[str, int] = field(default_factory=dict)


class FrontDoorQueue:
    """Priority/SLA-tier queue with per-tenant quotas and fair dequeue
    (see the module docstring for the policy).  Thread-safe; all methods
    take an explicit ``now``."""

    def __init__(self, *, tiers: Sequence[TierSpec] = DEFAULT_TIERS,
                 max_depth: int = 256,
                 quotas: Optional[Dict[str, TokenBucket]] = None,
                 tenant_weights: Optional[Dict[str, float]] = None,
                 fair: bool = True):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        levels = sorted(t.level for t in tiers)
        if levels != list(range(len(tiers))):
            raise ValueError(f"tier levels must be 0..{len(tiers) - 1}, "
                             f"got {levels}")
        self.tiers: Dict[str, TierSpec] = {t.name: t for t in tiers}
        self.by_level: List[TierSpec] = sorted(tiers, key=lambda t: t.level)
        self.max_depth = max_depth
        self.quotas = dict(quotas or {})
        self.tenant_weights = dict(tenant_weights or {})
        self.fair = fair
        self.stats = QueueStats()
        # (level, tenant) -> FIFO of jobs; per-tenant fair-share state
        self._queues: Dict[Tuple[int, str], Deque[Job]] = {}
        self._vtime: Dict[str, float] = {}
        self._vclock = 0.0
        self._depth = 0
        self._lock = threading.Condition()

    # -- admission ----------------------------------------------------------

    def submit(self, job: Job, now: float) -> Job:
        """Admission-control a job into the queue (or raise).

        Order of checks: unknown tier (``ValueError``) → global depth
        bound (:class:`BackpressureError`) → tenant token bucket
        (:class:`QuotaExceededError`).  On accept the job is stamped with
        ``submitted_at = now`` and its absolute SLA ``deadline``.
        """
        if job.tier not in self.tiers:
            raise ValueError(f"unknown tier {job.tier!r} "
                             f"(have {sorted(self.tiers)})")
        spec = self.tiers[job.tier]
        with self._lock:
            if self._depth >= self.max_depth:
                self.stats.rejected_backpressure += 1
                self._bump(self.stats.rejected_by_tenant, job.tenant)
                raise BackpressureError(
                    f"queue full ({self._depth}/{self.max_depth}); "
                    f"rejecting {job.tenant}/{job.tier}",
                    tenant=job.tenant, tier=job.tier, depth=self._depth,
                    bound=self.max_depth)
            bucket = self.quotas.get(job.tenant)
            if bucket is not None and not bucket.try_take(now):
                self.stats.rejected_quota += 1
                self._bump(self.stats.rejected_by_tenant, job.tenant)
                raise QuotaExceededError(
                    f"tenant {job.tenant!r} over quota "
                    f"({bucket.rate:g}/s, burst {bucket.burst:g})",
                    tenant=job.tenant, tier=job.tier, depth=self._depth,
                    bound=self.max_depth,
                    retry_after=bucket.time_until_token(now))
            job.submitted_at = now
            job.deadline = now + spec.deadline
            job.effective_tier = job.tier
            self._enqueue(spec.level, job)
            self.stats.accepted += 1
            self._bump(self.stats.accepted_by_tenant, job.tenant)
            self._lock.notify_all()
            return job

    # -- dequeue ------------------------------------------------------------

    def next_batch(self, n: int, now: float) -> List[Job]:
        """Dequeue up to ``n`` jobs in policy order: escalate overdue
        jobs, then repeatedly take the head of the highest-priority
        non-empty tier, picking the tenant with the lowest fair-share
        virtual time (FIFO across tenants when ``fair=False``).  One
        batch may mix tiers — lower tiers fill the slots the higher
        tiers do not need, so spare capacity is never wasted."""
        out: List[Job] = []
        with self._lock:
            self._escalate(now)
            while len(out) < n:
                job = self._pop_one()
                if job is None:
                    break
                out.append(job)
            self.stats.dispatched += len(out)
        return out

    def wait_for_jobs(self, timeout: float) -> bool:
        """Block until the queue is non-empty (or ``timeout`` elapses);
        the dispatcher worker parks here between groups."""
        with self._lock:
            if self._depth:
                return True
            return self._lock.wait(timeout)

    def kick(self) -> None:
        """Wake any :meth:`wait_for_jobs` waiter without enqueuing —
        used by the dispatcher to apply control ops / shutdown promptly."""
        with self._lock:
            self._lock.notify_all()

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return self._depth

    def depth_by_tenant(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for (_, tenant), q in self._queues.items():
                out[tenant] = out.get(tenant, 0) + len(q)
            return out

    # -- internals ----------------------------------------------------------

    @staticmethod
    def _bump(d: Dict[str, int], key: str) -> None:
        d[key] = d.get(key, 0) + 1

    def _enqueue(self, level: int, job: Job) -> None:
        self._queues.setdefault((level, job.tenant),
                                deque()).append(job)
        self._depth += 1

    def _escalate(self, now: float) -> None:
        """Promote overdue jobs one level (tail of the higher tier).
        Within one per-tenant FIFO the head is oldest, so popping
        overdue heads catches every overdue job."""
        for spec in self.by_level[1:]:          # level 0 cannot escalate
            wait = spec.escalation_wait
            if not math.isfinite(wait):
                continue
            for (level, tenant), q in list(self._queues.items()):
                if level != spec.level:
                    continue
                while q and now - q[0].submitted_at >= wait:
                    job = q.popleft()
                    job.effective_tier = self.by_level[level - 1].name
                    job.escalations += 1
                    self.stats.escalations += 1
                    self._queues.setdefault((level - 1, tenant),
                                            deque()).append(job)

    def _pop_one(self) -> Optional[Job]:
        for spec in self.by_level:
            tenants = [t for (lvl, t), q in self._queues.items()
                       if lvl == spec.level and q]
            if not tenants:
                continue
            if self.fair:
                tenant = min(tenants,
                             key=lambda t: (self._vtime.get(t, 0.0), t))
            else:       # FIFO across tenants: oldest head wins
                tenant = min(
                    tenants,
                    key=lambda t: (self._queues[(spec.level, t)][0]
                                   .submitted_at,
                                   self._queues[(spec.level, t)][0].job_id))
            q = self._queues[(spec.level, tenant)]
            job = q.popleft()
            self._depth -= 1
            # start-time fair queueing: charge 1/weight virtual seconds
            w = max(self.tenant_weights.get(tenant, 1.0), 1e-9)
            v = max(self._vtime.get(tenant, 0.0), self._vclock)
            self._vtime[tenant] = v + 1.0 / w
            self._vclock = v
            return job
        return None
