"""Pluggable result stores + completion handles for the front door.

Finished images are OFFLOADED out of the serving process's working set
the moment a group completes: the dispatcher ``put``\\ s each image into a
:class:`ResultStore` and resolves the job's :class:`ResultHandle` with
the store reference — clients poll/await the handle and fetch the pixels
only when they want them, instead of every completed request pinning an
array in process memory (the paper's §V NFS image store, and the
object-storage offload production serving systems use).

Two backends ship:

* :class:`MemoryResultStore` — a dict; zero-dependency default for tests
  and benchmarks (the "offload" is then just decoupling lifetime from
  the engine's completion records).
* :class:`FileResultStore` — one ``.npy`` per image plus a ``.json``
  metadata sidecar under a directory; the process-memory cost of a
  finished job drops to a file path.

Handles are dual-mode: ``wait(timeout)``/``done()``/``image()`` from
plain threads, ``await handle.wait_async()`` from asyncio (the future is
a ``concurrent.futures.Future``, bridged with ``asyncio.wrap_future`` —
stdlib only).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
from typing import Any, Dict, Iterator, Optional, Protocol, Tuple

import numpy as np

__all__ = ["FileResultStore", "GatewayClosedError", "MemoryResultStore",
           "ResultHandle", "ResultStore"]


class GatewayClosedError(RuntimeError):
    """The gateway shut down (without drain) before this job was served."""


class ResultStore(Protocol):
    """Where finished images live after the engine is done with them."""

    def put(self, job_id: int, image: np.ndarray,
            meta: Optional[Dict[str, Any]] = None) -> str:
        """Persist one result; returns an opaque reference."""
        ...

    def get(self, ref: str) -> np.ndarray:
        """Load the image back by reference."""
        ...

    def meta(self, ref: str) -> Dict[str, Any]:
        """Load the metadata sidecar (``{}`` if none was stored)."""
        ...

    def __len__(self) -> int: ...


class MemoryResultStore:
    """In-memory backend: a dict of ``ref -> (image, meta)``."""

    def __init__(self):
        self._items: Dict[str, Tuple[np.ndarray, Dict[str, Any]]] = {}

    def put(self, job_id: int, image: np.ndarray,
            meta: Optional[Dict[str, Any]] = None) -> str:
        ref = f"mem:{job_id}"
        self._items[ref] = (np.asarray(image), dict(meta or {}))
        return ref

    def get(self, ref: str) -> np.ndarray:
        return self._items[ref][0]

    def meta(self, ref: str) -> Dict[str, Any]:
        return dict(self._items[ref][1])

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self) -> Iterator[str]:
        return iter(self._items)


class FileResultStore:
    """Filesystem backend: ``<dir>/<job_id>.npy`` + ``<job_id>.json``.
    The reference is the ``.npy`` path, so results survive the process
    and the serving host's memory holds only path strings."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._count = 0

    def put(self, job_id: int, image: np.ndarray,
            meta: Optional[Dict[str, Any]] = None) -> str:
        path = os.path.join(self.directory, f"{job_id}.npy")
        np.save(path, np.asarray(image))
        if meta:
            with open(os.path.join(self.directory, f"{job_id}.json"),
                      "w") as fh:
                json.dump(meta, fh)
        self._count += 1
        return path

    def get(self, ref: str) -> np.ndarray:
        return np.load(ref)

    def meta(self, ref: str) -> Dict[str, Any]:
        side = os.path.splitext(ref)[0] + ".json"
        if not os.path.exists(side):
            return {}
        with open(side) as fh:
            return json.load(fh)

    def __len__(self) -> int:
        return self._count


class ResultHandle:
    """Completion handle for one accepted job.

    Resolves (from the dispatcher's worker thread) to a store reference
    plus a small metadata dict — route, node, scores, latencies — never
    the pixels; ``image()`` fetches those from the store on demand.
    """

    def __init__(self, job_id: int, store: ResultStore):
        self.job_id = job_id
        self._store = store
        self._future: "concurrent.futures.Future[str]" = \
            concurrent.futures.Future()
        self.meta: Dict[str, Any] = {}

    # -- dispatcher side ----------------------------------------------------

    def _resolve(self, ref: str, meta: Dict[str, Any]) -> None:
        self.meta = meta
        self._future.set_result(ref)

    def _fail(self, exc: BaseException) -> None:
        if not self._future.done():
            self._future.set_exception(exc)

    # -- client side --------------------------------------------------------

    def done(self) -> bool:
        return self._future.done()

    def wait(self, timeout: Optional[float] = None) -> str:
        """Block until served; returns the result-store reference."""
        return self._future.result(timeout)

    async def wait_async(self) -> str:
        """Awaitable form of :meth:`wait` (asyncio, stdlib bridge)."""
        import asyncio
        return await asyncio.wrap_future(self._future)

    @property
    def ref(self) -> Optional[str]:
        return self._future.result(0) if self._future.done() else None

    def image(self) -> np.ndarray:
        """Fetch the finished image from the result store (blocks until
        the job is served)."""
        return self._store.get(self.wait())
