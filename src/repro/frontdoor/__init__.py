"""Front door: async multi-tenant serving gateway over the engine.

Four pieces (one module each):

* :mod:`repro.frontdoor.queue` — SLA-tier priority queue with per-tenant
  token-bucket quotas, weighted-fair dequeue, deadline escalation, and
  typed backpressure rejections;
* :mod:`repro.frontdoor.dispatcher` — worker-thread bridge admitting the
  fair-share head of the queue into ``ServingEngine.serve_group`` at
  every step-group boundary (plus graceful node join/leave);
* :mod:`repro.frontdoor.results` — pluggable result stores (memory /
  filesystem) and the completion handles clients poll or await;
* :mod:`repro.frontdoor.gateway` — the client-facing API tying them
  together.

``python -m repro.launch.frontdoor`` drives it with N concurrent
synthetic tenant clients; the ``frontdoor_load`` benchmark measures tier
isolation, quota enforcement and fairness.
"""
from repro.frontdoor.dispatcher import Dispatcher
from repro.frontdoor.gateway import Gateway
from repro.frontdoor.queue import (BackpressureError, DEFAULT_TIERS,
                                   FrontDoorQueue, Job, QuotaExceededError,
                                   TierSpec, TokenBucket)
from repro.frontdoor.results import (FileResultStore, GatewayClosedError,
                                     MemoryResultStore, ResultHandle,
                                     ResultStore)

__all__ = [
    "BackpressureError", "DEFAULT_TIERS", "Dispatcher", "FileResultStore",
    "FrontDoorQueue", "Gateway", "GatewayClosedError", "Job",
    "MemoryResultStore", "QuotaExceededError", "ResultHandle",
    "ResultStore", "TierSpec", "TokenBucket",
]
