"""Dispatcher: bridges the front-door queue to the step-group engine loop.

The serving engine is synchronous and batch-oriented: one
``ServingEngine.serve_group`` call runs one staged-pipeline pass (one set
of AOT generation buckets) to completion.  The dispatcher runs that loop
on a dedicated WORKER THREAD and, at every group boundary, admits the
fair-share head of the queue as the next group:

    submit (any thread / asyncio) ──> FrontDoorQueue ──┐
                                                       │ next_batch(max_batch)
          worker thread:  ... group N ──[boundary]─────┴─> group N+1 ...

Between groups the worker also applies queued CONTROL OPS — node
join/leave — so capacity changes are graceful by construction: routing
happens inside ``serve_batch`` at admission, so a node marked failed at a
boundary simply stops receiving new groups while every already-accepted
job still in the queue reroutes to the survivors.  Zero accepted jobs are
lost (``tests/test_frontdoor.py`` pins this).

SLA tiers map onto the scheduler's existing priority machinery here:
``premium`` (tier level 0) jobs run with ``quality_tier=True``, so
repeated premium prompts take the scheduler's ``fast_path="priority"``
pin-to-fastest-node path, exactly like the paper's quality-aware
priority scheduling.

On completion each job's image is ``put`` into the result store and the
job's handle resolves with the store reference + metadata; the
``ServeResult`` image pointer is dropped so finished pixels do not
accumulate in engine memory (the offload contract of
``repro.frontdoor.results``).
"""
from __future__ import annotations

import threading
import time
import warnings
from typing import Callable, List, Optional

from repro.core.pipeline import TransientBackendError
from repro.frontdoor.queue import FrontDoorQueue, Job
from repro.frontdoor.results import GatewayClosedError, ResultStore
from repro.runtime.serving import Request, ServingEngine

__all__ = ["Dispatcher"]


class Dispatcher:
    """Worker-thread pump from a :class:`FrontDoorQueue` into a
    :class:`ServingEngine` (see the module docstring for the loop)."""

    def __init__(self, engine: ServingEngine, queue: FrontDoorQueue,
                 store: ResultStore, *,
                 clock: Callable[[], float] = time.perf_counter,
                 idle_wait: float = 0.005,
                 max_group_retries: int = 3,
                 retry_backoff: float = 0.01):
        self.engine = engine
        self.queue = queue
        self.store = store
        self.clock = clock
        self.idle_wait = idle_wait
        # transiently failed groups retry with doubling backoff before
        # the whole group is failed to its handles
        self.max_group_retries = int(max_group_retries)
        self.retry_backoff = float(retry_backoff)
        self.groups_served = 0
        self.jobs_served = 0
        self._control: List[Callable[[], None]] = []
        self._control_lock = threading.Lock()
        self._stop = threading.Event()
        self._drain_on_stop = True
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("dispatcher already started")
        self._thread = threading.Thread(target=self._run,
                                        name="frontdoor-dispatcher",
                                        daemon=True)
        self._thread.start()

    def stop(self, *, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop the worker.  ``drain=True`` (default) serves everything
        already accepted first — the graceful path; ``drain=False`` fails
        still-queued jobs with :class:`GatewayClosedError`.

        If ``timeout`` expires with the worker still alive, a
        ``RuntimeWarning`` is issued and the thread handle is KEPT (so
        ``running`` stays truthful and a later ``stop`` can join it) —
        earlier revisions dropped the handle silently, making hung
        shutdowns invisible."""
        self._drain_on_stop = drain
        self._stop.set()
        self.queue.kick()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                warnings.warn(
                    f"dispatcher worker did not stop within {timeout}s "
                    f"({len(self.queue)} jobs still queued); thread handle "
                    "kept — call stop() again to re-join",
                    RuntimeWarning, stacklevel=2)
                return
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- control ops (applied at the next group boundary) -------------------

    def leave_node(self, node: int) -> None:
        """Gracefully remove ``node`` from the fleet at the next group
        boundary: in-flight work finishes first, queued jobs reroute."""
        with self._control_lock:
            self._control.append(lambda: self.engine.fail_node(node))
        self._kick()

    def join_node(self, *, speed: float = 1.0,
                  capacity: Optional[int] = None) -> None:
        """Add a fresh node at the next group boundary (see
        ``ServingEngine.join_node``)."""
        with self._control_lock:
            self._control.append(
                lambda: self.engine.join_node(speed=speed,
                                              capacity=capacity))
        self._kick()

    def _kick(self) -> None:
        # wake the worker so a control op on an idle queue applies promptly
        self.queue.kick()

    def _apply_control(self) -> None:
        with self._control_lock:
            ops, self._control = self._control, []
        for op in ops:
            op()

    # -- the worker loop ----------------------------------------------------

    def _run(self) -> None:
        while True:
            self._apply_control()
            if self._stop.is_set():
                if not self._drain_on_stop or not len(self.queue):
                    break
            elif not len(self.queue):
                self.queue.wait_for_jobs(self.idle_wait)
                continue
            jobs = self.queue.next_batch(self.engine.max_batch,
                                         now=self.clock())
            if not jobs:
                continue
            self._serve_group(jobs)
        # anything still queued after a no-drain stop fails typed
        for job in self.queue.next_batch(len(self.queue) or 1,
                                         now=self.clock()):
            if job.handle is not None:
                job.handle._fail(GatewayClosedError(
                    f"gateway closed before job {job.job_id} was served"))

    def _serve_group(self, jobs: List[Job]) -> None:
        batch = [Request(j.prompt, j.seed,
                         quality_tier=(j.quality_tier
                                       if j.quality_tier is not None
                                       else self._is_priority(j)),
                         submitted_at=j.submitted_at,
                         tenant=j.tenant, tier=j.tier)
                 for j in jobs]
        backoff = self.retry_backoff
        attempt = 0
        while True:
            try:
                completed = self.engine.serve_group(batch)
                break
            except TransientBackendError as exc:
                # transiently failed group: back off and retry (on top of
                # the Generate stage's own in-call retry budget)
                attempt += 1
                if attempt > self.max_group_retries:
                    for j in jobs:
                        if j.handle is not None:
                            j.handle._fail(exc)
                    return
                time.sleep(backoff)
                backoff *= 2.0
            except Exception as exc:             # fail the whole group
                for j in jobs:
                    if j.handle is not None:
                        j.handle._fail(exc)
                return
        done_at = self.clock()
        for job, comp in zip(jobs, completed):
            job.admitted_at = job.submitted_at + comp.queue_delay
            job.finished_at = done_at
            res = comp.result
            meta = {
                "tenant": job.tenant, "tier": job.tier,
                "effective_tier": job.effective_tier,
                "escalations": job.escalations,
                "route": res.fast_path or res.route.value,
                "node": res.node, "score": res.score,
                "queue_delay": comp.queue_delay,
                "wall_total": res.wall_total,
                "latency": res.latency,
            }
            ref = self.store.put(job.job_id, res.image, meta)
            res.image = None      # offloaded: the store owns the pixels now
            self.jobs_served += 1
            if job.handle is not None:
                job.handle._resolve(ref, meta)
        self.groups_served += 1

    def _is_priority(self, job: Job) -> bool:
        spec = self.queue.tiers.get(job.tier)
        return spec is not None and spec.level == 0
