"""Checkpoint manager — atomic, async, retained, resumable, reshardable.

Layout of one checkpoint:

    <root>/step_<n>.tmp/      (written)
    <root>/step_<n>/          (atomically published via rename)
        manifest.json         treedef paths, shapes, dtypes, partition
                              specs, mesh shape/axes, extra state (data
                              iterator, RNG, step)
        arrays.npz            one entry per leaf (flattened '/'-joined key)

Fault-tolerance contract:
  * writes are atomic (tmp dir + rename) — a crash mid-write never corrupts
    the latest checkpoint;
  * ``save_async`` double-buffers on a worker thread: training continues
    while the previous step serialises (arrays are snapshotted to host
    numpy before the thread starts, so no aliasing with the live buffers);
  * ``restore`` reads the newest complete checkpoint and verifies the
    manifest hash of every array's shape/dtype;
  * retention keeps the newest ``keep`` checkpoints (plus every ``keep_every``-th).

Single-process container note: arrays are saved as full (replicated)
host arrays.  On a real multi-host pod each host saves only the shards it
owns (``addressable_shards``) under ``arrays.<host>.npz`` — the manifest
format already records the global shape + PartitionSpec needed to
reassemble, which is what ``runtime/elastic.py`` uses to reshard onto a
different mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_names(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3, keep_every: int = 0):
        self.root = root
        self.keep = keep
        self.keep_every = keep_every
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None,
             specs: Optional[PyTree] = None) -> str:
        arrays, _ = _flatten_with_names(tree)
        host = {k: np.asarray(v) for k, v in arrays.items()}
        spec_map = {}
        if specs is not None:
            spec_arrays, _ = _flatten_with_names(specs)
            spec_map = {k: str(v) for k, v in spec_arrays.items()}
        return self._write(step, host, extra or {}, spec_map)

    def save_async(self, step: int, tree: PyTree, *, extra: Optional[dict] = None,
                   specs: Optional[PyTree] = None) -> None:
        self.wait()  # double-buffer: at most one outstanding write
        arrays, _ = _flatten_with_names(tree)
        host = {k: np.asarray(v) for k, v in arrays.items()}  # snapshot NOW
        spec_map = {}
        if specs is not None:
            spec_arrays, _ = _flatten_with_names(specs)
            spec_map = {k: str(v) for k, v in spec_arrays.items()}
        extra = dict(extra or {})

        def work():
            try:
                self._write(step, host, extra, spec_map)
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: dict,
               spec_map: Dict[str, str]) -> str:
        final = os.path.join(self.root, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "extra": extra,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "spec": spec_map.get(k, "")} for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._retire()
        return final

    def _retire(self) -> None:
        steps = self.all_steps()
        doomed = steps[:-self.keep] if self.keep else []
        for s in doomed:
            if self.keep_every and s % self.keep_every == 0:
                continue
            shutil.rmtree(os.path.join(self.root, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.root)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: PyTree, *, step: Optional[int] = None,
                ) -> Tuple[PyTree, dict]:
        """Restore into the structure of ``template`` (shapes verified)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        names, treedef = _flatten_with_names(template)
        leaves = {}
        for key, tmpl in names.items():
            arr = data[key]
            want = tuple(getattr(tmpl, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
            leaves[key] = arr
        flat, _ = jax.tree_util.tree_flatten_with_path(template)
        ordered = []
        for path, _leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            ordered.append(leaves[key])
        tree = jax.tree_util.tree_unflatten(treedef, ordered)
        return tree, manifest["extra"]

    def manifest(self, step: int) -> dict:
        d = os.path.join(self.root, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)
