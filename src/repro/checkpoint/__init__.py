"""Checkpointing: sharded save/restore, async writer, retention, elastic."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
