"""Deterministic, resumable, shardable data pipeline.

Design goals (the fault-tolerance story depends on all three):
  * **Deterministic**: batch t is a pure function of (seed, step) — no
    hidden RNG state, so a restore at step t replays batch t exactly.
  * **Resumable**: ``DataState`` is a tiny pytree saved inside checkpoints;
    restoring it resumes mid-epoch with zero drift.
  * **Shardable**: each data-parallel host takes a disjoint slice of every
    global batch (``host_index``/``host_count``), matching how batches are
    fed to a ``("pod","data")``-sharded global array.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional

import numpy as np


@dataclass(frozen=True)
class DataState:
    seed: int
    step: int

    def next(self) -> "DataState":
        return replace(self, step=self.step + 1)


class ShardedDataLoader:
    """Samples global batches from in-memory arrays (or a factory fn).

    ``arrays`` is a dict of equally-lengthed numpy arrays; every batch is a
    dict of slices along axis 0.  Sampling is with replacement from a
    per-step PRNG stream: batch(t) == batch(t) always.
    """

    def __init__(self, arrays: Dict[str, np.ndarray], *, global_batch: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 transform: Optional[Callable[[Dict[str, np.ndarray], np.random.Generator], Dict[str, np.ndarray]]] = None):
        lens = {len(v) for v in arrays.values()}
        assert len(lens) == 1, "all arrays must share axis-0 length"
        self.n = lens.pop()
        assert global_batch % host_count == 0, "global batch must split across hosts"
        self.arrays = arrays
        self.global_batch = global_batch
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = global_batch // host_count
        self.transform = transform
        self.state = DataState(seed=seed, step=0)

    def batch_at(self, state: DataState) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((state.seed << 20) ^ state.step)
        idx = rng.integers(0, self.n, size=(self.global_batch,))
        lo = self.host_index * self.local_batch
        sel = idx[lo: lo + self.local_batch]
        batch = {k: v[sel] for k, v in self.arrays.items()}
        if self.transform is not None:
            batch = self.transform(batch, rng)
        return batch

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state)
        self.state = self.state.next()
        return b

    def __iter__(self):
        return self

    # -- checkpoint integration ------------------------------------------------

    def state_dict(self) -> dict:
        return {"seed": self.state.seed, "step": self.state.step}

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState(seed=int(d["seed"]), step=int(d["step"]))

    def skip_to(self, step: int) -> None:
        """Fast-forward (e.g. after restoring a checkpoint written at step t)."""
        self.state = DataState(seed=self.state.seed, step=step)
