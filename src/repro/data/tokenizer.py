"""Hash tokenizer — deterministic, dependency-free word-level tokenizer.

Words map to ids via a stable FNV hash into a fixed vocab.  Reserved ids:
0 = PAD, 1 = BOS, 2 = EOS.  Good enough for the LM smoke paths and the
text towers; the full-size archs only ever see ShapeDtypeStructs.
"""
from __future__ import annotations

import re
from typing import List, Sequence

import numpy as np

from repro.utils import stable_hash

_WORD_RE = re.compile(r"[a-zA-Z']+|[0-9]+|[^\sa-zA-Z0-9]")


class HashTokenizer:
    PAD, BOS, EOS = 0, 1, 2
    N_RESERVED = 3

    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > self.N_RESERVED
        self.vocab_size = vocab_size

    def encode(self, text: str, *, max_len: int, add_bos: bool = True,
               add_eos: bool = True) -> np.ndarray:
        words = _WORD_RE.findall(text.lower())
        ids = [self.N_RESERVED + stable_hash(w, self.vocab_size - self.N_RESERVED)
               for w in words]
        if add_bos:
            ids = [self.BOS] + ids
        if add_eos:
            ids = ids + [self.EOS]
        ids = ids[:max_len]
        out = np.full((max_len,), self.PAD, np.int32)
        out[: len(ids)] = ids
        return out

    def encode_batch(self, texts: Sequence[str], *, max_len: int) -> np.ndarray:
        return np.stack([self.encode(t, max_len=max_len) for t in texts])

    def lengths(self, batch: np.ndarray) -> np.ndarray:
        return (batch != self.PAD).sum(axis=-1)
