"""Data substrate: procedural captioned-image corpus + sharded pipeline."""
from repro.data.synthetic import (  # noqa: F401
    SceneSpec, make_corpus, render_caption, render_scene, caption_of,
    parse_caption, random_spec,
)
from repro.data.tokenizer import HashTokenizer  # noqa: F401
from repro.data.pipeline import ShardedDataLoader, DataState  # noqa: F401
