"""Procedural captioned-image corpus (stands in for COCO/DiffusionDB/Flickr30k).

Scenes are parameterised by (shape, color, background, size, position); each
spec renders deterministically to an image and captions deterministically to
a natural-language template.  Crucially the caption is *parseable back* to
the spec, which gives the offline CLIP proxy its cross-modal alignment: the
text tower renders the parsed caption and embeds the canonical render.

The structural-similarity property the paper leans on ("a bird and an
airplane might share a reference despite unrelated semantics") is modelled
by shapes sharing layout: e.g. 'ring' and 'circle' at the same position
have nearly identical structure but different captions.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

SHAPES = ("circle", "square", "triangle", "cross", "ring")
COLORS = {
    "red": (1.0, -0.7, -0.7), "green": (-0.7, 1.0, -0.7), "blue": (-0.7, -0.7, 1.0),
    "yellow": (1.0, 1.0, -0.7), "purple": (0.6, -0.7, 1.0), "orange": (1.0, 0.2, -0.8),
    "white": (1.0, 1.0, 1.0), "cyan": (-0.7, 1.0, 1.0),
}
BACKGROUNDS = {
    "black": (-1.0, -1.0, -1.0), "gray": (0.0, 0.0, 0.0), "navy": (-0.8, -0.8, -0.2),
    "olive": (-0.2, -0.2, -0.8), "maroon": (-0.2, -0.8, -0.8), "teal": (-0.8, -0.2, -0.2),
}
SIZES = {"small": 0.18, "medium": 0.3, "large": 0.42}
POSITIONS = {"left": (-0.4, 0.0), "center": (0.0, 0.0), "right": (0.4, 0.0)}


@dataclass(frozen=True)
class SceneSpec:
    shape: str = "circle"
    color: str = "red"
    background: str = "black"
    size: str = "medium"
    position: str = "center"

    def key(self) -> Tuple[str, str, str, str, str]:
        return (self.shape, self.color, self.background, self.size, self.position)


def random_spec(rng: np.random.Generator) -> SceneSpec:
    return SceneSpec(
        shape=rng.choice(SHAPES),
        color=rng.choice(list(COLORS)),
        background=rng.choice(list(BACKGROUNDS)),
        size=rng.choice(list(SIZES)),
        position=rng.choice(list(POSITIONS)),
    )


def caption_of(spec: SceneSpec) -> str:
    return (f"a {spec.size} {spec.color} {spec.shape} at the {spec.position} "
            f"on a {spec.background} background")


_CAP_RE = re.compile(
    rf"(?P<size>{'|'.join(SIZES)})?\s*(?P<color>{'|'.join(COLORS)})?\s*"
    rf"(?P<shape>{'|'.join(SHAPES)})")


def parse_caption(text: str) -> SceneSpec:
    """Best-effort inverse of ``caption_of`` (robust to reordered phrases —
    the prompt optimizer permutes phrase order)."""
    t = text.lower()

    def find(options, default):
        for o in options:
            if re.search(rf"\b{o}\b", t):
                return o
        return default

    shape = find(SHAPES, "circle")
    size = find(SIZES, "medium")
    position = find(POSITIONS, "center")
    background = "black"
    m = re.search(rf"on an? (\w+) background", t)
    if m and m.group(1) in BACKGROUNDS:
        background = m.group(1)
    else:
        # phrase reordering (prompt optimizer) may strip the "on";
        # background words are disjoint from color words, so a bare
        # mention is unambiguous
        background = find(BACKGROUNDS, "black")
    # color: first color word that is not the background
    color = "red"
    for c in COLORS:
        if re.search(rf"\b{c}\b", t):
            color = c
            break
    return SceneSpec(shape, color, background, size, position)


def render_scene(spec: SceneSpec, res: int = 32) -> np.ndarray:
    """Render to (res, res, 3) float32 in [-1, 1]."""
    yy, xx = np.meshgrid(np.linspace(-1, 1, res), np.linspace(-1, 1, res),
                         indexing="ij")
    cx, cy = POSITIONS[spec.position]
    r = SIZES[spec.size]
    dx, dy = xx - cx, yy - cy
    if spec.shape == "circle":
        mask = dx * dx + dy * dy <= r * r
    elif spec.shape == "ring":
        d2 = dx * dx + dy * dy
        mask = (d2 <= r * r) & (d2 >= (0.55 * r) ** 2)
    elif spec.shape == "square":
        mask = (np.abs(dx) <= r) & (np.abs(dy) <= r)
    elif spec.shape == "triangle":
        mask = (dy >= -r) & (np.abs(dx) <= (r - dy) * 0.5) & (dy <= r)
    elif spec.shape == "cross":
        mask = ((np.abs(dx) <= 0.3 * r) & (np.abs(dy) <= r)) | \
               ((np.abs(dy) <= 0.3 * r) & (np.abs(dx) <= r))
    else:  # pragma: no cover
        raise ValueError(spec.shape)
    img = np.empty((res, res, 3), np.float32)
    img[:] = np.asarray(BACKGROUNDS[spec.background], np.float32)
    img[mask] = np.asarray(COLORS[spec.color], np.float32)
    return img


def render_caption(caption: str, res: int = 32) -> np.ndarray:
    """Canonical render of a caption (the proxy embedder's text path)."""
    return render_scene(parse_caption(caption), res)


def make_corpus(n: int, *, res: int = 32, seed: int = 0,
                specs: Optional[Sequence[SceneSpec]] = None,
                ) -> Tuple[np.ndarray, List[str], List[SceneSpec]]:
    """Corpus of (images, captions, specs). Deterministic in (n, res, seed)."""
    rng = np.random.default_rng(seed)
    if specs is None:
        specs = [random_spec(rng) for _ in range(n)]
    images = np.stack([render_scene(s, res) for s in specs])
    # mild per-image noise so corpus images are not pixel-identical to renders
    images = images + rng.normal(0, 0.02, images.shape).astype(np.float32)
    images = np.clip(images, -1, 1)
    captions = [caption_of(s) for s in specs]
    return images.astype(np.float32), captions, list(specs)


def all_specs() -> List[SceneSpec]:
    out = []
    for sh in SHAPES:
        for c in COLORS:
            for b in BACKGROUNDS:
                for sz in SIZES:
                    for p in POSITIONS:
                        out.append(SceneSpec(sh, c, b, sz, p))
    return out
