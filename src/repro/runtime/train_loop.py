"""Fault-tolerant training loop.

The contract targeted at 1000+ nodes, exercised here single-process:

* **Checkpoint/restart** — periodic async checkpoints (atomic publish);
  on (re)start the loop restores the newest checkpoint including the data
  iterator state, so batch t is replayed exactly (the pipeline is a pure
  function of (seed, step)).
* **NaN / divergence rollback** — a non-finite loss triggers a rollback to
  the last checkpoint and a ``skip_batches`` fast-forward of the data
  iterator past the poisonous window (standard large-run practice).
* **Straggler mitigation** — per-step wall times feed an EMA; steps slower
  than ``straggler_factor`` × EMA are counted and surfaced through
  ``LoopReport.straggler_steps``; the hook ``on_straggler`` lets a cluster
  driver rebalance (in the paper's terms: the request-scheduler's
  queue-depth penalty is the serving-side twin of this).
* **Failure injection** — ``fail_at`` aborts mid-run to let the tests prove
  the restart path is bitwise-exact.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import ShardedDataLoader

PyTree = Any


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    skip_batches_on_rollback: int = 1
    straggler_factor: float = 3.0
    max_rollbacks: int = 3
    fail_at: Optional[int] = None        # simulate a node failure at step N


@dataclass
class LoopReport:
    steps_done: int = 0
    rollbacks: int = 0
    restarts: int = 0
    straggler_steps: int = 0
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def run_training(step_fn: Callable[[PyTree, Dict[str, np.ndarray]], Any],
                 state: PyTree,
                 loader: ShardedDataLoader,
                 ckpt: CheckpointManager,
                 cfg: LoopConfig,
                 *,
                 on_metrics: Optional[Callable[[int, Dict], None]] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 ) -> tuple:
    """Run (or resume) training.  Returns (state, LoopReport)."""
    report = LoopReport()
    jit_step = jax.jit(step_fn, donate_argnums=0)

    # resume from the newest checkpoint if one exists -----------------------
    latest = ckpt.latest_step()
    if latest is not None:
        state, extra = ckpt.restore(state)
        loader.load_state_dict(extra["data"])
        start = int(extra["step"])
        report.restarts += 1
    else:
        start = 0

    ema = None
    step = start
    while step < cfg.total_steps:
        if cfg.fail_at is not None and step == cfg.fail_at:
            ckpt.wait()
            raise SimulatedFailure(f"injected failure at step {step}")

        batch = next(loader)
        t0 = time.perf_counter()
        state, metrics = jit_step(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        report.step_times.append(dt)

        # straggler detection (wall-time EMA) ---------------------------
        if ema is None:
            ema = dt
        else:
            if dt > cfg.straggler_factor * ema:
                report.straggler_steps += 1
                if on_straggler is not None:
                    on_straggler(step, dt / ema)
            ema = 0.9 * ema + 0.1 * dt

        # NaN rollback ---------------------------------------------------
        if not np.isfinite(loss):
            if report.rollbacks >= cfg.max_rollbacks:
                raise FloatingPointError(
                    f"loss non-finite at step {step}; rollback budget spent")
            report.rollbacks += 1
            ckpt.wait()
            prev = ckpt.latest_step()
            if prev is None:
                raise FloatingPointError("loss non-finite before first ckpt")
            state, extra = ckpt.restore(state)
            loader.load_state_dict(extra["data"])
            # Skip the data window PAST the poisoned batch (skipping only
            # relative to the checkpoint would replay the same batch and
            # loop forever).  ``step`` is the index of the failed batch.
            loader.skip_to(step + cfg.skip_batches_on_rollback)
            step = int(extra["step"])
            continue

        report.losses.append(loss)
        report.steps_done += 1
        step += 1

        if on_metrics is not None and step % cfg.log_every == 0:
            on_metrics(step, {k: float(v) for k, v in metrics.items()})

        if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
            ckpt.save_async(step, state,
                            extra={"step": step, "data": loader.state_dict()})

    ckpt.wait()
    return state, report
