"""Serving engine: CacheGenius front-end over a jitted diffusion backend.

This is the deployment-shaped layer: the paper's §V "asynchronous task
queue" in front of the Fig. 5 pipeline.  Three pieces:

* :class:`DiffusionBackend` — AOT-compiled txt2img / img2img samplers for a
  (tiny or full) DiT + VAE.  Every (workflow × step-count × batch-bucket)
  is compiled once up front (``precompile``), the TPU-side answer to the
  paper's Docker cold-start fix (§V: "rebuilding the image with
  preinstalled dependencies" → here: persistent compile cache + AOT).
* :class:`ServingEngine` — the request queue over the CacheGenius
  orchestrator, with TWO draining disciplines:

  - ``run(arrivals, mode="continuous")`` — **continuous batching**, the
    primary path.  An event-driven loop consumes a timestamped arrival
    process (:func:`repro.core.trace.poisson_arrivals` /
    ``trace_arrivals`` / ``bursty_arrivals``) on a virtual clock that
    advances by measured service wall time.  Whenever the in-flight step
    group (one staged-pipeline pass, i.e. one set of AOT generation
    buckets) completes, everything that has arrived in the meantime is
    admitted into the next group — up to ``max_batch`` — so a request
    never waits for a drain boundary, only for the group ahead of it.
    ``mode="drain"`` is the fixed-drain baseline at the same offered
    load: a bucket closes only when ``max_batch`` requests have arrived
    (or the trace ends), so stragglers wait out the fill time — the
    behaviour whose p95 queue delay the continuous mode beats under
    bursty traffic.  ``run(..., step_level=True)`` sharpens admission
    from step-GROUP to step granularity: a persistent slot engine
    (:class:`DiffusionSlotEngine` / :class:`EmulatedSlotEngine`)
    advances a ragged in-flight set one denoising step per compiled
    ``step_slots`` launch, admitting arrivals into free slots at ANY
    step boundary and retiring each chain the step it ends, while
    Archive/Finish run in submission order so every observable matches
    the group modes exactly.
  - ``submit`` + ``drain()`` — the legacy closed-loop surface: everything
    is queued up front and drained in FIFO micro-batches.

  Either way each ``Completed`` carries a TRUE ``queue_delay`` (time the
  request actually waited before its pipeline admission, from the
  per-stage timestamps — not submission-clock ticks) and a result with
  ``wall_total`` + per-stage ``stage_walls``.  Node failures reroute
  through ``CacheGenius.fail_node``.
* :class:`LMResponseCache` — the beyond-paper adaptation for the LM archs
  (DESIGN.md §Arch-applicability): GPTCache-style semantic response cache
  in front of decode; exact analog of Algorithm 1's HIT_RETURN branch with
  no img2img middle band (tokens are discrete).

Invariants (pinned by ``tests/test_serving_continuous.py`` and, for the
step-level mode, the ragged-admission property suite in
``tests/test_step_level.py``): on traces where batched/sequential parity
holds, continuous-mode results are a permutation (in fact,
arrival-order-identical) of fixed-drain results — batch partitioning
never changes routes, images, cache state, or hit/miss stats, and
step-level slot admission reproduces both bitwise for any slot capacity;
widely spaced single submissions reproduce sequential ``serve``
bitwise; and a run whose group sizes stay inside the precompiled buckets
triggers no JIT at serve time (step-level runs reuse exactly ONE
``step_slots`` executable per slot capacity).  The eviction sweep fires at EXACT
request-count crossings inside the Finish stage (archives past the
boundary are deferred and flushed per request), so sub-batch maintenance
intervals keep their sequential cadence — no interval clamp is needed.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import TransientBackendError
from repro.core.system import CacheGenius, GenerationBackend, Plan, \
    ServeResult
from repro.core.trace import TimedRequest
from repro.models.diffusion import dit as dit_mod
from repro.models.diffusion import vae as vae_mod
from repro.models.diffusion.sampler import (ddim_sample, ddim_timesteps,
                                            resume_noise_levels,
                                            resume_sample, sdedit_start,
                                            step_slots)
from repro.models.diffusion.schedule import DiffusionSchedule
from repro.utils import next_pow2


# ---------------------------------------------------------------------------
# diffusion backend (AOT-bucketed samplers)
# ---------------------------------------------------------------------------


class DiffusionBackend(GenerationBackend):
    """txt2img/img2img over a DiT+VAE with per-(kind, steps, batch) AOT
    compilation.  ``embed_prompt`` maps a prompt to the conditioning vector
    (injected; the benchmarks use the proxy CLIP embedder).

    Implements the batch-first ``GenerationBackend`` protocol directly
    (``txt2img_batch`` / ``img2img_batch`` are the required surface; the
    scalar overrides below hit the batch=1 AOT bucket without the padding
    plumbing), plus the latent-depth cache surface: ``resume_batch``
    resumes the truncated img2img DDIM chain from an archived depth-k
    latent (AOT kind ``"resume@k"``), and ``archive_latents_batch``
    produces the noised intermediates to archive (kind
    ``"latents@k1,k2,..."``) — both bucketed exactly like the classic
    kinds, so every (kind, steps, batch) compiles once."""

    supports_latent_resume = True

    def __init__(self, net_params, net_cfg: dit_mod.DiTConfig, vae_params,
                 vae_cfg: vae_mod.VAEConfig,
                 embed_prompt: Callable[[str], np.ndarray],
                 *, schedule: Optional[DiffusionSchedule] = None,
                 latent_scale: float = 1.0,
                 img2img_strength: float = 0.6):
        self.net_params = net_params
        self.net_cfg = net_cfg
        self.vae_params = vae_params
        self.vae_cfg = vae_cfg
        self.embed_prompt = embed_prompt
        self.sched = schedule or DiffusionSchedule.linear(1000)
        self.latent_scale = latent_scale
        self.strength = img2img_strength
        self._compiled: Dict[Tuple[str, int, int], Any] = {}
        self.compile_seconds: Dict[Tuple[str, int, int], float] = {}

    # -- jittable cores -----------------------------------------------------
    #
    # Both cores take a VECTOR of per-request seeds: each batch element's
    # initial noise is drawn exactly as the sequential batch=1 path draws
    # it (vmap of split+normal over the element's own PRNGKey), so batching
    # requests never changes any individual request's sample trajectory.

    def _txt2img_core(self, net, vae, ctx, seeds, steps: int, batch: int):
        eps = dit_mod.make_eps_fn(net, self.net_cfg)
        el_shape = (self.net_cfg.img_res, self.net_cfg.img_res,
                    self.net_cfg.in_ch)

        def _noise(seed):
            k_noise, _ = jax.random.split(jax.random.PRNGKey(seed))
            return jax.random.normal(k_noise, (1,) + el_shape)[0]

        x_init = jax.vmap(_noise)(seeds)
        z = ddim_sample(eps, self.sched, (batch,) + el_shape, ctx,
                        jax.random.PRNGKey(0), steps=steps, x_init=x_init)
        return vae_mod.decode(vae, self.vae_cfg, z / self.latent_scale)

    def _img2img_core(self, net, vae, ref_img, ctx, seeds, steps: int):
        eps = dit_mod.make_eps_fn(net, self.net_cfg)
        mean, _ = vae_mod.encode(vae, self.vae_cfg, ref_img)
        z_ref = mean * self.latent_scale

        def _noise(seed, z1):
            k1, _ = jax.random.split(jax.random.PRNGKey(seed))
            return jax.random.normal(k1, (1,) + z1.shape)[0]

        noise = jax.vmap(_noise)(seeds, z_ref)
        x_init, t_start = sdedit_start(self.sched, z_ref, noise,
                                       strength=self.strength)
        z = ddim_sample(eps, self.sched, z_ref.shape, ctx,
                        jax.random.PRNGKey(0), steps=steps,
                        x_init=x_init, t_start=t_start)
        return vae_mod.decode(vae, self.vae_cfg, z / self.latent_scale)

    def _resume_core(self, net, vae, latent, ctx, steps_total: int, k: int):
        eps = dit_mod.make_eps_fn(net, self.net_cfg)
        z = resume_sample(eps, self.sched, latent, ctx, steps=steps_total,
                          k=k, strength=self.strength)
        return vae_mod.decode(vae, self.vae_cfg, z / self.latent_scale)

    def _step_slots_core(self, net, x, ctx, t, t_prev, active):
        # ONE ragged denoising step over the slot buffer: per-slot
        # timesteps, inactive slots pass through (see sampler.step_slots)
        eps = dit_mod.make_eps_fn(net, self.net_cfg)
        return step_slots(eps, self.sched, x, ctx, t, t_prev, active)

    def _slot_noise_core(self, seeds):
        # txt2img slot init: EXACTLY _txt2img_core's per-seed noise draw,
        # so a slot trajectory starts where the batched sampler would
        el_shape = (self.net_cfg.img_res, self.net_cfg.img_res,
                    self.net_cfg.in_ch)

        def _noise(seed):
            k_noise, _ = jax.random.split(jax.random.PRNGKey(seed))
            return jax.random.normal(k_noise, (1,) + el_shape)[0]

        return jax.vmap(_noise)(seeds)

    def _slot_img_init_core(self, vae, ref_img, seeds):
        # img2img slot init: _img2img_core's encode + per-seed noise +
        # SDEdit start, stopping BEFORE the chain (the chain runs in the
        # step-level engine, one step_slots launch per boundary)
        mean, _ = vae_mod.encode(vae, self.vae_cfg, ref_img)
        z_ref = mean * self.latent_scale

        def _noise(seed, z1):
            k1, _ = jax.random.split(jax.random.PRNGKey(seed))
            return jax.random.normal(k1, (1,) + z1.shape)[0]

        noise = jax.vmap(_noise)(seeds, z_ref)
        x_init, _ = sdedit_start(self.sched, z_ref, noise,
                                 strength=self.strength)
        return x_init

    def _slot_decode_core(self, vae, z):
        return vae_mod.decode(vae, self.vae_cfg, z / self.latent_scale)

    def _archive_latents_core(self, vae, images, seeds, depths, steps_total):
        # noised intermediates of the img2img chain each image WOULD run:
        # the same encode + per-seed noise draw as _img2img_core, pushed
        # to resume_noise_levels()[k] — depth 0 equals sdedit_start's
        # x_init exactly, so resume(k=0) replays full img2img
        mean, _ = vae_mod.encode(vae, self.vae_cfg, images)
        z0 = mean * self.latent_scale

        def _noise(seed, z1):
            k1, _ = jax.random.split(jax.random.PRNGKey(seed))
            return jax.random.normal(k1, (1,) + z1.shape)[0]

        noise = jax.vmap(_noise)(seeds, z0)
        levels = resume_noise_levels(self.sched, steps=steps_total,
                                     strength=self.strength)
        b = images.shape[0]
        return jnp.stack([
            self.sched.q_sample(z0, jnp.full((b,), levels[k], jnp.int32),
                                noise)
            for k in depths])

    # -- AOT bucket management -----------------------------------------------

    def _get(self, kind: str, steps: int, batch: int):
        key = (kind, steps, batch)
        if key not in self._compiled:
            t0 = time.perf_counter()
            res = self.vae_cfg.downsample * self.net_cfg.img_res
            lat_sds = jax.ShapeDtypeStruct(
                (batch, self.net_cfg.img_res, self.net_cfg.img_res,
                 self.net_cfg.in_ch), jnp.float32)
            if kind == "txt2img":
                fn = jax.jit(lambda n, v, c, s: self._txt2img_core(
                    n, v, c, s, steps, batch))
                args = (self.net_params, self.vae_params,
                        jax.ShapeDtypeStruct((batch, self.net_cfg.ctx_dim),
                                             jnp.float32),
                        jax.ShapeDtypeStruct((batch,), jnp.int32))
            elif kind.startswith("resume@"):
                k = int(kind.split("@", 1)[1])
                fn = jax.jit(lambda n, v, l, c: self._resume_core(
                    n, v, l, c, steps, k))
                args = (self.net_params, self.vae_params, lat_sds,
                        jax.ShapeDtypeStruct((batch, self.net_cfg.ctx_dim),
                                             jnp.float32))
            elif kind.startswith("latents@"):
                depths = tuple(int(d) for d in
                               kind.split("@", 1)[1].split(","))
                fn = jax.jit(lambda v, i, s: self._archive_latents_core(
                    v, i, s, depths, steps))
                args = (self.vae_params,
                        jax.ShapeDtypeStruct((batch, res, res, 3),
                                             jnp.float32),
                        jax.ShapeDtypeStruct((batch,), jnp.int32))
            elif kind == "step_slots":
                # steps is 0 for slot kinds: ONE compiled program per slot
                # capacity covers every mixture of per-slot step counts
                fn = jax.jit(lambda n, x, c, t, tp, a: self._step_slots_core(
                    n, x, c, t, tp, a))
                args = (self.net_params, lat_sds,
                        jax.ShapeDtypeStruct((batch, self.net_cfg.ctx_dim),
                                             jnp.float32),
                        jax.ShapeDtypeStruct((batch,), jnp.int32),
                        jax.ShapeDtypeStruct((batch,), jnp.int32),
                        jax.ShapeDtypeStruct((batch,), jnp.bool_))
            elif kind == "slot_noise":
                fn = jax.jit(self._slot_noise_core)
                args = (jax.ShapeDtypeStruct((batch,), jnp.int32),)
            elif kind == "slot_img_init":
                fn = jax.jit(lambda v, r, s: self._slot_img_init_core(
                    v, r, s))
                args = (self.vae_params,
                        jax.ShapeDtypeStruct((batch, res, res, 3),
                                             jnp.float32),
                        jax.ShapeDtypeStruct((batch,), jnp.int32))
            elif kind == "slot_decode":
                fn = jax.jit(lambda v, z: self._slot_decode_core(v, z))
                args = (self.vae_params, lat_sds)
            else:
                fn = jax.jit(lambda n, v, r, c, s: self._img2img_core(
                    n, v, r, c, s, steps))
                args = (self.net_params, self.vae_params,
                        jax.ShapeDtypeStruct((batch, res, res, 3), jnp.float32),
                        jax.ShapeDtypeStruct((batch, self.net_cfg.ctx_dim),
                                             jnp.float32),
                        jax.ShapeDtypeStruct((batch,), jnp.int32))
            self._compiled[key] = fn.lower(
                *jax.tree_util.tree_map(_to_sds, args)).compile()
            self.compile_seconds[key] = time.perf_counter() - t0
        return self._compiled[key]

    def precompile(self, *, step_buckets: Sequence[int] = (20, 30),
                   batch_buckets: Sequence[int] = (1,),
                   kinds: Sequence[str] = ("txt2img", "img2img")) -> float:
        """Compile every serving bucket up front; returns total seconds.
        This removes generation-path cold starts entirely.  ``kinds``
        restricts the workflow sweep when a policy pins each workflow to
        one step count (txt2img at steps_full, img2img at steps_ref)."""
        t0 = time.perf_counter()
        for b in batch_buckets:
            for s in step_buckets:
                for kind in kinds:
                    self._get(kind, s, b)
        return time.perf_counter() - t0

    def precompile_step_level(self, slot_capacity: int) -> float:
        """Compile the step-level serving buckets: ONE ``step_slots``
        program at the slot capacity (covering every ragged step mixture)
        plus the batch-of-one slot init/decode programs.  Returns total
        seconds."""
        t0 = time.perf_counter()
        self._get("step_slots", 0, slot_capacity)
        self._get("slot_noise", 0, 1)
        self._get("slot_img_init", 0, 1)
        self._get("slot_decode", 0, 1)
        return time.perf_counter() - t0

    def make_slot_engine(self, capacity: int) -> "DiffusionSlotEngine":
        return DiffusionSlotEngine(self, capacity)

    # -- GenerationBackend interface ------------------------------------------

    def txt2img(self, prompt: str, steps: int, seed: int) -> np.ndarray:
        ctx = jnp.asarray(self.embed_prompt(prompt), jnp.float32)[None]
        fn = self._get("txt2img", steps, 1)
        out = fn(self.net_params, self.vae_params, ctx,
                 jnp.asarray([seed], jnp.int32))
        return np.asarray(out[0])

    def img2img(self, prompt: str, reference: np.ndarray, steps: int,
                seed: int) -> np.ndarray:
        ctx = jnp.asarray(self.embed_prompt(prompt), jnp.float32)[None]
        fn = self._get("img2img", steps, 1)
        out = fn(self.net_params, self.vae_params,
                 jnp.asarray(reference, jnp.float32)[None], ctx,
                 jnp.asarray([seed], jnp.int32))
        return np.asarray(out[0])

    # -- batched entry points --------------------------------------------------

    @staticmethod
    def _bucket(n: int) -> int:
        """Pad a group to the next power-of-two AOT bucket so a handful of
        compiled programs covers every batch size."""
        return next_pow2(n)

    def _pad_ctx_seeds(self, prompts: Sequence[str], seeds: Sequence[int],
                       bucket: int):
        ctx = np.stack([np.asarray(self.embed_prompt(p), np.float32)
                        for p in prompts])
        pad = bucket - len(prompts)
        if pad:
            ctx = np.concatenate([ctx, np.repeat(ctx[-1:], pad, axis=0)])
        seeds_arr = np.asarray(list(seeds) + [0] * pad, np.int32)
        return jnp.asarray(ctx), jnp.asarray(seeds_arr)

    def txt2img_batch(self, prompts: Sequence[str], steps: int,
                      seeds: Sequence[int]) -> np.ndarray:
        """Batched text-to-image: one padded AOT call for the whole group.
        Element i equals ``txt2img(prompts[i], steps, seeds[i])`` up to XLA
        batching numerics (identical noise trajectories by construction)."""
        n = len(prompts)
        if n == 0:
            res = self.vae_cfg.downsample * self.net_cfg.img_res
            return np.zeros((0, res, res, 3), np.float32)
        bucket = self._bucket(n)
        ctx, seeds_arr = self._pad_ctx_seeds(prompts, seeds, bucket)
        fn = self._get("txt2img", steps, bucket)
        out = fn(self.net_params, self.vae_params, ctx, seeds_arr)
        return np.asarray(out[:n])

    def img2img_batch(self, prompts: Sequence[str], references: np.ndarray,
                      steps: int, seeds: Sequence[int]) -> np.ndarray:
        """Batched SDEdit img2img over stacked references (B, H, W, 3)."""
        n = len(prompts)
        if n == 0:
            res = self.vae_cfg.downsample * self.net_cfg.img_res
            return np.zeros((0, res, res, 3), np.float32)
        bucket = self._bucket(n)
        ctx, seeds_arr = self._pad_ctx_seeds(prompts, seeds, bucket)
        refs = np.asarray(references, np.float32)
        pad = bucket - n
        if pad:
            refs = np.concatenate([refs, np.repeat(refs[-1:], pad, axis=0)])
        fn = self._get("img2img", steps, bucket)
        out = fn(self.net_params, self.vae_params, jnp.asarray(refs), ctx,
                 seeds_arr)
        return np.asarray(out[:n])

    # -- latent-depth cache surface -------------------------------------------

    def resume_batch(self, prompts: Sequence[str], latents: np.ndarray,
                     steps_total: int, k: int,
                     seeds: Sequence[int]) -> np.ndarray:
        """Resume the ``steps_total``-step img2img chain from depth ``k``
        for a stacked batch of archived latents (no noise draw — the
        latents are pre-noised at archive time, so ``seeds`` only shapes
        the padding)."""
        n = len(prompts)
        if n == 0:
            res = self.vae_cfg.downsample * self.net_cfg.img_res
            return np.zeros((0, res, res, 3), np.float32)
        bucket = self._bucket(n)
        ctx, _ = self._pad_ctx_seeds(prompts, seeds, bucket)
        lats = np.asarray(latents, np.float32)
        pad = bucket - n
        if pad:
            lats = np.concatenate([lats, np.repeat(lats[-1:], pad, axis=0)])
        fn = self._get(f"resume@{int(k)}", steps_total, bucket)
        out = fn(self.net_params, self.vae_params, jnp.asarray(lats), ctx)
        return np.asarray(out[:n])

    def archive_latents_batch(self, images: np.ndarray,
                              seeds: Sequence[int],
                              depths: Sequence[int],
                              steps_total: int) -> np.ndarray:
        """Noised img2img-chain intermediates of each image at every
        requested depth — ``(len(depths), B, img_res, img_res, in_ch)``.
        The per-image noise reuses the archive ``seed`` through the SAME
        draw as ``_img2img_core``, so depth 0 is bitwise the SDEdit
        initial state of ``img2img(image, seed)``."""
        imgs = np.asarray(images, np.float32)
        n = imgs.shape[0]
        if n == 0:
            return np.zeros((len(depths), 0, self.net_cfg.img_res,
                             self.net_cfg.img_res, self.net_cfg.in_ch),
                            np.float32)
        bucket = self._bucket(n)
        pad = bucket - n
        if pad:
            imgs = np.concatenate([imgs, np.repeat(imgs[-1:], pad, axis=0)])
        seeds_arr = jnp.asarray(np.asarray(list(seeds) + [0] * pad,
                                           np.int32))
        kind = "latents@" + ",".join(str(int(d)) for d in depths)
        fn = self._get(kind, steps_total, bucket)
        out = fn(self.vae_params, jnp.asarray(imgs), seeds_arr)
        return np.asarray(out)[:, :n]

    def as_generation_backend(self) -> GenerationBackend:
        """Compatibility shim: DiffusionBackend now IS a GenerationBackend
        (batch-first protocol), so this is the identity."""
        return self


def _to_sds(x):
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


# ---------------------------------------------------------------------------
# step-level slot engines (ragged in-flight set, one denoising step / call)
# ---------------------------------------------------------------------------


class DiffusionSlotEngine:
    """Persistent step-wise sampler over a fixed-capacity slot buffer.

    Each occupied slot holds one in-flight generation request's latent,
    conditioning vector and DDIM timestep sub-sequence; every
    :meth:`step` call advances ALL active slots one denoising step through
    a single AOT-compiled ``("step_slots", 0, capacity)`` launch with
    per-slot timesteps, so requests with mixed step counts (K-step
    txt2img misses, truncated img2img band hits, ``resume@k`` latent-depth
    hits) enter and retire at ANY step boundary.

    Slot init reuses the batched cores' exact seed→noise draws
    (``slot_noise`` / ``slot_img_init``) and the per-kind timestep
    geometry of ``ddim_sample`` / ``resume_sample``, so a slot trajectory
    is the same chain the group sampler would run — only the launch
    granularity changes.  ``progress[handle]`` records the slot's step
    index after each advance (strictly monotone; pinned by the
    ragged-admission property suite) and ``step_calls`` counts compiled
    launches (exactly one executable per slot capacity)."""

    def __init__(self, backend: "DiffusionBackend", capacity: int):
        self.backend = backend
        self.capacity = int(capacity)
        cfg = backend.net_cfg
        self._lat = np.zeros((capacity, cfg.img_res, cfg.img_res,
                              cfg.in_ch), np.float32)
        self._ctx = np.zeros((capacity, cfg.ctx_dim), np.float32)
        self._active = np.zeros((capacity,), bool)
        self._ts: List[Optional[np.ndarray]] = [None] * capacity
        self._pos = [0] * capacity
        self._state: List[Optional[object]] = [None] * capacity
        self._handle = [-1] * capacity
        self.progress: Dict[int, List[int]] = {}
        self.step_calls = 0

    def free_count(self) -> int:
        return int(self.capacity - self._active.sum())

    def active_count(self) -> int:
        return int(self._active.sum())

    def admit(self, state, handle: int) -> None:
        """Seat one planned ``gen`` request in a free slot: compute its
        initial latent (per-request seed-noise semantics preserved) and
        its DDIM timestep sub-sequence."""
        b = self.backend
        plan = state.plan
        slot = int(np.argmin(self._active))
        if self._active[slot]:
            raise RuntimeError("slot engine is full")
        seeds = jnp.asarray([state.seed], jnp.int32)
        if plan.latent is not None:
            # resume@k: the last steps of the steps_total-step truncated
            # img2img chain (same geometry as resume_sample)
            steps_total = int(plan.steps) + int(plan.resume_k)
            ts = ddim_timesteps(b.sched.T, steps_total,
                                t_start=int(b.strength * b.sched.T))
            ts = np.asarray(ts[int(plan.resume_k):])
            x0 = np.asarray(plan.latent, np.float32)
        elif plan.ref is not None:
            ts = np.asarray(ddim_timesteps(
                b.sched.T, int(plan.steps),
                t_start=int(b.strength * b.sched.T)))
            fn = b._get("slot_img_init", 0, 1)
            x0 = np.asarray(fn(b.vae_params,
                               jnp.asarray(plan.ref, jnp.float32)[None],
                               seeds)[0])
        else:
            ts = np.asarray(ddim_timesteps(b.sched.T, int(plan.steps)))
            fn = b._get("slot_noise", 0, 1)
            x0 = np.asarray(fn(seeds)[0])
        self._lat[slot] = x0
        self._ctx[slot] = np.asarray(b.embed_prompt(state.prompt),
                                     np.float32)
        self._ts[slot] = ts
        self._pos[slot] = 0
        self._state[slot] = state
        self._handle[slot] = int(handle)
        self._active[slot] = True
        self.progress[int(handle)] = [0]

    def step(self) -> List[Tuple[int, object]]:
        """Advance every active slot one DDIM step (one compiled launch);
        decode and free slots whose chain just finished.  Returns the
        retired ``(handle, state)`` pairs (``state.image`` set)."""
        b = self.backend
        t = np.zeros((self.capacity,), np.int32)
        tp = np.full((self.capacity,), -1, np.int32)
        for i in range(self.capacity):
            if not self._active[i]:
                continue
            ts, p = self._ts[i], self._pos[i]
            t[i] = ts[p]
            tp[i] = ts[p + 1] if p + 1 < len(ts) else -1
        fn = b._get("step_slots", 0, self.capacity)
        out = fn(b.net_params, jnp.asarray(self._lat),
                 jnp.asarray(self._ctx), jnp.asarray(t), jnp.asarray(tp),
                 jnp.asarray(self._active))
        self._lat = np.array(out)   # copy: the slot buffer stays writable
        self.step_calls += 1
        retired: List[Tuple[int, object]] = []
        dec = b._get("slot_decode", 0, 1)
        for i in range(self.capacity):
            if not self._active[i]:
                continue
            self._pos[i] += 1
            self.progress[self._handle[i]].append(self._pos[i])
            if self._pos[i] >= len(self._ts[i]):
                img = np.asarray(dec(b.vae_params,
                                     jnp.asarray(self._lat[i])[None])[0])
                st = self._state[i]
                st.image = img
                retired.append((self._handle[i], st))
                self._active[i] = False
                self._ts[i] = None
                self._state[i] = None
                self._handle[i] = -1
        return retired


class EmulatedSlotEngine:
    """Slot-engine surface for generic :class:`GenerationBackend`\\ s (no
    resident latent state).  Each admitted request's image is computed at
    admission as a batch of ONE — element-for-element the call sequential
    ``serve`` makes, so step-level serving stays bitwise-identical on any
    deterministic backend — and the slot then counts down its plan's step
    budget so admission/retirement interleaving (and therefore clock,
    archive and maintenance order) matches the real slot engine's ragged
    schedule."""

    def __init__(self, system: CacheGenius, capacity: int):
        self.system = system
        self.capacity = int(capacity)
        self._remaining: List[int] = [0] * capacity
        self._state: List[Optional[object]] = [None] * capacity
        self._handle = [-1] * capacity
        self._active = np.zeros((capacity,), bool)
        self.progress: Dict[int, List[int]] = {}
        self.step_calls = 0

    def free_count(self) -> int:
        return int(self.capacity - self._active.sum())

    def active_count(self) -> int:
        return int(self._active.sum())

    def admit(self, state, handle: int) -> None:
        backend = self.system.backend
        plan = state.plan
        slot = int(np.argmin(self._active))
        if self._active[slot]:
            raise RuntimeError("slot engine is full")
        if plan.latent is not None:
            img = backend.resume_batch(
                [state.prompt], np.asarray(plan.latent)[None],
                int(plan.steps) + int(plan.resume_k), int(plan.resume_k),
                [state.seed])[0]
        elif plan.ref is not None:
            img = backend.img2img_batch(
                [state.prompt], np.asarray(plan.ref)[None],
                int(plan.steps), [state.seed])[0]
        else:
            img = backend.txt2img_batch(
                [state.prompt], int(plan.steps), [state.seed])[0]
        state.image = np.asarray(img)
        self._remaining[slot] = max(int(plan.steps), 1)
        self._state[slot] = state
        self._handle[slot] = int(handle)
        self._active[slot] = True
        self.progress[int(handle)] = [0]

    def step(self) -> List[Tuple[int, object]]:
        self.step_calls += 1
        retired: List[Tuple[int, object]] = []
        for i in range(self.capacity):
            if not self._active[i]:
                continue
            self._remaining[i] -= 1
            h = self._handle[i]
            self.progress[h].append(self.progress[h][-1] + 1)
            if self._remaining[i] <= 0:
                retired.append((h, self._state[i]))
                self._active[i] = False
                self._state[i] = None
                self._handle[i] = -1
        return retired


# ---------------------------------------------------------------------------
# batched request engine
# ---------------------------------------------------------------------------


@dataclass
class Request:
    prompt: str
    seed: int = 0
    quality_tier: bool = False
    submitted_at: float = 0.0   # perf_counter (drain) / virtual clock (run)
    # multi-tenant tags (None = untagged single-tenant traffic): set by
    # the front-door gateway and by tagged arrival processes; surfaced in
    # the per-(tenant, tier) latency percentiles (tenant_tier_stats)
    tenant: Optional[str] = None
    tier: Optional[str] = None


@dataclass
class Completed:
    request: Request
    result: ServeResult
    queue_delay: float          # seconds actually waited before admission
    finished_at: float = 0.0    # engine-clock instant the result came back


class ServingEngine:
    """Asynchronous-queue semantics (paper §V "asynchronous task queue")
    over ``CacheGenius.serve_batch``.

    ``run`` is the continuous-batching event loop over a timestamped
    arrival process; ``submit`` + ``drain`` is the legacy closed-loop
    surface (everything queued up front, FIFO micro-batches of
    ``max_batch``).  See the module docstring for the two draining
    disciplines and the timing/parity invariants.
    """

    def __init__(self, system: CacheGenius, *, max_batch: int = 8):
        self.system = system
        self.max_batch = max_batch
        self.queue: List[Request] = []
        self.completed: List[Completed] = []
        # step-level telemetry: active-slot count sampled before every
        # step launch of the most recent step_level=True run, plus the
        # engine itself (step_calls / progress / capacity introspection)
        self.slot_occupancy: List[int] = []
        self.last_slot_engine: Optional[object] = None
        # Maintenance intervals smaller than max_batch are honoured: the
        # Finish stage sweeps at exact request-count crossings (archives
        # past a crossing are deferred to the per-request result loop),
        # so the sweep cadence no longer depends on batch partitioning
        # and the old clamp-to-max_batch is gone.

    # -- legacy closed-loop surface -------------------------------------------

    def submit(self, prompt: str, *, seed: int = 0,
               quality_tier: bool = False) -> None:
        self.queue.append(Request(prompt, seed, quality_tier,
                                  submitted_at=time.perf_counter()))

    def serve_group(self, batch: Sequence[Request]) -> List[Completed]:
        """Serve ONE micro-batch (one step group) right now, wall-clock.

        This is the group-boundary primitive the front-door dispatcher
        pumps (``repro.frontdoor.dispatcher``): requests go through one
        staged-pipeline pass, ``queue_delay`` reports submission →
        pipeline admission on ``time.perf_counter`` (the clock
        ``submitted_at`` must be on), and completions are appended to
        ``self.completed`` in submission order.
        """
        if not batch:
            return []
        results = self.system.serve_batch(
            [r.prompt for r in batch],
            seeds=[r.seed for r in batch],
            quality_tiers=[r.quality_tier for r in batch],
            submitted_ats=[r.submitted_at for r in batch])
        done_at = time.perf_counter()
        out = [Completed(req, res, queue_delay=res.queue_delay,
                         finished_at=done_at)
               for req, res in zip(batch, results)]
        self.completed.extend(out)
        return out

    def drain(self) -> List[Completed]:
        """Serve the whole queue in FIFO micro-batches of ``max_batch``.

        ``queue_delay`` is the time each request ACTUALLY waited: from its
        ``submit`` instant to its micro-batch's pipeline admission, both on
        ``time.perf_counter`` (earlier revisions reported submission-clock
        ticks).  Within a micro-batch later submissions waited less; across
        micro-batches delays grow by the service time of the batches ahead.
        """
        out = []
        while self.queue:
            batch, self.queue = (self.queue[: self.max_batch],
                                 self.queue[self.max_batch:])
            out.extend(self.serve_group(batch))
        return out

    # -- continuous batching ----------------------------------------------------

    def run(self, arrivals: Iterable[TimedRequest], *,
            mode: str = "continuous", start: float = 0.0,
            step_level: bool = False, slot_capacity: Optional[int] = None,
            on_step: Optional[Callable[[int], None]] = None,
            ) -> List[Completed]:
        """Serve a timestamped arrival process; returns arrival order.

        The virtual clock starts at ``start`` and advances two ways: idling
        to the next arrival when nothing is queued, and by the MEASURED wall
        time of each staged-pipeline pass while serving — so simulated
        arrival gaps and real compute compose on one timeline.  When
        splitting one trace across several ``run`` calls (e.g. to fail a
        node between halves), pass the previous call's final
        ``finished_at`` as ``start`` so backlog carries over instead of the
        clock rewinding to the next arrival.

        ``mode="continuous"`` admits everything that has arrived (up to
        ``max_batch``) into the next generation bucket the moment the
        in-flight group completes.  ``mode="drain"`` is the fixed-drain
        baseline: a bucket closes only once ``max_batch`` requests have
        arrived (or the trace is exhausted), so a request that just misses
        a closure waits for the bucket to fill — a full burst period under
        bursty traffic.

        Each ``Completed`` carries ``queue_delay`` = admission instant −
        arrival instant on the virtual clock (also stamped onto
        ``result.queue_delay``, overriding the pipeline's perf-counter
        figure, which has no meaning on a virtual timeline) and
        ``finished_at`` = the group's completion instant.

        ``step_level=True`` (continuous mode only) switches admission from
        step-GROUP to step granularity: a persistent slot engine of
        ``slot_capacity`` slots (default ``max_batch``) advances every
        in-flight generation one denoising step per launch, admitting
        arrivals into free slots at ANY step boundary and retiring
        finished slots through per-request Archive/Finish passes in
        submission order (exact maintenance crossings preserved).
        ``on_step(step_no)`` is the fault-injection hook (e.g.
        ``fail_node`` / chaos injection while work is in flight): with
        ``step_level=True`` it is called before each step launch; in
        group mode it is called before each GROUP is served (the group
        counter stands in for the step number — group granularity is the
        finest boundary that mode has).  See :class:`DiffusionSlotEngine`
        / :class:`EmulatedSlotEngine` and ``docs/ARCHITECTURE.md``.
        """
        if mode not in ("continuous", "drain"):
            raise ValueError(f"unknown mode {mode!r}")
        if step_level and mode != "continuous":
            raise ValueError("step_level=True requires mode='continuous'")
        if not step_level and slot_capacity is not None:
            raise ValueError(
                "slot_capacity only applies with step_level=True")
        if self.queue:
            raise RuntimeError(
                "ServingEngine.run would strand the submit() queue "
                f"({len(self.queue)} pending requests) — drain() it first")
        if step_level:
            return self._run_step_level(
                arrivals, start=start,
                slot_capacity=slot_capacity or self.max_batch,
                on_step=on_step)
        pending = deque(sorted(arrivals, key=lambda a: a.arrival_time))
        ready: List[TimedRequest] = []
        out: List[Completed] = []
        now = float(start)
        group_no = 0

        def admit_arrived() -> None:
            while pending and pending[0].arrival_time <= now + 1e-12:
                ready.append(pending.popleft())

        while pending or ready:
            admit_arrived()
            if mode == "drain":
                while len(ready) < self.max_batch and pending:
                    now = max(now, pending[0].arrival_time)
                    admit_arrived()
            if not ready:
                now = max(now, pending[0].arrival_time)
                continue
            batch, ready = ready[: self.max_batch], ready[self.max_batch:]
            if on_step is not None:
                on_step(group_no)
            group_no += 1
            admitted = now
            t0 = time.perf_counter()
            results = self.system.serve_batch(
                [r.prompt for r in batch],
                seeds=[r.seed for r in batch],
                quality_tiers=[r.quality_tier for r in batch])
            now = admitted + (time.perf_counter() - t0)
            for r, res in zip(batch, results):
                res.queue_delay = admitted - r.arrival_time
                req = Request(r.prompt, r.seed, r.quality_tier,
                              submitted_at=r.arrival_time,
                              tenant=r.tenant, tier=r.tier)
                out.append(Completed(req, res, queue_delay=res.queue_delay,
                                     finished_at=now))
        self.completed.extend(out)
        return out

    def _run_step_level(self, arrivals: Iterable[TimedRequest], *,
                        start: float, slot_capacity: int,
                        on_step: Optional[Callable[[int], None]],
                        ) -> List[Completed]:
        """Step-level continuous batching over a persistent slot engine.

        Event loop invariants (the ragged-admission property suite pins
        each of these against group-continuous and sequential ``serve``):

        * ADMISSION — whenever slots are free and requests have arrived,
          one Embed..Plan pass (``ServePipeline.run_admission``) plans the
          admission group against the current cache snapshot; ``gen``
          plans are seated in slots, everything else completes
          immediately.  Earlier unfinalized gen requests seed the Plan
          stage's coalescing set, so a near-duplicate arriving mid-flight
          aliases onto the in-flight slot exactly as it would alias
          inside one group.
        * RETIREMENT — a slot retires the step its chain ends; the image
          is decoded per slot, but Archive/Finish run in SUBMISSION order
          (``ServePipeline.finalize`` per request), so blob ids, history
          records, eviction sweeps at exact maintenance crossings, and
          per-request stats all match the sequential loop regardless of
          retirement interleaving.
        * TIMING — the virtual clock advances by the measured wall time
          of every admission pass, step launch, and finalize pass;
          ``queue_delay`` is admission instant − arrival instant, and
          per-request ``wall_total`` / ``stage_walls`` are stamped from
          the slot's OWN timestamp trail (never group-smeared).
        * FAULTS — a node death mid-flight (``on_step`` → ``fail_node``)
          never loses an accepted job: occupied slots finish their chain
          and their archive/accounting reroute to a surviving node at
          finalize, leaving the dead node's VectorDB untouched.
        """
        system = self.system
        make = getattr(system.backend, "make_slot_engine", None)
        engine = (make(slot_capacity) if make is not None
                  else EmulatedSlotEngine(system, slot_capacity))
        self.last_slot_engine = engine
        self.slot_occupancy = []
        pending = deque(sorted(arrivals, key=lambda a: a.arrival_time))
        ready: List[TimedRequest] = []
        out: List[Completed] = []
        now = float(start)
        states: Dict[int, object] = {}
        arr_of: Dict[int, TimedRequest] = {}
        admit_t: Dict[int, float] = {}
        img_ready: Dict[int, bool] = {}
        alias_target: Dict[int, int] = {}
        inflight_gen: List[int] = []   # unfinalized gen handles, ascending
        next_handle = 0
        next_fin = 0
        step_no = 0

        def admit_arrived() -> None:
            while pending and pending[0].arrival_time <= now + 1e-12:
                ready.append(pending.popleft())

        def do_admission() -> None:
            nonlocal now, next_handle
            free = engine.free_count()
            batch, rest = ready[:free], ready[free:]
            ready[:] = rest
            base = next_handle
            admitted = now
            inflight = [(states[h].qvec, h) for h in inflight_gen]
            t0 = time.perf_counter()
            planned = system.pipeline.run_admission(
                system, [r.prompt for r in batch],
                seeds=[r.seed for r in batch],
                quality_tiers=[r.quality_tier for r in batch],
                inflight=inflight or None)
            for s, r in zip(planned, batch):
                h = base + s.index
                states[h], arr_of[h], admit_t[h] = s, r, admitted
                if s.plan.kind == "gen":
                    self._admit_with_retry(engine, s, h)
                    inflight_gen.append(h)
                    img_ready[h] = False
                elif s.plan.kind == "alias":
                    t = s.plan.target
                    alias_target[h] = base + t if t >= 0 else -(t + 1)
            next_handle += len(batch)
            now = admitted + (time.perf_counter() - t0)

        def finalize_due() -> None:
            nonlocal now, next_fin
            while next_fin < next_handle:
                st = states[next_fin]
                if st.plan.kind == "gen" and not img_ready[next_fin]:
                    break      # submission-order gate: wait for the slot
                if st.plan.kind == "alias":
                    # target is an earlier gen request — already retired
                    # (and finalized) by the submission-order gate, so its
                    # image is available; this is the history fast path
                    # sequential serve takes once the target is recorded
                    st.plan = Plan(kind="history",
                                   image=states[alias_target[next_fin]].image)
                elif st.plan.kind == "gen":
                    node = st.plan.node
                    if (0 <= node < len(system.dbs)
                            and not system.scheduler.nodes[node].alive):
                        alive = [i for i in range(len(system.dbs))
                                 if system.scheduler.nodes[i].alive]
                        if alive:   # reroute archive + accounting off the
                            st.plan.node = alive[0]   # dead node's VDB
                t0 = time.perf_counter()
                system.pipeline.finalize(system, st)
                now += time.perf_counter() - t0
                r = arr_of[next_fin]
                res = st.result
                res.queue_delay = admit_t[next_fin] - r.arrival_time
                req = Request(r.prompt, r.seed, r.quality_tier,
                              submitted_at=r.arrival_time,
                              tenant=r.tenant, tier=r.tier)
                out.append(Completed(req, res, queue_delay=res.queue_delay,
                                     finished_at=now))
                if inflight_gen and inflight_gen[0] == next_fin:
                    inflight_gen.pop(0)
                next_fin += 1

        while pending or ready or next_fin < next_handle:
            admit_arrived()
            if ready and engine.free_count() > 0:
                do_admission()
                finalize_due()     # cached/history/alias complete at once
            if engine.active_count() > 0:
                if on_step is not None:
                    on_step(step_no)
                self.slot_occupancy.append(engine.active_count())
                t0 = time.perf_counter()
                retired = engine.step()
                now += time.perf_counter() - t0
                step_no += 1
                for h, st in retired:
                    st.stage_ts["Generate"] = time.perf_counter()
                    img_ready[h] = True
                finalize_due()
            elif not ready:
                finalize_due()
                if pending:
                    now = max(now, pending[0].arrival_time)
                elif next_fin >= next_handle:
                    break
        self.completed.extend(out)
        return out

    def _admit_with_retry(self, engine, state, handle: int) -> None:
        """Seat one gen plan in a slot, retrying transient backend faults
        (the emulated engine generates AT admit time — a batch-of-one
        backend call — so this is the step-level analogue of the Generate
        stage's retry loop).  Health bookkeeping mirrors
        ``GenerateStage._call``; the final failed attempt re-raises so no
        accepted job is silently dropped."""
        system = self.system
        retries = getattr(system, "transient_retries", 0)
        sched = (system.scheduler
                 if getattr(system, "use_scheduler", False) else None)
        node = state.plan.node
        attempt = 0
        while True:
            try:
                engine.admit(state, handle)
            except TransientBackendError:
                if sched is not None and 0 <= node < len(sched.nodes):
                    sched.observe_fault(node, kind="transient")
                stats = getattr(system, "stats", None)
                if stats is not None:
                    stats.transient_retries += 1
                attempt += 1
                if attempt > retries:
                    raise
                continue
            if sched is not None and 0 <= node < len(sched.nodes):
                sched.observe_ok(node)
            return

    def fail_node(self, node: int) -> None:
        self.system.fail_node(node)

    def join_node(self, *, speed: float = 1.0,
                  capacity: Optional[int] = None) -> int:
        """Grow the fleet by one fresh node (see ``CacheGenius
        .join_node``); returns the new node index.  Safe between groups —
        routing only consults the fleet at batch admission."""
        return self.system.join_node(speed=speed, capacity=capacity)

    def tagged_stats(self) -> Dict[Tuple[Optional[str], Optional[str]],
                                   Dict[str, float]]:
        """Per-(tenant, tier) latency percentiles over everything this
        engine has completed (empty when traffic is untagged) — see
        :func:`tenant_tier_stats`."""
        return tenant_tier_stats(self.completed)


def tenant_tier_stats(completed: Sequence[Completed],
                      ) -> Dict[Tuple[Optional[str], Optional[str]],
                                Dict[str, float]]:
    """Queue-delay and wall-latency percentiles per (tenant, tier).

    Groups tagged completions (requests whose ``tenant`` or ``tier`` is
    set) and reports, per group: ``n``, ``queue_delay_p50/p95``,
    ``wall_p50/p95`` (per-request measured pipeline wall ``wall_total``,
    falling back to the batch-amortised ``wall_latency`` when a caller
    built results without stage timestamps) and ``e2e_p50/p95``
    (queue delay + wall).  Untagged completions are skipped; fully
    untagged traffic returns ``{}``, which is the "don't print the
    table" signal the serve CLI keys on.
    """
    groups: Dict[Tuple[Optional[str], Optional[str]], List[Completed]] = {}
    for c in completed:
        if c.request.tenant is None and c.request.tier is None:
            continue
        groups.setdefault((c.request.tenant, c.request.tier), []).append(c)
    out: Dict[Tuple[Optional[str], Optional[str]], Dict[str, float]] = {}
    for key in sorted(groups, key=lambda k: (str(k[0]), str(k[1]))):
        cs = groups[key]
        qd = np.array([c.queue_delay for c in cs])
        wall = np.array([c.result.wall_total if c.result.wall_total > 0
                         else c.result.wall_latency for c in cs])
        e2e = qd + wall
        out[key] = {
            "n": len(cs),
            "queue_delay_p50": float(np.percentile(qd, 50)),
            "queue_delay_p95": float(np.percentile(qd, 95)),
            "wall_p50": float(np.percentile(wall, 50)),
            "wall_p95": float(np.percentile(wall, 95)),
            "e2e_p50": float(np.percentile(e2e, 50)),
            "e2e_p95": float(np.percentile(e2e, 95)),
        }
    return out


# ---------------------------------------------------------------------------
# LM response cache (beyond-paper arch adaptation)
# ---------------------------------------------------------------------------


@dataclass
class LMResponseCache:
    """Semantic response cache for LM serving — the paper's HIT_RETURN
    branch ported to discrete tokens.  There is no img2img middle band:
    a near-miss cannot be 'partially denoised', so scores below the hit
    threshold always decode from scratch (and archive the result)."""

    embed: Callable[[str], np.ndarray]
    hit_threshold: float = 0.95
    capacity: int = 4096
    _vecs: np.ndarray = field(default=None, repr=False)  # type: ignore
    _responses: List[str] = field(default_factory=list, repr=False)
    hits: int = 0
    misses: int = 0

    def __post_init__(self):
        dim = len(np.asarray(self.embed("probe")).reshape(-1))
        self._vecs = np.zeros((0, dim), np.float32)

    def lookup(self, prompt: str) -> Optional[str]:
        if self._vecs.shape[0] == 0:
            self.misses += 1
            return None
        q = _l2n(np.asarray(self.embed(prompt), np.float32).reshape(-1))
        sims = self._vecs @ q
        i = int(np.argmax(sims))
        if sims[i] >= self.hit_threshold:
            self.hits += 1
            return self._responses[i]
        self.misses += 1
        return None

    def insert(self, prompt: str, response: str) -> None:
        q = _l2n(np.asarray(self.embed(prompt), np.float32).reshape(-1))
        self._vecs = np.concatenate([self._vecs, q[None]])[-self.capacity:]
        self._responses = (self._responses + [response])[-self.capacity:]

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / max(total, 1)


def _l2n(x: np.ndarray) -> np.ndarray:
    return x / max(float(np.linalg.norm(x)), 1e-12)
