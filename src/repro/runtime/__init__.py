"""Distributed runtime: logical-axis partitioning, step builders, training
loop with fault tolerance, elastic resharding, and the serving engine."""
