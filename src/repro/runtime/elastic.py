"""Elastic re-meshing: restore a checkpoint onto a *different* mesh.

A 512-chip multi-pod run that loses a pod restarts on 256 chips (or vice
versa after repair).  The checkpoint manifest records every array's global
shape + PartitionSpec string; ``reshard_checkpoint`` reads the global
arrays and lays them out on the new mesh with the same *logical* specs —
axis names that don't exist on the new mesh (e.g. ``pod``) degrade to
replication, everything else re-sharding automatically via device_put.

Single-process note: arrays are stored whole, so resharding is a pure
layout operation here.  On a real cluster each host reads only the shard
ranges it owns — the manifest already carries what's needed to compute
them (global shape + spec), which is why specs are persisted at save time.
"""
from __future__ import annotations

import ast
import re
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def parse_spec(text: str, mesh: Mesh) -> P:
    """Parse "PartitionSpec('data', None, ('pod','data'))" back to a spec,
    dropping axis names the target mesh doesn't have."""
    if not text or not text.startswith("PartitionSpec"):
        return P()
    body = text[len("PartitionSpec"):]
    try:
        parts = ast.literal_eval(body)
    except (ValueError, SyntaxError):
        return P()
    if not isinstance(parts, tuple):
        parts = (parts,)
    out = []
    names = set(mesh.axis_names)
    for p in parts:
        if p is None:
            out.append(None)
        elif isinstance(p, str):
            out.append(p if p in names else None)
        elif isinstance(p, (tuple, list)):
            kept = tuple(a for a in p if a in names)
            out.append(kept if kept else None)
        else:
            out.append(None)
    return P(*out)


def reshard_checkpoint(manager, template: PyTree, mesh: Mesh, *,
                       step: Optional[int] = None,
                       specs: Optional[PyTree] = None) -> tuple:
    """Restore ``template``-shaped state onto ``mesh``.

    ``specs`` (a PartitionSpec pytree) overrides the manifest's stored
    specs — pass the new mesh's partitioning when the parallelism layout
    changes (e.g. model axis 16 → 8), not just the device count.
    """
    state, extra = manager.restore(template, step=step)
    if step is None:
        step = manager.latest_step()
    manifest = manager.manifest(step)
    spec_by_name: Dict[str, str] = {
        k: v.get("spec", "") for k, v in manifest["arrays"].items()}

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    spec_leaves = (jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)) if specs is not None
        else None)

    out = []
    for i, (path, leaf) in enumerate(flat):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx",
                        getattr(p, "name", p)))) for p in path)
        if spec_leaves is not None:
            spec = spec_leaves[i]
        else:
            spec = parse_spec(spec_by_name.get(name, ""), mesh)
        # drop spec axes that no longer divide (elastic shrink safety)
        spec = _fit_spec(spec, np.shape(leaf), mesh)
        out.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out), extra


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    parts = list(spec) + [None] * (len(shape) - len(spec))
    fitted = []
    for dim, p in zip(shape, parts):
        if p is None:
            fitted.append(None)
            continue
        axes = p if isinstance(p, tuple) else (p,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        fitted.append(p if dim % n == 0 else None)
    return P(*fitted)
