"""Step builders: one compiled program per (architecture × shape) cell.

``build_cell_program(arch, cell)`` returns a :class:`CellProgram` whose
``step_fn`` + ShapeDtypeStruct args + PartitionSpec trees are exactly what
the multi-pod dry-run lowers::

    with mesh, logical_rules(prog.rules):
        jax.jit(prog.step_fn,
                in_shardings=shardings(prog.in_specs),
                donate_argnums=prog.donate).lower(*prog.args_sds).compile()

The same ``step_fn`` executes eagerly on CPU for the reduced-config smoke
tests (``build_cell_program(..., reduced=True)`` + ``init_state``).

Cell kinds
----------
* ``train``   — forward + backward + optimizer update (+ microbatch
                gradient accumulation via ``lax.scan`` when the cell says so)
* ``prefill`` — LM full-sequence forward returning bf16 KV caches
* ``decode``  — LM single-token serve step against a seq_len KV cache
* ``gen``     — diffusion serve step: ONE denoising step of the sampler
                (DDIM for eps-models, Euler for rectified flow).  The
                sampler multiplies by ``cell.steps``; CacheGenius's routing
                changes that multiplier (N → K → 0) on this same program.
* ``infer``   — vision forward pass
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.diffusion import dit as dit_mod
from repro.models.diffusion import mmdit as mmdit_mod
from repro.models.diffusion import unet as unet_mod
from repro.models.diffusion import vae as vae_mod
from repro.models.diffusion.sampler import ddim_step, ddpm_loss, rf_loss
from repro.models.diffusion.schedule import DiffusionSchedule
from repro.models.transformer import lm as lm_mod
from repro.models.vision import convnext as cnx_mod
from repro.models.vision import efficientnet as eff_mod
from repro.optim.adafactor import (AdafactorConfig, adafactor_init,
                                   adafactor_update)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime import partition
from repro.runtime.pspec import decode_rules, maybe_constraint, train_rules

PyTree = Any


# ---------------------------------------------------------------------------
# program container
# ---------------------------------------------------------------------------


@dataclass
class CellProgram:
    arch: ArchSpec
    cell: ShapeCell
    step_fn: Callable
    args_sds: Tuple[PyTree, ...]
    in_specs: Tuple[PyTree, ...]
    rules: Dict[str, Any]
    donate: Tuple[int, ...] = ()
    out_specs: Any = None            # None → infer
    init_fn: Optional[Callable] = None   # key -> state (materialised)
    meta: Dict[str, Any] = field(default_factory=dict)


# functional train state as a plain dict keeps checkpoint paths stable
def _train_state_sds(params_sds, opt_init):
    opt_sds = jax.eval_shape(opt_init, params_sds)
    return {"params": params_sds, "opt": opt_sds,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _optimizer(name: str):
    if name == "adafactor":
        cfg = AdafactorConfig(lr=1e-2)
        return (lambda p: adafactor_init(p, cfg),
                lambda g, s, p: adafactor_update(g, s, p, cfg))
    cfg = AdamWConfig(lr=3e-4)
    return (lambda p: adamw_init(p),
            lambda g, s, p: adamw_update(g, s, p, cfg))


def _dtype_of(arch: ArchSpec, options: Optional[Dict[str, Any]] = None):
    if options and options.get("bf16_params"):
        return jnp.bfloat16
    return jnp.bfloat16 if arch.param_dtype == "bfloat16" else jnp.float32


def _data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _data_size(mesh_shape: Dict[str, int], multi_pod: bool) -> int:
    n = mesh_shape.get("data", 1)
    if multi_pod:
        n *= mesh_shape.get("pod", 1)
    return n


def _batch_spec(batch: int, dsize: int, multi_pod: bool,
                mesh_shape: Dict[str, int], *, res: int = 0,
                shard_spatial: bool = False, tail: int = 1):
    """Spec for a (B, [res, res,] …) input: shard the batch over the data
    axes when divisible; otherwise shard the first spatial dim; otherwise
    split batch over 'data' and spatial over 'pod' (gen_fast multi-pod)."""
    data = _data_axes(multi_pod)
    none_tail = (None,) * tail
    if not shard_spatial and batch % dsize == 0:
        return P(data, *none_tail)
    if res:
        if batch % mesh_shape.get("data", 1) == 0 and multi_pod \
                and res % mesh_shape.get("pod", 1) == 0 and not shard_spatial:
            return P(("data",), ("pod",), *none_tail[1:])
        if res % dsize == 0:
            return P(None, data, *none_tail[1:])
        if res % mesh_shape.get("data", 1) == 0:
            return P(None, ("data",), *none_tail[1:])
    return P(*((None,) + none_tail))


# ---------------------------------------------------------------------------
# microbatched grad accumulation
# ---------------------------------------------------------------------------


def _accumulate_grads(loss_fn, params, batches, n_micro: int,
                      acc_dtype=jnp.float32):
    """loss_fn(params, micro_batch) -> (loss, aux). ``batches`` is a pytree
    whose leaves have a leading (n_micro, …) axis.  Returns (grads, loss,
    aux) averaged over microbatches.  ``acc_dtype``: the 400B-class bf16
    archs accumulate in bf16 — an fp32 accumulator alone costs 6.25 GB per
    v5e chip at 400B/256."""
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    if n_micro == 1:
        mb = jax.tree_util.tree_map(lambda x: x[0], batches)
        (loss, aux), grads = vg(params, mb)
        return grads, loss, aux

    def body(carry, mb):
        acc, loss_sum = carry
        (loss, _aux), g = vg(params, mb)
        acc = jax.tree_util.tree_map(
            lambda a, gg: a + gg.astype(a.dtype), acc, g)
        return (acc, loss_sum + loss), None

    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, acc_dtype), params)
    (grads, loss_sum), _ = jax.lax.scan(body, (acc0, 0.0), batches)
    grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
    return grads, loss_sum / n_micro, {}


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_programs(arch: ArchSpec, cell: ShapeCell, cfg, *, multi_pod: bool,
                 mesh_shape: Dict[str, int], reduced: bool,
                 options: Optional[Dict[str, Any]] = None) -> CellProgram:
    options = options or {}
    dt = jnp.float32 if reduced else _dtype_of(arch, options)
    dsize = _data_size(mesh_shape, multi_pod)
    data = _data_axes(multi_pod)
    opt_init, opt_update = _optimizer(arch.optimizer)

    params_sds = jax.eval_shape(
        lambda k: lm_mod.init_lm(k, cfg, param_dtype=dt),
        jax.random.key(0))
    p_specs = partition.sanitize_specs(
        partition.tree_specs(params_sds, partition.LM_RULES),
        params_sds, mesh_shape)

    if cell.kind == "train":
        rules = train_rules(multi_pod)
        if options.get("shard_heads"):
            rules["heads"] = "model"
        b, s = cell.global_batch, cell.seq_len
        want_micro = arch.train_microbatches or cell.microbatches
        n_micro = max(1, min(want_micro, b // max(dsize, 1)))
        while b % n_micro or (b // n_micro) % dsize:
            n_micro -= 1
        mb = b // n_micro
        acc_dtype = dt if dt == jnp.bfloat16 else jnp.float32
        vocab_chunks = options.get("vocab_chunks", 1)

        def loss_fn(p, mbatch):
            return lm_mod.lm_loss(p, cfg, mbatch["tokens"], mbatch["labels"],
                                  vocab_chunks=vocab_chunks)

        def step_fn(state, batch):
            toks = batch["tokens"]
            micro = {
                "tokens": maybe_constraint(
                    toks[:, :-1].reshape(n_micro, mb, s), P(None, data, None)),
                "labels": maybe_constraint(
                    toks[:, 1:].reshape(n_micro, mb, s), P(None, data, None)),
            }
            grads, loss, _ = _accumulate_grads(loss_fn, state["params"],
                                               micro, n_micro,
                                               acc_dtype=acc_dtype)
            params, opt, metrics = opt_update(grads, state["opt"],
                                              state["params"])
            new_state = {"params": params, "opt": opt,
                         "step": state["step"] + 1}
            return new_state, {"loss": loss, **metrics}

        state_sds = _train_state_sds(params_sds, opt_init)
        if arch.fsdp_params and not reduced:
            p_specs_eff = partition.fsdp_specs(
                p_specs, params_sds, _MeshShim(mesh_shape))
        else:
            p_specs_eff = p_specs
        state_specs = {
            "params": p_specs_eff,
            "opt": partition.derive_state_specs(
                state_sds["opt"], p_specs_eff, params_sds,
                mesh=_MeshShim(mesh_shape), zero=not reduced),
            "step": P(),
        }
        batch_sds = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        batch_specs = {"tokens": P(data, None)}

        def init_fn(key):
            params = lm_mod.init_lm(key, cfg, param_dtype=dt)
            return {"params": params, "opt": opt_init(params),
                    "step": jnp.zeros((), jnp.int32)}

        return CellProgram(arch, cell, step_fn, (state_sds, batch_sds),
                           (state_specs, batch_specs), rules, donate=(0,),
                           init_fn=init_fn,
                           meta={"tokens": b * s, "n_micro": n_micro})

    if cell.kind == "prefill":
        rules = train_rules(multi_pod)
        b, s = cell.global_batch, cell.seq_len

        def step_fn(params, tokens):
            logits, caches, _aux = lm_mod.apply_lm(params, cfg, tokens,
                                                   return_kv=True)
            caches = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.bfloat16), caches)
            return logits[:, -1:], caches

        tokens_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
        cache_spec = P(None, data, "model", None, None)
        n_pat = len(cfg.pattern)
        out_specs = (P(data, None, "model"),
                     {pi: (cache_spec, cache_spec) for pi in range(n_pat)})
        return CellProgram(arch, cell, step_fn, (params_sds, tokens_sds),
                           (p_specs, P(data, None)), rules,
                           out_specs=out_specs,
                           init_fn=lambda k: lm_mod.init_lm(k, cfg, param_dtype=dt),
                           meta={"tokens": b * s})

    # decode ---------------------------------------------------------------
    rules = decode_rules(multi_pod, shard_kv=cell.shard_kv)
    b, s = cell.global_batch, cell.seq_len
    batch_rule = rules["batch"]
    kv_rule = rules["kv_seq"]

    def step_fn(params, token, caches, cache_len):
        return lm_mod.apply_lm_decode(params, cfg, token, caches, cache_len)

    caches_sds = jax.eval_shape(
        partial(lm_mod.init_kv_cache, cfg, b, s, jnp.bfloat16))
    cache_spec = P(None, batch_rule, kv_rule, None, None)
    caches_specs = jax.tree_util.tree_map(
        lambda _: cache_spec, caches_sds,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    token_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    len_sds = jax.ShapeDtypeStruct((), jnp.int32)
    out_specs = (P(batch_rule, None, "model"), caches_specs)

    return CellProgram(
        arch, cell, step_fn,
        (params_sds, token_sds, caches_sds, len_sds),
        (p_specs, P(batch_rule, None), caches_specs, P()),
        rules, donate=(2,), out_specs=out_specs,
        init_fn=lambda k: lm_mod.init_lm(k, cfg, param_dtype=dt),
        meta={"tokens": b, "kv_len": s})


# ---------------------------------------------------------------------------
# diffusion family
# ---------------------------------------------------------------------------


def _diffusion_apply(dcfg):
    if dcfg.backbone == "dit":
        return dit_mod.init_dit, dit_mod.apply_dit, "eps"
    if dcfg.backbone == "unet":
        return unet_mod.init_unet, unet_mod.apply_unet, "eps"
    if dcfg.backbone == "mmdit":
        return mmdit_mod.init_mmdit, mmdit_mod.apply_mmdit, "v"
    raise ValueError(dcfg.backbone)


def _diffusion_rules_table(backbone: str):
    return {"dit": partition.DIT_RULES, "unet": partition.UNET_RULES,
            "mmdit": partition.MMDIT_RULES}[backbone]


def _ctx_sds(dcfg, batch: int, dtype):
    if dcfg.backbone == "dit":
        return jax.ShapeDtypeStruct((batch, dcfg.net.ctx_dim), dtype)
    if dcfg.backbone == "unet":
        return jax.ShapeDtypeStruct((batch, dcfg.ctx_len, dcfg.ctx_dim), dtype)
    return {"txt": jax.ShapeDtypeStruct((batch, dcfg.net.txt_len,
                                         dcfg.net.txt_dim), dtype),
            "vec": jax.ShapeDtypeStruct((batch, dcfg.net.vec_dim), dtype)}


def _ctx_specs(dcfg, bspec_first):
    if dcfg.backbone == "dit":
        return P(bspec_first, None)
    if dcfg.backbone == "unet":
        return P(bspec_first, None, None)
    return {"txt": P(bspec_first, None, None), "vec": P(bspec_first, None)}


def _diffusion_programs(arch: ArchSpec, cell: ShapeCell, dcfg, *,
                        multi_pod: bool, mesh_shape: Dict[str, int],
                        reduced: bool,
                        options: Optional[Dict[str, Any]] = None
                        ) -> CellProgram:
    options = options or {}
    dt = jnp.float32 if reduced else _dtype_of(arch, options)
    dsize = _data_size(mesh_shape, multi_pod)
    data = _data_axes(multi_pod)
    opt_init, opt_update = _optimizer(arch.optimizer)
    init_net, apply_net, pred = _diffusion_apply(dcfg)
    rules = train_rules(multi_pod)
    sched = DiffusionSchedule.linear(1000)
    if dcfg.backbone == "unet":
        latent = 8 if reduced else (cell.img_res or 256) // dcfg.vae.downsample
    else:
        latent = dcfg.net.img_res
    res = latent * dcfg.vae.downsample

    net_sds = jax.eval_shape(
        lambda k: init_net(k, dcfg.net, param_dtype=dt), jax.random.key(0))
    vae_sds = jax.eval_shape(
        lambda k: vae_mod.init_vae(k, dcfg.vae, param_dtype=dt),
        jax.random.key(0))
    net_specs = partition.sanitize_specs(
        partition.tree_specs(net_sds, _diffusion_rules_table(dcfg.backbone)),
        net_sds, mesh_shape)
    vae_specs = partition.sanitize_specs(
        partition.tree_specs(vae_sds, partition.VAE_RULES),
        vae_sds, mesh_shape)
    if options.get("dp_only"):
        # §Perf variant: replicate params, shard the batch over BOTH mesh
        # axes — for sub-1B models the per-conv TP collectives cost more
        # than one gradient all-reduce.
        repl = lambda t: jax.tree_util.tree_map(  # noqa: E731
            lambda l: P(*([None] * len(l.shape))), t,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        net_specs, vae_specs = repl(net_sds), repl(vae_sds)

    if cell.kind == "train":
        b = cell.global_batch
        n_micro = max(1, min(cell.microbatches, b // max(dsize, 1)))
        while b % n_micro or (b // n_micro) % dsize:
            n_micro -= 1
        if options.get("dp_only"):
            n_micro = 1
        mb = b // n_micro
        if options.get("dp_only"):
            both = tuple(a for a in ("pod", "data", "model")
                         if a in mesh_shape)
            img_spec = P(both, None, None, None)
        else:
            img_spec = _batch_spec(mb, dsize, multi_pod, mesh_shape,
                                   res=res, tail=3)

        def loss_fn(vae_p, net_p, mbatch):
            imgs = mbatch["images"].astype(dt)
            mean, _logvar = vae_mod.encode(vae_p, dcfg.vae, imgs)
            z = jax.lax.stop_gradient(mean) * 0.18215
            key = jax.random.fold_in(jax.random.key(17), mbatch["idx"])
            if pred == "eps":
                fn = lambda x, t, c: apply_net(net_p, dcfg.net, x, t, c)  # noqa: E731
                return ddpm_loss(fn, sched, z, mbatch["ctx"], key), {}
            fn = lambda x, t, c: apply_net(net_p, dcfg.net, x, t, c)      # noqa: E731
            ctx = {"txt": mbatch["ctx"]["txt"], "vec": mbatch["ctx"]["vec"]}
            return rf_loss(fn, z, ctx, key), {}

        micro_img_spec = P(None, *tuple(img_spec))
        rules = dict(rules)
        rules["batch"] = tuple(img_spec)[0]

        def step_fn(state, batch):
            micro = {
                "images": maybe_constraint(
                    batch["images"].reshape((n_micro, mb) +
                                            batch["images"].shape[1:]),
                    micro_img_spec),
                "ctx": jax.tree_util.tree_map(
                    lambda x: x.reshape((n_micro, mb) + x.shape[1:]),
                    batch["ctx"]),
                "idx": state["step"] * n_micro + jnp.arange(n_micro),
            }
            loss_p = partial(loss_fn, state["vae"])
            grads, loss, _ = _accumulate_grads(loss_p, state["params"],
                                               micro, n_micro)
            params, opt, metrics = opt_update(grads, state["opt"],
                                              state["params"])
            return ({"params": params, "vae": state["vae"], "opt": opt,
                     "step": state["step"] + 1},
                    {"loss": loss, **metrics})

        state_sds = {"params": net_sds, "vae": vae_sds,
                     "opt": jax.eval_shape(opt_init, net_sds),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        net_specs_eff = (partition.fsdp_specs(net_specs, net_sds,
                                              _MeshShim(mesh_shape))
                         if arch.fsdp_params and not reduced else net_specs)
        state_specs = {
            "params": net_specs_eff, "vae": vae_specs,
            "opt": partition.derive_state_specs(
                state_sds["opt"], net_specs_eff, net_sds,
                mesh=_MeshShim(mesh_shape), zero=not reduced),
            "step": P(),
        }
        batch_sds = {"images": jax.ShapeDtypeStruct((b, res, res, 3),
                                                    jnp.float32),
                     "ctx": _ctx_sds(dcfg, b, jnp.float32)}
        bfirst = tuple(img_spec)[0]
        batch_specs = {"images": img_spec,
                       "ctx": _ctx_specs(dcfg, bfirst)}

        def init_fn(key):
            k1, k2 = jax.random.split(key)
            params = init_net(k1, dcfg.net, param_dtype=dt)
            return {"params": params,
                    "vae": vae_mod.init_vae(k2, dcfg.vae, param_dtype=dt),
                    "opt": opt_init(params),
                    "step": jnp.zeros((), jnp.int32)}

        return CellProgram(arch, cell, step_fn, (state_sds, batch_sds),
                           (state_specs, batch_specs), rules, donate=(0,),
                           init_fn=init_fn,
                           meta={"latent": latent, "n_micro": n_micro})

    # gen: one denoising step ------------------------------------------------
    b = cell.global_batch
    x_spec = _batch_spec(b, dsize, multi_pod, mesh_shape, res=latent,
                         shard_spatial=cell.shard_spatial, tail=3)
    bfirst = tuple(x_spec)[0]
    # activation-constraint rules for the backbone's logical axes: the
    # batch rule must match the input spec (gen batches may be indivisible
    # → replicated); "seq" stays whole unless the sequence-parallel §Perf
    # variant is on.
    rules = dict(rules)
    rules["batch"] = bfirst
    if options.get("seq_shard"):
        rules["seq"] = "model"

    if pred == "eps":
        def step_fn(net, x, t, t_prev, ctx):
            eps = apply_net(net, dcfg.net, x, t, ctx)
            tb = t[0].astype(jnp.int32)
            return ddim_step(sched, x, eps, tb,
                             t_prev.astype(jnp.int32)).astype(x.dtype)
    else:
        def step_fn(net, x, t, t_prev, ctx):
            v = apply_net(net, dcfg.net, x, t.astype(x.dtype) / sched.T, ctx)
            dt_ = (t_prev.astype(x.dtype) - t[0].astype(x.dtype)) / sched.T
            return (x + dt_ * v).astype(x.dtype)

    x_sds = jax.ShapeDtypeStruct((b, latent, latent, dcfg.vae.z_ch), dt)
    t_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    tp_sds = jax.ShapeDtypeStruct((), jnp.int32)
    ctx_sds = _ctx_sds(dcfg, b, dt)
    return CellProgram(
        arch, cell, step_fn,
        (net_sds, x_sds, t_sds, tp_sds, ctx_sds),
        (net_specs, x_spec, P(bfirst), P(), _ctx_specs(dcfg, bfirst)),
        rules, donate=(1,), out_specs=x_spec,
        init_fn=lambda k: init_net(k, dcfg.net, param_dtype=dt),
        meta={"latent": latent, "steps": cell.steps})


# ---------------------------------------------------------------------------
# vision family
# ---------------------------------------------------------------------------


def _vision_programs(arch: ArchSpec, cell: ShapeCell, cfg, *,
                     multi_pod: bool, mesh_shape: Dict[str, int],
                     reduced: bool,
                     options: Optional[Dict[str, Any]] = None) -> CellProgram:
    options = options or {}
    dt = jnp.float32 if reduced else _dtype_of(arch, options)
    dsize = _data_size(mesh_shape, multi_pod)
    data = _data_axes(multi_pod)
    opt_init, opt_update = _optimizer(arch.optimizer)
    rules = train_rules(multi_pod)
    if arch.family == "vision-convnext":
        init_net, apply_net = cnx_mod.init_convnext, cnx_mod.apply_convnext
    else:
        init_net, apply_net = eff_mod.init_effnet, eff_mod.apply_effnet

    params_sds = jax.eval_shape(
        lambda k: init_net(k, cfg, param_dtype=dt), jax.random.key(0))
    p_specs = partition.sanitize_specs(
        partition.tree_specs(params_sds, partition.VISION_RULES),
        params_sds, mesh_shape)
    b = cell.global_batch
    res = cell.img_res if not reduced else 32
    img_spec = _batch_spec(b, dsize, multi_pod, mesh_shape, res=res,
                           shard_spatial=cell.shard_spatial, tail=3)
    bfirst = tuple(img_spec)[0]

    if cell.kind == "train":
        def loss_fn(p, batch):
            logits = apply_net(p, cfg, batch["images"].astype(dt))
            logits = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, batch["labels"][:, None],
                                      axis=-1)[:, 0]
            return jnp.mean(lse - tgt), {}

        def step_fn(state, batch):
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)
            params, opt, metrics = opt_update(grads, state["opt"],
                                              state["params"])
            return ({"params": params, "opt": opt,
                     "step": state["step"] + 1},
                    {"loss": loss, **metrics})

        state_sds = _train_state_sds(params_sds, opt_init)
        state_specs = {
            "params": p_specs,
            "opt": partition.derive_state_specs(
                state_sds["opt"], p_specs, params_sds,
                mesh=_MeshShim(mesh_shape), zero=not reduced),
            "step": P(),
        }
        batch_sds = {"images": jax.ShapeDtypeStruct((b, res, res, 3),
                                                    jnp.float32),
                     "labels": jax.ShapeDtypeStruct((b,), jnp.int32)}
        batch_specs = {"images": img_spec, "labels": P(bfirst)}

        def init_fn(key):
            params = init_net(key, cfg, param_dtype=dt)
            return {"params": params, "opt": opt_init(params),
                    "step": jnp.zeros((), jnp.int32)}

        return CellProgram(arch, cell, step_fn, (state_sds, batch_sds),
                           (state_specs, batch_specs), rules, donate=(0,),
                           init_fn=init_fn, meta={})

    def step_fn(params, images):
        return apply_net(params, cfg, images.astype(dt))

    img_sds = jax.ShapeDtypeStruct((b, res, res, 3), jnp.float32)
    return CellProgram(arch, cell, step_fn, (params_sds, img_sds),
                       (p_specs, img_spec), rules,
                       init_fn=lambda k: init_net(k, cfg, param_dtype=dt),
                       meta={})


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


class _MeshShim:
    """Duck-typed stand-in so spec derivation needs only axis sizes, not a
    real jax Mesh (the dry-run builds programs before devices exist)."""

    def __init__(self, shape: Dict[str, int]):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


DEFAULT_MESH_SHAPE = {"data": 16, "model": 16}
MULTIPOD_MESH_SHAPE = {"pod": 2, "data": 16, "model": 16}


def _reduce_cell(cell: ShapeCell) -> ShapeCell:
    """Shrink a cell's shapes for the CPU smoke tests (same kind/flow)."""
    from dataclasses import replace
    if cell.kind in ("train",) and cell.seq_len:
        return replace(cell, seq_len=16, global_batch=8, microbatches=2)
    if cell.kind == "prefill":
        return replace(cell, seq_len=16, global_batch=2)
    if cell.kind == "decode":
        return replace(cell, seq_len=32, global_batch=2)
    if cell.kind == "gen":
        return replace(cell, global_batch=2, img_res=32, shard_spatial=False)
    if cell.kind == "train":   # diffusion / vision train
        return replace(cell, global_batch=4, img_res=32, microbatches=2)
    return replace(cell, global_batch=2, img_res=32, shard_spatial=False)


def build_cell_program(arch: ArchSpec, cell: ShapeCell, *,
                       multi_pod: bool = False,
                       mesh_shape: Optional[Dict[str, int]] = None,
                       reduced: bool = False,
                       options: Optional[Dict[str, Any]] = None) -> CellProgram:
    """``options`` — §Perf variants (default None = paper-faithful baseline):
      * ``vocab_chunks``: int — streaming chunked CE for LM train cells
      * ``microbatches``: int — override the cell/arch microbatch count
      * ``remat``: bool — toggle activation checkpointing
    """
    if mesh_shape is None:
        mesh_shape = MULTIPOD_MESH_SHAPE if multi_pod else DEFAULT_MESH_SHAPE
    cfg = arch.make_reduced() if reduced else arch.make_config(cell)
    if reduced:
        cell = _reduce_cell(cell)
        mesh_shape = {"data": 1, "model": 1}
    opts = dict(options or {})
    if "microbatches" in opts:
        from dataclasses import replace as _replace
        cell = _replace(cell, microbatches=opts["microbatches"])
        arch = _replace(arch, train_microbatches=None)
    if "remat" in opts and hasattr(cfg, "remat"):
        cfg = cfg._replace(remat=opts["remat"])
    kw = dict(multi_pod=multi_pod, mesh_shape=mesh_shape, reduced=reduced,
              options=opts)
    if arch.family_group == "lm":
        return _lm_programs(arch, cell, cfg, **kw)
    if arch.family_group == "diffusion":
        return _diffusion_programs(arch, cell, cfg, **kw)
    return _vision_programs(arch, cell, cfg, **kw)
