"""Logical-axis partitioning (MaxText-style logical→mesh axis rules).

Models annotate intermediates with *logical* axis names
(``logical_constraint(x, "batch", "seq", "model")``); the runtime installs a
mapping from logical names to mesh axes before lowering.  Outside a rules
context the annotations are no-ops, so the same model code runs unsharded
on CPU tests and fully partitioned in the dry-run.

Rules map a logical name to a mesh axis, a tuple of mesh axes, or None
(replicated).  ``None`` logical names are always replicated.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_state = threading.local()


def current_rules() -> Optional[Dict[str, Axis]]:
    return getattr(_state, "rules", None)


@contextmanager
def logical_rules(rules: Dict[str, Axis]):
    prev = current_rules()
    _state.rules = dict(rules)
    try:
        yield
    finally:
        _state.rules = prev


def resolve_spec(*names: Optional[str]) -> P:
    """Map logical names to a PartitionSpec under the current rules."""
    rules = current_rules() or {}
    return P(*[rules.get(n) if n is not None else None for n in names])


def logical_constraint(x, *names: Optional[str]):
    """with_sharding_constraint if rules are installed; identity otherwise."""
    rules = current_rules()
    if rules is None:
        return x
    spec = resolve_spec(*names)
    return jax.lax.with_sharding_constraint(x, spec)


def maybe_constraint(x, spec):
    """Raw-PartitionSpec constraint, applied only when a rules context is
    installed (i.e. during distributed lowering; identity in CPU tests)."""
    if current_rules() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# Default rule sets --------------------------------------------------------

def train_rules(multi_pod: bool) -> Dict[str, Axis]:
    data = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": data,          # batch / token-parallel
        "seq": None,            # sequence kept whole in training
        "model": "model",       # TP: heads / ffn hidden / vocab
        "expert": "model",      # EP shares the model axis
        "kv_seq": None,
    }


def decode_rules(multi_pod: bool, *, shard_kv: Optional[str] = None,
                 ) -> Dict[str, Axis]:
    """``shard_kv``:
      * None         — cache replicated along seq, batch over data (small S)
      * "model"      — cache seq over the model axis (decode_32k: batch is
                       large enough for the data axis, heads too few to TP)
      * "data_model" — cache seq over data+model (long_500k, batch=1): the
                       attention softmax reduction lowers to an all-reduce —
                       SPMD-derived flash-decoding.
    """
    data = ("pod", "data") if multi_pod else ("data",)
    if shard_kv == "data_model":
        kv: Axis = tuple(data) + ("model",)
        batch: Axis = None
    elif shard_kv == "model":
        kv = "model"
        batch = data
    else:
        kv = None
        batch = data
    return {
        "batch": batch,
        "seq": None,
        "model": "model",
        "expert": "model",
        "kv_seq": kv,
    }
