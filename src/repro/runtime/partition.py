"""Parameter / input partitioning rules per model family.

Rules are (path-regex → PartitionSpec-for-the-layer-local-shape); stacked
layer parameters (leading scan dim from the grouped trunks) automatically
get a ``None`` prepended.  The optimizer moments inherit the parameter
spec, optionally ZeRO-extended over the data axis (largest divisible
unsharded dim).
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any
Rule = Tuple[str, P]


# ---------------------------------------------------------------------------
# rule tables
# ---------------------------------------------------------------------------

LM_RULES: List[Rule] = [
    (r"embed$", P("model", None)),                      # vocab-sharded
    (r"unembed/w$", P(None, "model")),
    (r"attn/w[qkv]/w$", P(None, "model")),              # head TP
    (r"attn/w[qkv]/b$", P("model")),
    (r"attn/wo/w$", P("model", None)),
    (r"ffn/(gate|up)/w$", P(None, "model")),            # MLP TP
    (r"ffn/down/w$", P("model", None)),
    (r"moe/router/w$", P(None, None)),
    (r"moe/w_(gate|up)$", P("model", None, None)),      # EP: experts on model
    (r"moe/w_down$", P("model", None, None)),
    (r"moe/shared/(gate|up)/w$", P(None, "model")),
    (r"moe/shared/down/w$", P("model", None)),
]

DIT_RULES: List[Rule] = [
    (r"patch_embed/w$", P(None, "model")),
    (r"qkv/w$", P(None, "model")),
    (r"proj/w$", P("model", None)),
    (r"mlp/fc1/w$", P(None, "model")),
    (r"mlp/fc2/w$", P("model", None)),
    (r"ada/w$", P(None, "model")),
    (r"final_proj/w$", P("model", None)),
]

MMDIT_RULES: List[Rule] = [
    (r"(img|txt)_in/w$", P(None, "model")),
    (r"qkv/w$", P(None, "model")),
    (r"proj/w$", P("model", None)),
    (r"mlp/fc1/w$", P(None, "model")),
    (r"mlp/fc2/w$", P("model", None)),
    (r"mod/w$", P(None, "model")),
    (r"linear1/w$", P(None, "model")),
    (r"linear2/w$", P("model", None)),
    (r"final_proj/w$", P("model", None)),
]

UNET_RULES: List[Rule] = [
    (r"(conv1|conv2|skip|down|up|expand_conv|project_conv)/w$",
     P(None, None, None, "model")),                     # out-channel TP
    (r"temb/w$", P(None, "model")),
    (r"(self_qkv|cross_q|cross_kv|geglu)/w$", P(None, "model")),
    (r"(self_out|cross_out|ff_out)/w$", P("model", None)),
    (r"(proj_in|proj_out)/w$", P(None, None, None, "model")),
    (r"conv_(in|out)/w$", P(None, None, None, None)),
]

VAE_RULES: List[Rule] = [
    (r"(conv1|conv2|skip|down|up|stem|from_z|to_img|to_moments)/w$",
     P(None, None, None, "model")),
]

VISION_RULES: List[Rule] = []  # replicate — small models, DP handles scale

# Cluster-retrieval slabs (core/cluster_index.py): the per-node cache
# state is embarrassingly parallel along the node axis, so the stacked
# ``(2, padded_nodes, capacity, dim)`` img/txt slabs and the
# ``(padded_nodes, capacity)`` validity mask shard along a 1-D "nodes"
# mesh — index planes, slot rows, and feature dims stay local.
CLUSTER_SLAB_SPEC = P(None, "nodes", None, None)
CLUSTER_VALID_SPEC = P("nodes", None)


# ---------------------------------------------------------------------------
# application
# ---------------------------------------------------------------------------


def spec_for(path: str, shape: Sequence[int], rules: List[Rule],
             *, stacked_prefix: bool = True) -> P:
    for pattern, spec in rules:
        if re.search(pattern, path):
            base = tuple(spec)
            if stacked_prefix and len(shape) == len(base) + 1:
                base = (None,) + base        # leading scan-stack dim
            elif len(shape) != len(base):
                continue                      # rank mismatch → keep looking
            return P(*base)
    return P(*([None] * len(shape)))


def tree_specs(tree: PyTree, rules: List[Rule]) -> PyTree:
    """PartitionSpec pytree matching ``tree`` (works on ShapeDtypeStructs)."""

    def visit(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        return spec_for(name, leaf.shape, rules)

    return jax.tree_util.tree_map_with_path(visit, tree)


def sanitize_specs(specs: PyTree, tree: PyTree, mesh_shape) -> PyTree:
    """Drop spec axes that do not divide the corresponding dim (e.g. the
    VAE's 3-channel output conv under a 16-way model axis)."""
    ms = mesh_shape if isinstance(mesh_shape, dict) else dict(mesh_shape.shape)

    def sizes(ax):
        return int(np.prod([ms.get(a, 1) for a in
                            (ax if isinstance(ax, tuple) else (ax,))]))

    def fit(spec, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        return P(*[p if (p is not None and dim % sizes(p) == 0) else None
                   for dim, p in zip(leaf.shape, parts)])

    return jax.tree_util.tree_map(
        fit, specs, tree, is_leaf=lambda x: isinstance(x, P))


def tree_shardings(tree_of_specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  tree_of_specs,
                                  is_leaf=lambda x: isinstance(x, P))


def zero_extend_spec(spec: P, shape: Sequence[int], mesh: Mesh,
                     axis: str = "data") -> P:
    """ZeRO: additionally shard the optimizer moment over the data axis on
    the largest dim that is unsharded and divisible.  No-op when the spec
    already uses the axis (FSDP'd params — a mesh axis may appear at most
    once per spec)."""
    if axis not in mesh.axis_names:
        return spec
    for part in spec:
        axes = part if isinstance(part, tuple) else (part,)
        if axis in axes:
            return spec
    n = mesh.shape[axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = -1, 0
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % n == 0 and s > best_size:
            best, best_size = i, s
    if best < 0:
        return spec
    parts[best] = axis
    return P(*parts)


def zero_specs(param_specs: PyTree, params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda spec, p: zero_extend_spec(spec, p.shape, mesh),
        param_specs, params, is_leaf=lambda x: isinstance(x, P))


def _path_str(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def derive_state_specs(state_sds: PyTree, param_specs: PyTree,
                       params_sds: PyTree, *, mesh: Optional[Mesh] = None,
                       zero: bool = False) -> PyTree:
    """PartitionSpecs for an optimizer/train state pytree.

    Every optimizer moment inherits its parameter's spec by name matching:
    a state leaf whose path ends with a parameter path gets that parameter's
    spec; a trailing ``row``/``col`` component (Adafactor's factored second
    moment) drops the corresponding trailing spec axis.  Scalars and
    unmatched leaves are replicated.  With ``zero=True`` full-shape moments
    are additionally sharded over the data axis (ZeRO)."""
    by_name = {}
    pflat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    sflat = jax.tree_util.tree_leaves(param_specs,
                                      is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(pflat, sflat):
        by_name[_path_str(path)] = (spec, tuple(leaf.shape))

    def visit(path, leaf):
        parts = _path_str(path).split("/")
        shape = tuple(getattr(leaf, "shape", ()))
        for i in range(len(parts)):
            cand = "/".join(parts[i:])
            if cand in by_name:
                spec, pshape = by_name[cand]
                if shape == pshape:
                    if zero and mesh is not None:
                        return zero_extend_spec(spec, shape, mesh)
                    return spec
            if parts[-1] in ("row", "col"):
                base = "/".join(parts[i:-1])
                if base in by_name:
                    spec, pshape = by_name[base]
                    full = list(spec) + [None] * (len(pshape) - len(spec))
                    if parts[-1] == "row" and shape == pshape[:-1]:
                        return P(*full[:-1])
                    if parts[-1] == "col" and shape == pshape[:-2] + pshape[-1:]:
                        return P(*(full[:-2] + full[-1:]))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(visit, state_sds)


def fsdp_specs(param_specs: PyTree, params_sds: PyTree, mesh: Mesh) -> PyTree:
    """FSDP: additionally shard every parameter over the data axis (largest
    unsharded divisible dim) — required for the 400B-class archs whose
    model-axis-only shards exceed one chip's HBM."""
    return jax.tree_util.tree_map(
        lambda spec, p: zero_extend_spec(spec, p.shape, mesh),
        param_specs, params_sds, is_leaf=lambda x: isinstance(x, P))


def count_sharded_bytes(tree: PyTree, specs: PyTree, mesh: Mesh) -> int:
    """Per-device bytes of a sharded pytree (for memory budgeting)."""
    total = 0
    for leaf, spec in zip(jax.tree_util.tree_leaves(tree),
                          jax.tree_util.tree_leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        size = np.prod(leaf.shape) * jax.numpy.dtype(leaf.dtype).itemsize
        denom = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= mesh.shape[a]
        total += int(size / denom)
    return total
