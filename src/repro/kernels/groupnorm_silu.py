"""Fused GroupNorm + SiLU — the UNet's ubiquitous pre-conv activation.

One VMEM round-trip instead of three (norm stats, affine, silu): the block
is a full (H, W, C) feature map per batch element, group statistics are
computed in-register, and the normalise+affine+silu epilogue is fused.
Feature maps larger than VMEM fall back to a channel-grouped two-pass
variant (grid over batch only is fine for all assigned latent sizes:
128×128×320×4B ≈ 2.6 MiB/block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams


def _gn_kernel(x_ref, scale_ref, bias_ref, o_ref, *, groups: int, eps: float):
    x = x_ref[0].astype(jnp.float32)               # (H, W, C)
    h, w, c = x.shape
    cg = c // groups
    xg = x.reshape(h * w, groups, cg)
    mean = jnp.mean(xg, axis=(0, 2), keepdims=True)          # (1, G, 1)
    var = jnp.mean(jnp.square(xg - mean), axis=(0, 2), keepdims=True)
    xn = (xg - mean) * jax.lax.rsqrt(var + eps)
    y = xn.reshape(h, w, c) * scale_ref[...].astype(jnp.float32) \
        + bias_ref[...].astype(jnp.float32)
    o_ref[0] = (y * jax.nn.sigmoid(y)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "eps", "interpret"))
def groupnorm_silu(x, scale, bias, *, groups: int = 32, eps: float = 1e-5,
                   interpret: bool = True):
    """x: (B, H, W, C); scale/bias: (C,) → silu(groupnorm(x))."""
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    kernel = functools.partial(_gn_kernel, groups=g, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda bi: (bi, 0, 0, 0)),
            pl.BlockSpec((c,), lambda bi: (0,)),
            pl.BlockSpec((c,), lambda bi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda bi: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale, bias)
