# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas TPU kernels + version-compat shims.

JAX renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` across
releases; the installed version may carry either name.  Every kernel in
this package imports :data:`CompilerParams` from here so the rename never
breaks the suite again.
"""
from jax.experimental.pallas import tpu as _pltpu

try:  # newer JAX
    CompilerParams = _pltpu.CompilerParams
except AttributeError:  # older JAX (e.g. 0.4.x)
    CompilerParams = _pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
