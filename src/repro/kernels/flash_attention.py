"""Flash attention for TPU — online-softmax with VMEM-tiled BlockSpecs.

Grid layout: ``(batch, heads, q_blocks, k_blocks)`` with the k-block axis
innermost and sequential — the running max / sum / accumulator live in VMEM
scratch and persist across k iterations, exactly the memory-hierarchy-aware
structure flash attention needs on TPU:

  HBM  → (block_q × d) Q tile, (block_k × d) K/V tiles streamed per step
  VMEM → running m/l/acc scratch (block_q × d floats)
  MXU  → q·kᵀ and p·v contractions, 128-aligned tiles

Sequence padding and causality are handled by an in-kernel iota mask, so
arbitrary (non-multiple) lengths are correct.  Validated in interpret mode
against ``ref.flash_attention_ref`` over shape/dtype sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int, kv_len: int, q_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)          # (block_k, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = cols < kv_len                         # padded-K validity
    if causal:
        # kv_len >= q_len aligns the END of q to the END of k (the
        # prefill/decode convention): row r attends keys ≤ r + (Sk − Sq)
        rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        mask = mask & (rows + (kv_len - q_len) >= cols)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                           # (block_q, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # (block_q, block_k)
    correction = jnp.exp(m_prev - m_new)          # (block_q, 1)
    l_new = correction * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * correction + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = False, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, H, D) → (B, Sq, H, D).

    Sequence lengths are padded to the block size internally; D should be a
    multiple of 128 on real TPUs (MXU alignment) but any D works in
    interpret mode.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, max(sq, 1))
    block_k = min(block_k, max(sk, 1))
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k

    qt = jnp.moveaxis(q, 2, 1)                    # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    n_q, n_k = sq_p // block_q, sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k, kv_len=sk, q_len=sq)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)
