"""Fused cosine-similarity + streaming top-k for the vector DB scan.

The paper's retrieval hot-spot (pgvector ANN scan) reimagined for TPU:
instead of a GPU warp-level heap, the database streams through VMEM in
``block_n`` tiles, the (Q × block_n) similarity tile is one MXU matmul,
and a running per-query top-k lives in VMEM scratch across grid steps
(the k-block axis is sequential).  Selection uses k rounds of
max+mask — argmax-free and Mosaic-friendly — which is cheap for the small
k (≤ 32) a cache lookup needs.

HBM traffic: each database row is read exactly once → the scan is
memory-bound at ~N·D·dtype bytes, the roofline optimum for one-shot
retrieval.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30


def _vdb_kernel(q_ref, db_ref, valid_ref, score_out, idx_out,
                best_s, best_i, *, k: int, block_n: int, n_blocks: int,
                n_total: int):
    ni = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        best_s[...] = jnp.full_like(best_s, NEG_INF)
        best_i[...] = jnp.zeros_like(best_i)

    q = q_ref[...].astype(jnp.float32)           # (Q, D)
    db = db_ref[...].astype(jnp.float32)         # (block_n, D)
    valid = valid_ref[...]                       # (1, block_n) int32

    s = jax.lax.dot_general(q, db, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, block_n)
    cols = ni * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (valid > 0) & (cols < n_total)
    s = jnp.where(ok, s, NEG_INF)

    # merge tile scores into the running top-k: k rounds of max+mask over
    # the concatenated (k + block_n) candidates
    cand_s = jnp.concatenate([best_s[...], s], axis=1)          # (Q, k+bn)
    cand_i = jnp.concatenate([best_i[...], cols], axis=1)
    new_s = jnp.zeros_like(best_s[...])
    new_i = jnp.zeros_like(best_i[...])
    for j in range(k):
        m = jnp.max(cand_s, axis=1, keepdims=True)              # (Q, 1)
        # first position achieving the max
        is_max = cand_s == m
        first = jnp.cumsum(is_max.astype(jnp.int32), axis=1) == 1
        pick = is_max & first
        picked_i = jnp.sum(jnp.where(pick, cand_i, 0), axis=1, keepdims=True)
        new_s = jax.lax.dynamic_update_slice(new_s, m, (0, j))
        new_i = jax.lax.dynamic_update_slice(new_i, picked_i, (0, j))
        cand_s = jnp.where(pick, NEG_INF, cand_s)
    best_s[...] = new_s
    best_i[...] = new_i

    @pl.when(ni == n_blocks - 1)
    def _finalize():
        score_out[...] = best_s[...].astype(score_out.dtype)
        idx_out[...] = best_i[...]


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def vdb_topk(queries, db, valid, k: int, *, block_n: int = 512,
             interpret: bool = True):
    """queries: (Q, D); db: (N, D); valid: (N,) bool → (scores, idx) (Q, k)."""
    qn, d = queries.shape
    n = db.shape[0]
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        db = jnp.pad(db, ((0, pad_n), (0, 0)))
        valid = jnp.pad(valid, (0, pad_n))
    n_p = n + pad_n
    n_blocks = n_p // block_n
    valid_i = valid.astype(jnp.int32).reshape(1, n_p)

    kernel = functools.partial(_vdb_kernel, k=k, block_n=block_n,
                               n_blocks=n_blocks, n_total=n)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((qn, d), lambda ni: (0, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
            pl.BlockSpec((1, block_n), lambda ni: (0, ni)),
        ],
        out_specs=[
            pl.BlockSpec((qn, k), lambda ni: (0, 0)),
            pl.BlockSpec((qn, k), lambda ni: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, k), jnp.float32),
            pltpu.VMEM((qn, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(queries, db, valid_i)
    return scores, idx
