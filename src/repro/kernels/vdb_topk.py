"""Fused cosine-similarity + streaming top-k for the vector DB scan.

The paper's retrieval hot-spot (pgvector ANN scan) reimagined for TPU:
instead of a GPU warp-level heap, the database streams through VMEM in
``block_n`` tiles, the (Q × block_n) similarity tile is one MXU matmul,
and a running per-query top-k lives in VMEM scratch across grid steps
(the k-block axis is sequential).  Selection uses k rounds of
max+mask — argmax-free and Mosaic-friendly — which is cheap for the small
k (≤ 32) a cache lookup needs.

Three entry points:

* :func:`vdb_topk` — one database slab (one node, one index), the PR-1
  kernel.
* :func:`vdb_topk_sharded` — the cluster-wide scan: BOTH dual-retrieval
  indexes of EVERY node in one launch, grid ``(index, node, db_block)``,
  with a query→node mask so each request only scores its scheduled
  node's slab (``mask_nodes=False`` turns the same launch into an
  all-nodes cluster scan over one global candidate list).
* :func:`vdb_topk_pernode` — the scheduling scan: same grid and the same
  single pass over the slabs, but the running top-k resets at every node
  boundary and is written out PER NODE, so one launch yields every
  query's top-k within every node's slab.  This is what score-aware
  request scheduling needs (each node's own best match, which a global
  top-k from one hot node could hide) and what lets the Schedule and
  Retrieve stages share a single scan.

Mesh-sharded variants (:func:`vdb_topk_sharded_mesh` /
:func:`vdb_topk_pernode_mesh`) run the SAME per-node scans inside
``shard_map`` over a 1-D ``"nodes"`` device mesh: each device scans only
its local node shard of the stacked slabs and only the per-node best-k
rows (scores + global slot ids) ever leave a device — never the slabs.
The cross-shard reduction of the global modes is
:func:`merge_shard_topk`, whose (score desc, global-slot-id asc)
ordering reproduces both single-device scans' tie-break bitwise (the
fix for the classic all-gather reordering bug on equal scores
straddling a shard boundary).

``interpret`` defaults to ``None`` = backend-aware: compile through
Mosaic whenever a TPU backend is present, fall back to interpret mode
elsewhere (CPU containers, unit tests), so ``use_pallas=True`` actually
compiles on real hardware.

HBM traffic: each database row is read exactly once → the scan is
memory-bound at ~N·D·dtype bytes, the roofline optimum for one-shot
retrieval.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams

NEG_INF = -1e30


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Backend-aware interpret default: only interpret when no TPU/Mosaic
    backend is available to compile the kernel."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _vdb_kernel(q_ref, db_ref, valid_ref, score_out, idx_out,
                best_s, best_i, *, k: int, block_n: int, n_blocks: int,
                n_total: int):
    ni = pl.program_id(0)

    @pl.when(ni == 0)
    def _init():
        best_s[...] = jnp.full_like(best_s, NEG_INF)
        best_i[...] = jnp.zeros_like(best_i)

    q = q_ref[...].astype(jnp.float32)           # (Q, D)
    db = db_ref[...].astype(jnp.float32)         # (block_n, D)
    valid = valid_ref[...]                       # (1, block_n) int32

    s = jax.lax.dot_general(q, db, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, block_n)
    cols = ni * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (valid > 0) & (cols < n_total)
    s = jnp.where(ok, s, NEG_INF)
    _merge_topk(best_s, best_i, s, cols, k)

    @pl.when(ni == n_blocks - 1)
    def _finalize():
        score_out[...] = best_s[...].astype(score_out.dtype)
        idx_out[...] = best_i[...]


def _merge_topk(best_s, best_i, s, cand_cols, k: int) -> None:
    """Merge one similarity tile into the running top-k: k rounds of
    max+mask over the concatenated (k + block_n) candidates."""
    cand_s = jnp.concatenate([best_s[...], s], axis=1)          # (Q, k+bn)
    cand_i = jnp.concatenate([best_i[...], cand_cols], axis=1)
    new_s = jnp.zeros_like(best_s[...])
    new_i = jnp.zeros_like(best_i[...])
    for j in range(k):
        m = jnp.max(cand_s, axis=1, keepdims=True)              # (Q, 1)
        # first position achieving the max
        is_max = cand_s == m
        first = jnp.cumsum(is_max.astype(jnp.int32), axis=1) == 1
        pick = is_max & first
        picked_i = jnp.sum(jnp.where(pick, cand_i, 0), axis=1, keepdims=True)
        new_s = jax.lax.dynamic_update_slice(new_s, m, (0, j))
        new_i = jax.lax.dynamic_update_slice(new_i, picked_i, (0, j))
        cand_s = jnp.where(pick, NEG_INF, cand_s)
    best_s[...] = new_s
    best_i[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def vdb_topk(queries, db, valid, k: int, *, block_n: int = 512,
             interpret: Optional[bool] = None):
    """queries: (Q, D); db: (N, D); valid: (N,) bool → (scores, idx) (Q, k)."""
    interpret = resolve_interpret(interpret)
    qn, d = queries.shape
    n = db.shape[0]
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        db = jnp.pad(db, ((0, pad_n), (0, 0)))
        valid = jnp.pad(valid, (0, pad_n))
    n_p = n + pad_n
    n_blocks = n_p // block_n
    valid_i = valid.astype(jnp.int32).reshape(1, n_p)

    kernel = functools.partial(_vdb_kernel, k=k, block_n=block_n,
                               n_blocks=n_blocks, n_total=n)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((qn, d), lambda ni: (0, 0)),
            pl.BlockSpec((block_n, d), lambda ni: (ni, 0)),
            pl.BlockSpec((1, block_n), lambda ni: (0, ni)),
        ],
        out_specs=[
            pl.BlockSpec((qn, k), lambda ni: (0, 0)),
            pl.BlockSpec((qn, k), lambda ni: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, k), jnp.float32),
            pltpu.VMEM((qn, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(queries, db, valid_i)
    return scores, idx


def _vdb_sharded_kernel(q_ref, slab_ref, valid_ref, nid_ref, score_out,
                        idx_out, best_s, best_i, *, k: int, block_n: int,
                        n_blocks: int, n_nodes: int, capacity: int,
                        mask_nodes: bool, per_node: bool = False):
    """Shared body of the cluster scan.  ``per_node=False`` keeps ONE
    running top-k across the whole (node, block) sweep of an index plane
    (global candidate list, optional query→node mask); ``per_node=True``
    resets the running top-k at every node boundary and flushes it per
    (plane, node) — same loads, same merge, different reduction."""
    ni = pl.program_id(1)                        # node
    bi = pl.program_id(2)                        # db block within the node

    new_reduction = bi == 0 if per_node else (ni == 0) & (bi == 0)

    @pl.when(new_reduction)
    def _init():
        best_s[...] = jnp.full_like(best_s, NEG_INF)
        best_i[...] = jnp.zeros_like(best_i)

    q = q_ref[...].astype(jnp.float32)           # (Q, D)
    db = slab_ref[0, 0].astype(jnp.float32)      # (block_n, D)
    valid = valid_ref[...]                       # (1, block_n) int32

    s = jax.lax.dot_general(q, db, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, bn)
    cols = bi * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = (valid > 0) & (cols < capacity)
    if mask_nodes and not per_node:
        nid = nid_ref[...]                       # (1, Q) int32
        ok = ok & (nid.reshape(-1, 1) == ni)     # query sees only its node
    s = jnp.where(ok, s, NEG_INF)
    _merge_topk(best_s, best_i, s, ni * capacity + cols, k)

    done = (bi == n_blocks - 1 if per_node
            else (ni == n_nodes - 1) & (bi == n_blocks - 1))

    @pl.when(done)
    def _finalize():
        score_out[...] = best_s[...].reshape(score_out.shape) \
            .astype(score_out.dtype)
        idx_out[...] = best_i[...].reshape(idx_out.shape)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "mask_nodes",
                                             "interpret"))
def vdb_topk_sharded(queries, slabs, valid, node_ids, k: int, *,
                     block_n: int = 512, mask_nodes: bool = True,
                     interpret: Optional[bool] = None):
    """Cluster-wide fused scan: all queries × all node slabs × both
    dual-retrieval indexes in ONE launch.

    queries: (Q, D); slabs: (n_idx, nodes, capacity, D) — the stacked
    device-resident cache state (``n_idx`` = 2 for the img/txt dual
    index); valid: (nodes, capacity) bool; node_ids: (Q,) int32 — the
    scheduler's node assignment per query (ignored when
    ``mask_nodes=False``: every query then scans the whole cluster).

    Returns ``(scores, idx)`` of shape (n_idx, Q, k); ``idx`` is the
    GLOBAL slot id ``node * capacity + col``.  Masked candidates carry
    the ``NEG_INF`` sentinel.

    The grid is ``(index, node, db_block)`` with the per-query running
    top-k in VMEM scratch across the whole (node, block) sweep of each
    index plane — every slab row is read exactly once per launch, so the
    scan stays memory-bound at ~n_idx·nodes·capacity·D·dtype bytes
    regardless of node count.
    """
    interpret = resolve_interpret(interpret)
    n_idx, n_nodes, cap, d = slabs.shape
    qn = queries.shape[0]
    block_n = min(block_n, cap)
    pad_c = (-cap) % block_n
    if pad_c:
        slabs = jnp.pad(slabs, ((0, 0), (0, 0), (0, pad_c), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad_c)))
    cap_p = cap + pad_c
    n_blocks = cap_p // block_n
    valid_i = valid.astype(jnp.int32)
    nid = node_ids.astype(jnp.int32).reshape(1, qn)

    kernel = functools.partial(_vdb_sharded_kernel, k=k, block_n=block_n,
                               n_blocks=n_blocks, n_nodes=n_nodes,
                               capacity=cap, mask_nodes=mask_nodes)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(n_idx, n_nodes, n_blocks),
        in_specs=[
            pl.BlockSpec((qn, d), lambda ii, ni, bi: (0, 0)),
            pl.BlockSpec((1, 1, block_n, d),
                         lambda ii, ni, bi: (ii, ni, bi, 0)),
            pl.BlockSpec((1, block_n), lambda ii, ni, bi: (ni, bi)),
            pl.BlockSpec((1, qn), lambda ii, ni, bi: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qn, k), lambda ii, ni, bi: (ii, 0, 0)),
            pl.BlockSpec((1, qn, k), lambda ii, ni, bi: (ii, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_idx, qn, k), jnp.float32),
            jax.ShapeDtypeStruct((n_idx, qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, k), jnp.float32),
            pltpu.VMEM((qn, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(queries, slabs, valid_i, nid)
    return scores, idx


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def vdb_topk_pernode(queries, slabs, valid, k: int, *,
                     block_n: int = 512,
                     interpret: Optional[bool] = None):
    """Per-node cluster scan: all queries × all node slabs × both
    dual-retrieval indexes in ONE launch, top-k kept PER NODE.

    queries: (Q, D); slabs: (n_idx, nodes, capacity, D); valid:
    (nodes, capacity) bool.  Returns ``(scores, idx)`` of shape
    (n_idx, nodes, Q, k); ``idx`` is the GLOBAL slot id
    ``node * capacity + col``.  Masked candidates carry ``NEG_INF``.

    Identical slab traffic to :func:`vdb_topk_sharded` (every row read
    exactly once per launch) and the SAME kernel body
    (:func:`_vdb_sharded_kernel` with ``per_node=True``); only the
    reduction differs — the VMEM running top-k resets at each node
    boundary and the finalize fires once per (index, node) instead of
    once per index plane.  This is the one device scan that feeds BOTH
    score-aware scheduling (per-node best match for every request) and
    the chosen node's retrieval candidates.
    """
    interpret = resolve_interpret(interpret)
    n_idx, n_nodes, cap, d = slabs.shape
    qn = queries.shape[0]
    block_n = min(block_n, cap)
    pad_c = (-cap) % block_n
    if pad_c:
        slabs = jnp.pad(slabs, ((0, 0), (0, 0), (0, pad_c), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad_c)))
    cap_p = cap + pad_c
    n_blocks = cap_p // block_n
    valid_i = valid.astype(jnp.int32)
    nid = jnp.zeros((1, qn), jnp.int32)          # unused in per-node mode

    kernel = functools.partial(_vdb_sharded_kernel, k=k, block_n=block_n,
                               n_blocks=n_blocks, n_nodes=n_nodes,
                               capacity=cap, mask_nodes=False,
                               per_node=True)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(n_idx, n_nodes, n_blocks),
        in_specs=[
            pl.BlockSpec((qn, d), lambda ii, ni, bi: (0, 0)),
            pl.BlockSpec((1, 1, block_n, d),
                         lambda ii, ni, bi: (ii, ni, bi, 0)),
            pl.BlockSpec((1, block_n), lambda ii, ni, bi: (ni, bi)),
            pl.BlockSpec((1, qn), lambda ii, ni, bi: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, qn, k), lambda ii, ni, bi: (ii, ni, 0, 0)),
            pl.BlockSpec((1, 1, qn, k), lambda ii, ni, bi: (ii, ni, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_idx, n_nodes, qn, k), jnp.float32),
            jax.ShapeDtypeStruct((n_idx, n_nodes, qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((qn, k), jnp.float32),
            pltpu.VMEM((qn, k), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(queries, slabs, valid_i, nid)
    return scores, idx


# ---------------------------------------------------------------------------
# mesh-sharded cluster scans (shard_map over the per-node grid axis)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _mesh_scan_fn(mesh, n_shard: int, capacity: int, k: int,
                  mask_nodes: bool, per_node: bool, use_pallas: bool,
                  interpret: bool, block_n: int):
    """Build (and cache) the jitted ``shard_map`` wrapper for one scan
    configuration.  Each device runs the unmodified single-device scan —
    Pallas kernel or jnp ref — over its LOCAL ``(n_idx, n_shard,
    capacity, dim)`` slab shard, then globalises the slot ids by its
    shard offset.  ``check_rep=False`` because ``pallas_call`` has no
    replication rule; every output here is explicitly sharded anyway.

    Cache note: keying on the hashable ``Mesh`` keeps one executable per
    (mesh, shape, mode) across ClusterIndex rebuilds/restacks."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(slabs_l, valid_l, queries, node_ids):
        shard = jax.lax.axis_index("nodes")
        offset = shard * n_shard * capacity
        if per_node:
            if use_pallas:
                s, i = vdb_topk_pernode(queries, slabs_l, valid_l, k,
                                        block_n=block_n,
                                        interpret=interpret)
            else:
                from repro.kernels.ref import vdb_topk_pernode_ref
                s, i = vdb_topk_pernode_ref(queries, slabs_l, valid_l, k)
            return s, i + offset
        # global modes: node ids become shard-local (queries scheduled on
        # another shard's node match nothing here — their candidates come
        # from the owning shard's list at merge time)
        nids_l = node_ids - shard * n_shard
        if use_pallas:
            s, i = vdb_topk_sharded(queries, slabs_l, valid_l, nids_l, k,
                                    block_n=block_n, mask_nodes=mask_nodes,
                                    interpret=interpret)
        else:
            from repro.kernels.ref import vdb_topk_sharded_ref
            s, i = vdb_topk_sharded_ref(queries, slabs_l, valid_l, nids_l,
                                        k, mask_nodes=mask_nodes)
        return s[None], (i + offset)[None]

    out_specs = ((P(None, "nodes", None, None),) * 2 if per_node
                 else (P("nodes", None, None, None),) * 2)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "nodes", None, None), P("nodes", None),
                  P(None, None), P(None)),
        out_specs=out_specs, check_rep=False)
    return jax.jit(fn)


def vdb_topk_sharded_mesh(queries, slabs, valid, node_ids, k: int, *,
                          mesh, block_n: int = 512, mask_nodes: bool = True,
                          use_pallas: bool = False,
                          interpret: Optional[bool] = None):
    """Mesh-sharded global cluster scan.

    ``slabs``: (n_idx, padded_nodes, capacity, D) sharded along the node
    axis over ``mesh`` (padded_nodes a multiple of the mesh size, pad
    nodes masked invalid); ``valid``: (padded_nodes, capacity);
    ``node_ids``: (Q,) GLOBAL node assignment (ignored when
    ``mask_nodes=False``).

    Returns STACKED per-shard results ``(shards, n_idx, Q, k)`` with
    GLOBAL slot ids ``node * capacity + col`` — the all-gather payload
    (k rows per query per shard, never the slabs).  Reduce to the global
    top-k with :func:`merge_shard_topk`.
    """
    interpret = resolve_interpret(interpret)
    _, padded_nodes, cap, _ = slabs.shape
    n_shard = padded_nodes // mesh.shape["nodes"]
    fn = _mesh_scan_fn(mesh, n_shard, cap, k, bool(mask_nodes), False,
                       bool(use_pallas), interpret, block_n)
    return fn(slabs, valid, queries, node_ids.astype(jnp.int32))


def vdb_topk_pernode_mesh(queries, slabs, valid, k: int, *,
                          mesh, block_n: int = 512,
                          use_pallas: bool = False,
                          interpret: Optional[bool] = None):
    """Mesh-sharded per-node cluster scan (the schedule+retrieve fusion).

    Same sharded layout as :func:`vdb_topk_sharded_mesh`.  The per-node
    reduction needs NO cross-shard merge — each node's top-k is complete
    on its owning shard — so the result is simply reassembled along the
    node axis: ``(n_idx, padded_nodes, Q, k)`` with GLOBAL slot ids
    (bitwise what the single-device :func:`vdb_topk_pernode` returns for
    the real, unpadded nodes).
    """
    interpret = resolve_interpret(interpret)
    _, padded_nodes, cap, _ = slabs.shape
    n_shard = padded_nodes // mesh.shape["nodes"]
    fn = _mesh_scan_fn(mesh, n_shard, cap, k, False, True,
                       bool(use_pallas), interpret, block_n)
    qn = queries.shape[0]
    return fn(slabs, valid, queries, jnp.zeros((qn,), jnp.int32))


def merge_shard_topk(scores, idx, k: int):
    """Exact cross-shard reduction of stacked per-shard top-k lists.

    ``scores``/``idx``: (shards, n_idx, Q, k_local) numpy arrays with
    GLOBAL slot ids.  Returns the global ``(n_idx, Q, k)`` top-k ordered
    by (score desc, global slot id asc) — the SAME tie-break both
    single-device scans produce (``jax.lax.top_k`` keeps the lower flat
    index on ties; the Pallas streaming merge encounters slots in
    ascending global order and keeps the first seen), so equal-score
    candidates straddling a shard boundary rank identically to the
    unsharded scan instead of in all-gather arrival order.
    """
    import numpy as np
    shards, n_idx, qn, kl = scores.shape
    flat_s = np.ascontiguousarray(
        np.transpose(scores, (1, 2, 0, 3))).reshape(n_idx, qn, shards * kl)
    flat_i = np.ascontiguousarray(
        np.transpose(idx, (1, 2, 0, 3))).reshape(n_idx, qn, shards * kl)
    k = min(k, shards * kl)
    order = np.lexsort((flat_i, -flat_s), axis=-1)[..., :k]
    return (np.take_along_axis(flat_s, order, -1),
            np.take_along_axis(flat_i, order, -1))
