"""Pure-jnp oracles for every Pallas kernel.  Tests sweep shapes/dtypes and
assert_allclose kernel(interpret=True) against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, H, D) -> (B, Sq, H, D)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), jnp.bool_), k=sk - sq)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def vdb_topk_ref(queries, db, valid, k: int):
    """queries: (Q, D) L2-normalised; db: (N, D); valid: (N,) bool.
    Returns (scores (Q, k), idx (Q, k)) by cosine similarity."""
    scores = queries @ db.T
    scores = jnp.where(valid[None, :], scores, -jnp.inf)
    return jax.lax.top_k(scores, k)


def vdb_topk_sharded_ref(queries, slabs, valid, node_ids, k: int, *,
                         mask_nodes: bool = True):
    """queries: (Q, D); slabs: (n_idx, nodes, cap, D); valid: (nodes, cap);
    node_ids: (Q,).  Returns (scores, idx) of shape (n_idx, Q, k) with
    GLOBAL slot ids ``node * cap + col``; masked candidates are -inf.

    Shape-generic on the node axis, so the mesh-sharded scan
    (``vdb_topk_sharded_mesh``) reuses this oracle verbatim per device
    over its LOCAL node shard — shard-local node ids in, shard-local
    slot ids out, offset to global by the caller.  Ties (equal scores,
    and every -inf row) resolve to the LOWER flat index via
    ``jax.lax.top_k`` — the ordering contract the cross-shard merge
    reproduces."""
    n_idx, n_nodes, cap, _ = slabs.shape
    scores = jnp.einsum("qd,incd->iqnc", queries, slabs)
    ok = valid[None, None, :, :]
    if mask_nodes:
        ok = ok & (node_ids[None, :, None, None]
                   == jnp.arange(n_nodes)[None, None, :, None])
    scores = jnp.where(ok, scores, -jnp.inf)
    flat = scores.reshape(n_idx, scores.shape[1], n_nodes * cap)
    return jax.lax.top_k(flat, k)


def vdb_topk_pernode_ref(queries, slabs, valid, k: int):
    """Per-node variant of the cluster scan: every query's top-k within
    EVERY node's slab (the schedule+retrieve fusion needs each node's own
    candidate set, not one global list a hot node could monopolise).

    queries: (Q, D); slabs: (n_idx, nodes, cap, D); valid: (nodes, cap).
    Returns (scores, idx) of shape (n_idx, nodes, Q, k) with GLOBAL slot
    ids ``node * cap + col``; masked candidates are -inf.  Shape-generic
    on the node axis (the mesh-sharded scan runs it per device on the
    local shard; per-node results need no cross-shard merge)."""
    n_idx, n_nodes, cap, _ = slabs.shape
    scores = jnp.einsum("qd,incd->inqc", queries, slabs)
    scores = jnp.where(valid[None, :, None, :], scores, -jnp.inf)
    s, col = jax.lax.top_k(scores, k)
    gidx = col + (jnp.arange(n_nodes) * cap)[None, :, None, None]
    return s, gidx


def groupnorm_silu_ref(x, scale, bias, *, groups: int = 32, eps: float = 1e-5):
    """x: (B, H, W, C) -> silu(groupnorm(x))."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.mean(jnp.square(xg - mean), axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(b, h, w, c) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return (y * jax.nn.sigmoid(y)).astype(dtype)


def adaln_modulate_ref(x, shift, scale, *, eps: float = 1e-5):
    """Fused LN(affine-free) + adaLN modulation.
    x: (B, T, D); shift/scale: (B, D)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    y = xn * (1.0 + scale.astype(jnp.float32)[:, None, :]) \
        + shift.astype(jnp.float32)[:, None, :]
    return y.astype(dtype)
