"""Fused affine-free LayerNorm + adaLN modulation (DiT block prologue).

y = LN(x) * (1 + scale[b]) + shift[b], fused into one VMEM pass: the DiT
calls this twice per block, and unfused it costs three HBM round-trips of
the (B, T, D) activation.  Token-tiled BlockSpec: (1, block_t, D) per grid
step, per-row statistics in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import CompilerParams


def _adaln_kernel(x_ref, shift_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[0].astype(jnp.float32)               # (block_t, D)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    sh = shift_ref[0].astype(jnp.float32)          # (1, D) row for batch b
    sc = scale_ref[0].astype(jnp.float32)
    o_ref[0] = (xn * (1.0 + sc) + sh).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "eps", "interpret"))
def adaln_modulate(x, shift, scale, *, block_t: int = 256, eps: float = 1e-5,
                   interpret: bool = True):
    """x: (B, T, D); shift/scale: (B, D) → (B, T, D)."""
    b, t, d = x.shape
    block_t = min(block_t, t)
    pad_t = (-t) % block_t
    if pad_t:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
    n_t = (t + pad_t) // block_t
    kernel = functools.partial(_adaln_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(b, n_t),
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, 1, d), lambda bi, ti: (bi, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda bi, ti: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t + pad_t, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, shift.reshape(b, 1, d), scale.reshape(b, 1, d))
    return out[:, :t]
