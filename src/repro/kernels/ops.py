"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the compiled kernels run natively; elsewhere (this CPU container,
unit tests) they execute in interpret mode, which runs the *same kernel
body* in Python-on-XLA for bit-accurate validation against ``ref.py``.
"""
from __future__ import annotations

from repro.kernels import ref
from repro.kernels.adaln import adaln_modulate as _adaln_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.groupnorm_silu import groupnorm_silu as _gn_pallas
from repro.kernels.vdb_topk import vdb_topk as _vdb_pallas
from repro.kernels.vdb_topk import vdb_topk_sharded as _vdb_sharded_pallas


def _interpret() -> bool:
    # single source of truth for the backend-aware interpret rule
    from repro.kernels.vdb_topk import resolve_interpret
    return resolve_interpret(None)


def flash_attention(q, k, v, *, causal: bool = False, block_q: int = 128,
                    block_k: int = 128):
    return _flash_pallas(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=_interpret())


def vdb_topk(queries, db, valid, k: int, *, block_n: int = 512):
    return _vdb_pallas(queries, db, valid, k, block_n=block_n,
                       interpret=_interpret())


def vdb_topk_sharded(queries, slabs, valid, node_ids, k: int, *,
                     block_n: int = 512, mask_nodes: bool = True):
    return _vdb_sharded_pallas(queries, slabs, valid, node_ids, k,
                               block_n=block_n, mask_nodes=mask_nodes,
                               interpret=_interpret())


def groupnorm_silu(x, scale, bias, *, groups: int = 32):
    return _gn_pallas(x, scale, bias, groups=groups, interpret=_interpret())


def adaln_modulate(x, shift, scale, *, block_t: int = 256):
    return _adaln_pallas(x, shift, scale, block_t=block_t,
                         interpret=_interpret())


# re-export oracles for convenience
flash_attention_ref = ref.flash_attention_ref
vdb_topk_ref = ref.vdb_topk_ref
vdb_topk_sharded_ref = ref.vdb_topk_sharded_ref
groupnorm_silu_ref = ref.groupnorm_silu_ref
adaln_modulate_ref = ref.adaln_modulate_ref
