"""repro — CacheGenius-JAX: semantic-aware caching for diffusion serving.

A production-grade JAX framework reproducing and extending
"Semantic-Aware Caching for Efficient Image Generation in Edge Computing"
(CacheGenius, CS.NI 2025).

Layout:
  repro.core       — the paper's contribution (cache, scheduler, LCU, policy)
  repro.models     — model zoo (LM / diffusion / vision)
  repro.kernels    — Pallas TPU kernels + jnp oracles
  repro.data       — synthetic captioned-image corpus + pipeline
  repro.optim      — optimizer stack
  repro.checkpoint — sharded checkpointing / restore / elastic reshard
  repro.runtime    — partitioning, step builders, train loop, serving engine
  repro.configs    — assigned architecture configs + input-shape cells
  repro.launch     — mesh, dry-run, roofline, drivers
"""

__version__ = "1.0.0"
