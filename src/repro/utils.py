"""Small shared utilities: pytree param helpers, counting, dtype tools."""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def param_count(tree: PyTree) -> int:
    """Total number of scalar parameters in a pytree of arrays/ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(math.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def param_bytes(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
    return int(total)


def tree_map_with_path_names(fn: Callable[[str, Any], Any], tree: PyTree) -> PyTree:
    """tree_map where fn receives a '/'-joined string path."""

    def _name(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_name(p), x), tree)


def cast_floating(tree: PyTree, dtype) -> PyTree:
    """Cast floating leaves of a pytree to `dtype`, leaving ints alone."""

    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def split_key_like_tree(key: jax.Array, tree: PyTree) -> PyTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))


def assert_no_nans(tree: PyTree, where: str = "") -> None:
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if bool(jnp.any(jnp.isnan(leaf))):
                raise AssertionError(f"NaN at {where}{jax.tree_util.keystr(path)}")


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


def l2n(x, axis: int = -1):
    """L2-normalise along ``axis`` with the project-wide 1e-12 floor."""
    x = np.asarray(x, np.float32)
    n = np.linalg.norm(x, axis=axis, keepdims=True)
    return x / np.maximum(n, 1e-12)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1).  The batched serving path pads
    query blocks and generation groups to these buckets so a handful of
    compiled shapes covers every micro-batch size."""
    b = 1
    while b < n:
        b *= 2
    return b


def stable_hash(s: str, mod: int) -> int:
    """Deterministic (process-independent) string hash into [0, mod)."""
    h = 1469598103934665603  # FNV-1a 64-bit
    for ch in s.encode("utf-8"):
        h ^= ch
        h = (h * 1099511628211) & 0xFFFFFFFFFFFFFFFF
    return h % mod
