import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract the roofline terms.

The two lines above MUST run before any other import (jax locks the device
count at first init).  Smoke tests and benches never import this module —
they see 1 device.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all 40 × 2
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b
    PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --skip-existing

Each cell writes ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` with
memory analysis, cost analysis, loop-weighted collective bytes and the
three roofline terms; EXPERIMENTS.md §Dry-run/§Roofline tables are built
from these files by ``benchmarks/roofline_table.py``.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, get_shape, list_archs
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, n_chips
from repro.models.diffusion import dit as dit_mod
from repro.models.diffusion import mmdit as mmdit_mod
from repro.models.diffusion import unet as unet_mod
from repro.models.diffusion import vae as vae_mod
from repro.models.vision import convnext as cnx_mod
from repro.models.vision import efficientnet as eff_mod
from repro.runtime.pspec import logical_rules
from repro.runtime.steps import build_cell_program

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shardings(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# useful-FLOPs reference per family
# ---------------------------------------------------------------------------


def model_flops_for(arch, cell, prog) -> Dict[str, Any]:
    fam = arch.family_group
    if fam == "lm":
        params_sds = (prog.args_sds[0]["params"] if cell.kind == "train"
                      else prog.args_sds[0])
        return roofline.lm_model_flops(arch, cell, params_sds)

    if fam == "diffusion":
        dcfg = arch.make_config(cell)
        latent = prog.meta["latent"]
        b = cell.global_batch
        key = jax.random.key(0)
        if dcfg.backbone == "dit":
            net_sds = jax.eval_shape(
                lambda k: dit_mod.init_dit(k, dcfg.net), key)
            fwd1 = roofline.measured_fwd_flops(
                lambda p, x, t, c: dit_mod.apply_dit(p, dcfg.net, x, t, c),
                (net_sds,
                 jax.ShapeDtypeStruct((1, latent, latent, dcfg.vae.z_ch),
                                      jnp.float32),
                 jax.ShapeDtypeStruct((1,), jnp.float32),
                 jax.ShapeDtypeStruct((1, dcfg.net.ctx_dim), jnp.float32)),
                (arch.name, "dit", latent))
        elif dcfg.backbone == "unet":
            net_sds = jax.eval_shape(
                lambda k: unet_mod.init_unet(k, dcfg.net), key)
            fwd1 = roofline.measured_fwd_flops(
                lambda p, x, t, c: unet_mod.apply_unet(p, dcfg.net, x, t, c),
                (net_sds,
                 jax.ShapeDtypeStruct((1, latent, latent, dcfg.vae.z_ch),
                                      jnp.float32),
                 jax.ShapeDtypeStruct((1,), jnp.float32),
                 jax.ShapeDtypeStruct((1, dcfg.ctx_len, dcfg.ctx_dim),
                                      jnp.float32)),
                (arch.name, "unet", latent))
        else:
            net_sds = jax.eval_shape(
                lambda k: mmdit_mod.init_mmdit(k, dcfg.net), key)
            ctx = {"txt": jax.ShapeDtypeStruct((1, dcfg.net.txt_len,
                                                dcfg.net.txt_dim), jnp.float32),
                   "vec": jax.ShapeDtypeStruct((1, dcfg.net.vec_dim),
                                               jnp.float32)}
            fwd1 = roofline.measured_fwd_flops(
                lambda p, x, t, c: mmdit_mod.apply_mmdit(p, dcfg.net, x, t, c),
                (net_sds,
                 jax.ShapeDtypeStruct((1, latent, latent, dcfg.vae.z_ch),
                                      jnp.float32),
                 jax.ShapeDtypeStruct((1,), jnp.float32), ctx),
                (arch.name, "mmdit", latent))
        if cell.kind == "train":
            res = latent * dcfg.vae.downsample
            vae_sds = jax.eval_shape(
                lambda k: vae_mod.init_vae(k, dcfg.vae), key)
            enc1 = roofline.measured_fwd_flops(
                lambda p, x: vae_mod.encode(p, dcfg.vae, x),
                (vae_sds, jax.ShapeDtypeStruct((1, res, res, 3), jnp.float32)),
                (arch.name, "vae_enc", res))
            mf = b * (3.0 * fwd1 + enc1)
            note = f"B*(3*fwd1 + vae_enc1), fwd1={fwd1:.3g} (measured)"
        else:
            mf = b * fwd1
            note = f"B*fwd1 per denoise step, fwd1={fwd1:.3g} (measured)"
        return {"model_flops": mf, "formula": note,
                "params_total": None, "params_active": None}

    # vision ----------------------------------------------------------------
    cfg = arch.make_config(cell)
    res = cell.img_res
    key = jax.random.key(0)
    if arch.family == "vision-convnext":
        net_sds = jax.eval_shape(lambda k: cnx_mod.init_convnext(k, cfg), key)
        fwd1 = roofline.measured_fwd_flops(
            lambda p, x: cnx_mod.apply_convnext(p, cfg, x),
            (net_sds, jax.ShapeDtypeStruct((1, res, res, 3), jnp.float32)),
            (arch.name, res))
    else:
        net_sds = jax.eval_shape(lambda k: eff_mod.init_effnet(k, cfg), key)
        fwd1 = roofline.measured_fwd_flops(
            lambda p, x: eff_mod.apply_effnet(p, cfg, x),
            (net_sds, jax.ShapeDtypeStruct((1, res, res, 3), jnp.float32)),
            (arch.name, res))
    mult = 3.0 if cell.kind == "train" else 1.0
    return {"model_flops": mult * cell.global_batch * fwd1,
            "formula": f"{mult:.0f}*B*fwd1, fwd1={fwd1:.3g} (measured)",
            "params_total": None, "params_active": None}


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             skip_model_flops: bool = False,
             save_hlo: Optional[str] = None,
             options: Optional[Dict[str, Any]] = None,
             submesh: Optional[tuple] = None) -> Dict[str, Any]:
    """``submesh=(d, m)``: lower on a (data=d, model=m) sub-mesh instead of
    the full pod — the serving-throughput variant (§Perf): one request per
    sub-mesh, pod-count/|submesh| requests in flight."""
    arch = get_arch(arch_name)
    cell = get_shape(arch.family_group, shape_name)
    if submesh is not None:
        mesh = jax.make_mesh(submesh, ("data", "model"))
        chips = int(submesh[0] * submesh[1])
        mesh_shape = {"data": submesh[0], "model": submesh[1]}
        mesh_tag = f"{submesh[0]}x{submesh[1]}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = n_chips(multi_pod)
        mesh_shape = None
        mesh_tag = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": mesh_tag, "chips": chips,
        "kind": cell.kind, "ok": False,
    }
    t0 = time.perf_counter()
    prog = build_cell_program(arch, cell, multi_pod=multi_pod,
                              options=options, mesh_shape=mesh_shape)
    in_sh = tuple(_shardings(s, mesh) for s in prog.in_specs)
    out_sh = _shardings(prog.out_specs, mesh) if prog.out_specs is not None \
        else None
    jit_kwargs: Dict[str, Any] = {"in_shardings": in_sh,
                                  "donate_argnums": prog.donate}
    if out_sh is not None:
        jit_kwargs["out_shardings"] = out_sh
    jitted = jax.jit(prog.step_fn, **jit_kwargs)
    with mesh:
        with logical_rules(prog.rules):
            lowered = jitted.lower(*prog.args_sds)
        rec["lower_s"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_estimate_bytes": int(mem.argument_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   + mem.output_size_in_bytes
                                   - mem.alias_size_in_bytes),
        # CPU-backend caveat: XLA's float-normalization-bf16 pass upcasts
        # every bf16 buffer to f32 on CPU (no native bf16), so temp_bytes
        # over-reports bf16 archs ~2× vs a real TPU compilation.  The
        # analytic budget below counts the sharded state + dominant
        # transients at their TRUE dtypes.
        "analytic_tpu_budget_bytes": _analytic_budget(arch, cell, prog,
                                                      multi_pod),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo_text = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo_text)
    weighted = roofline.hlo_cost(hlo_text)
    rec["cost"] = {
        # XLA static analysis (while bodies counted once — for reference)
        "xla_flops_per_device": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        # loop-weighted instruction model (used for the roofline terms)
        "flops_per_device": weighted.flops,
        "bytes_per_device": weighted.bytes,
        "dot_flops": weighted.dot_flops,
        "conv_flops": weighted.conv_flops,
    }
    coll = roofline.collective_stats(hlo_text)
    rec["collectives"] = {"operand_bytes": coll.operand_bytes,
                          "wire_bytes": coll.wire_bytes,
                          "count": coll.count, "by_op": coll.by_op}
    if skip_model_flops:
        mf = {"model_flops": 0.0, "formula": "skipped"}
    else:
        mf = model_flops_for(arch, cell, prog)
    rec["model_flops"] = mf
    terms = roofline.roofline_terms(
        {"flops": rec["cost"]["xla_flops_per_device"],
         "bytes accessed": rec["cost"]["xla_bytes_per_device"]},
        coll, chips, mf["model_flops"], weighted=weighted)
    rec["terms"] = {
        "compute_s": terms.compute_s, "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "collective_wire_s": terms.collective_wire_s,
        "dominant": terms.dominant, "step_seconds": terms.step_seconds,
        "useful_ratio": terms.useful_ratio, "mfu": terms.mfu,
    }
    if cell.kind == "gen":
        rec["sampler_steps"] = cell.steps
    rec["meta"] = {k: v for k, v in prog.meta.items()
                   if isinstance(v, (int, float, str))}
    rec["ok"] = True
    return rec


def _sharded_tree_bytes(tree, specs, mesh_shape: Dict[str, int]) -> int:
    """Per-device bytes of an SDS tree under its PartitionSpec tree."""
    import numpy as np
    from jax.sharding import PartitionSpec as P
    flat_t = jax.tree_util.tree_leaves(tree)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    total = 0
    for leaf, sp in zip(flat_t, flat_s):
        if not hasattr(leaf, "shape"):
            continue
        size = float(np.prod(leaf.shape, dtype=float)) * \
            jnp.dtype(leaf.dtype).itemsize
        denom = 1
        for ax in tuple(sp)[: len(leaf.shape)]:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= mesh_shape.get(a, 1)
        total += int(size / denom)
    return total


def _analytic_budget(arch, cell, prog, multi_pod: bool) -> int:
    """Per-chip HBM bytes at TRUE dtypes: sharded state (params + opt +
    inputs) + gradient accumulator + remat activation saves + the largest
    transient (one layer's fp32 attention logits).  The CPU backend's
    memory_analysis over-reports bf16 archs because float-normalization
    upcasts every bf16 buffer to f32; this budget is the TPU-dtype truth."""
    from repro.launch.mesh import mesh_shape_dict
    ms = mesh_shape_dict(multi_pod)
    state_bytes = 0
    for sds_tree, spec_tree in zip(prog.args_sds, prog.in_specs):
        try:
            state_bytes += _sharded_tree_bytes(sds_tree, spec_tree, ms)
        except Exception:  # noqa: BLE001
            pass
    transient = 0
    if cell.kind == "train" and arch.family_group == "lm":
        cfg = arch.make_config(cell)
        dsize = ms.get("data", 1) * (ms.get("pod", 1) if multi_pod else 1)
        n_micro = prog.meta.get("n_micro", 1)
        mb_dev = max(cell.global_batch // n_micro // dsize, 1)
        bpe = 2 if arch.param_dtype == "bfloat16" else 4
        params_sds = prog.args_sds[0]["params"]
        params_specs = prog.in_specs[0]["params"]
        grad_acc = _sharded_tree_bytes(params_sds, params_specs, ms)
        saves = cfg.n_groups * mb_dev * cell.seq_len * cfg.d_model * bpe
        heads_dev = -(-cfg.n_heads // ms.get("model", 1))
        logits = mb_dev * heads_dev * cell.seq_len * cell.seq_len * 4
        transient = grad_acc + saves + logits
    return int(state_bytes + transient)


def _out_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape name")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-model-flops", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = [args.arch] if args.arch else list(list_archs())
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch_name in archs:
        arch = get_arch(arch_name)
        shapes = [args.shape] if args.shape else list(arch.shapes)
        for shape_name in shapes:
            for mp in meshes:
                mesh_tag = "2x16x16" if mp else "16x16"
                path = _out_path(args.out, arch_name, shape_name, mesh_tag)
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {arch_name} {shape_name} {mesh_tag}")
                    continue
                label = f"{arch_name:28s} {shape_name:12s} {mesh_tag:8s}"
                try:
                    rec = run_cell(arch_name, shape_name, multi_pod=mp,
                                   skip_model_flops=args.skip_model_flops)
                    t = rec["terms"]
                    print(f"[ ok ] {label} compile={rec['compile_s']:6.1f}s "
                          f"mem/dev={rec['memory']['peak_estimate_bytes']/2**30:6.2f}GiB "
                          f"C={t['compute_s']*1e3:8.2f}ms M={t['memory_s']*1e3:8.2f}ms "
                          f"X={t['collective_s']*1e3:8.2f}ms dom={t['dominant']}")
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch_name, "shape": shape_name,
                           "mesh": mesh_tag, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append(label)
                    print(f"[FAIL] {label} {type(e).__name__}: {str(e)[:160]}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"\ndone; {len(failures)} failures")
    for f_ in failures:
        print("  FAIL:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
