"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all in seconds:

    compute    = HLO_FLOPs_global   / (chips × peak_FLOP/s)
    memory     = HLO_bytes_global   / (chips × HBM_bw)
    collective = collective_bytes_per_device / link_bw

``cost_analysis()`` of the SPMD-partitioned module reports *per-partition*
flops/bytes; we scale by chip count for the global numerators so the
division by chips recovers the per-chip time (identical number, the
formula shape follows the brief).

collective_bytes is parsed from ``compiled.as_text()``: every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
instruction's operand bytes, with two crucial corrections:

  * **loop weighting** — collectives inside ``while`` bodies (microbatch
    accumulation, layer scans) run once per iteration; the parser weights
    each computation by its loop trip count (nested loops multiply).
  * **ring wire bytes** — besides the operand-sum the brief prescribes, we
    also report the ring-algorithm wire bytes (2(g−1)/g for all-reduce,
    (g−1)/g for gather/scatter halves), which is what actually crosses ICI.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link (aggregate model, per the brief)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# operand bytes as a multiple of result bytes, per op
_OPERAND_MULT = {"all-reduce": lambda g: 1.0,
                 "all-gather": lambda g: 1.0 / g,
                 "reduce-scatter": lambda g: float(g),
                 "all-to-all": lambda g: 1.0,
                 "collective-permute": lambda g: 1.0}

# ring wire bytes per device as a multiple of result bytes
_WIRE_MULT = {"all-reduce": lambda g: 2.0 * (g - 1) / g,
              "all-gather": lambda g: (g - 1) / g,
              "reduce-scatter": lambda g: float(g - 1),
              "all-to-all": lambda g: (g - 1) / g,
              "collective-permute": lambda g: 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_WHILE_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one result type string, e.g. 'f32[16,128]{1,0}' or a tuple
    '(f32[4], bf16[2,2])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """Group the HLO text by computation.  Header lines look like
    ``%name (params...) -> type {`` or ``ENTRY %name (...) -> type {``;
    parameter lists may contain nested tuple parens, so we key off the
    first token rather than trying to match the whole signature."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and \
                (s.startswith("%") or s.startswith("ENTRY")):
            tok = s.split()[0]
            if tok == "ENTRY" and len(s.split()) > 1:
                tok = s.split()[1]
            cur = tok.lstrip("%")
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


@dataclass
class CollectiveStats:
    operand_bytes: float = 0.0        # the brief's prescribed sum
    wire_bytes: float = 0.0           # ring-model bytes over ICI
    by_op: Dict[str, float] = field(default_factory=dict)
    count: int = 0


def _comp_collectives(lines: List[str]) -> CollectiveStats:
    st = CollectiveStats()
    for s in lines:
        if "-done" in s:
            continue
        for op in _COLLECTIVES:
            token = f" {op}(" if f" {op}(" in s else f" {op}-start(" \
                if f" {op}-start(" in s else None
            if token is None:
                continue
            result_type = s.split("=", 1)[1].split(token)[0] if "=" in s else ""
            rbytes = _shape_bytes(result_type)
            g = _group_size(s)
            st.operand_bytes += rbytes * _OPERAND_MULT[op](g)
            st.wire_bytes += rbytes * _WIRE_MULT[op](g)
            st.by_op[op] = st.by_op.get(op, 0.0) + rbytes * _OPERAND_MULT[op](g)
            st.count += 1
            break
    return st


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(c) for s in cond_lines for c in _CONST_RE.findall(s)]
    consts = [c for c in consts if c > 1]
    return max(consts) if consts else 1


def _loop_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution-count multiplier per computation: while bodies/conditions
    multiply by the loop trip count (parsed from the condition's compare
    constant); fusion/reduce targets inherit their caller's multiplier."""
    mult: Dict[str, float] = {name: 1.0 for name in comps}
    call_re = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
    for _ in range(6):  # fixed-point over realistic nesting depths
        changed = False
        for name, lines in comps.items():
            for s in lines:
                if " while(" in s:
                    mc = _WHILE_COND_RE.search(s)
                    mb = _WHILE_BODY_RE.search(s)
                    if not (mc and mb):
                        continue
                    trips = _trip_count(comps.get(mc.group(1), []))
                    for target in (mb.group(1), mc.group(1)):
                        want = mult.get(name, 1.0) * trips
                        if target in mult and mult[target] < want:
                            mult[target] = want
                            changed = True
                else:
                    for target in call_re.findall(s):
                        want = mult.get(name, 1.0)
                        if target in mult and mult[target] < want:
                            mult[target] = want
                            changed = True
        if not changed:
            break
    return mult


def _fused_targets(comps: Dict[str, List[str]]) -> set:
    """Computations reached via calls=/to_apply= — their internal buffers
    live in registers/VMEM, so they contribute flops but not HBM bytes."""
    call_re = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
    out = set()
    for lines in comps.values():
        for s in lines:
            out.update(call_re.findall(s))
    return out


def collective_stats(hlo: str) -> CollectiveStats:
    """Loop-weighted collective bytes for one partitioned HLO module."""
    comps = _split_computations(hlo)
    mult = _loop_multipliers(comps)
    total = CollectiveStats()
    for name, lines in comps.items():
        st = _comp_collectives(lines)
        w = mult.get(name, 1.0)
        total.operand_bytes += st.operand_bytes * w
        total.wire_bytes += st.wire_bytes * w
        total.count += int(st.count * w)
        for op, b in st.by_op.items():
            total.by_op[op] = total.by_op.get(op, 0.0) + b * w
    return total


# ---------------------------------------------------------------------------
# loop-weighted flops / bytes (XLA's cost_analysis counts while bodies once,
# which under-reports scanned layers and microbatch loops by 10-100×)
# ---------------------------------------------------------------------------

_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIMLABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->")

# no HBM traffic: pure aliasing / metadata ops
_FREE_OPS = {"parameter", "get-tuple-element", "bitcast", "tuple",
             "constant", "after-all", "partition-id", "replica-id",
             "while", "conditional", "call", "iota", "domain",
             "opt-barrier"}


def _parse_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    dot_flops: float = 0.0
    conv_flops: float = 0.0


def hlo_cost(hlo: str) -> HloCost:
    """Instruction-level, loop-weighted flop/byte model of a partitioned
    module.  FLOPs: 2·M·N·K for dots, 2·out·kernel for convolutions.
    Bytes: every non-free top-level instruction reads its operands and
    writes its result once (post-fusion HLO granularity ≈ HBM traffic);
    instructions inside fusion bodies stay in registers → bytes 0."""
    comps = _split_computations(hlo)
    mult = _loop_multipliers(comps)
    fused = _fused_targets(comps)

    # global symbol table: instruction name -> result type string
    symtab: Dict[str, str] = {}
    for lines in comps.values():
        for s in lines:
            m = _INSTR_RE.match(s)
            if m:
                symtab[m.group(1)] = m.group(2)

    out = HloCost()
    for name, lines in comps.items():
        w = mult.get(name, 1.0)
        count_bytes = name not in fused
        for s in lines:
            m = _INSTR_RE.match(s)
            if not m:
                continue
            _res_name, res_type, op = m.groups()
            res_bytes = _shape_bytes(res_type)
            res_dims = _parse_dims(res_type)
            # ---- flops
            if op == "dot":
                cm = _CONTRACT_RE.search(s)
                ops = _OPERAND_RE.findall(s.split("(", 1)[1])
                k = 1
                if cm and ops:
                    lhs_dims = _parse_dims(symtab.get(ops[0], ""))
                    for ci in (cm.group(1).split(",") if cm.group(1) else []):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                f = 2.0 * math.prod(res_dims or [0]) * k
                out.flops += f * w
                out.dot_flops += f * w
            elif op == "convolution":
                ops = _OPERAND_RE.findall(s.split("(", 1)[1])
                rhs_dims = _parse_dims(symtab.get(ops[1], "")) if len(ops) > 1 else []
                o_size = 1
                dm = _DIMLABELS_RE.search(s)
                if dm and rhs_dims:
                    rhs_labels = dm.group(2)
                    if "o" in rhs_labels:
                        o_size = rhs_dims[rhs_labels.index("o")]
                kernel = (math.prod(rhs_dims) / max(o_size, 1)) if rhs_dims else 0
                f = 2.0 * math.prod(res_dims or [0]) * kernel
                out.flops += f * w
                out.conv_flops += f * w
            # ---- bytes (TPU semantics, not CPU artifacts)
            if count_bytes and op not in _FREE_OPS:
                if op == "copy":
                    # loop-carried buffer copies are a CPU-backend artifact;
                    # XLA:TPU aliases while-loop state in place
                    continue
                if op == "dynamic-update-slice" or (
                        op == "fusion" and "dynamic-update-slice" in _res_name):
                    # in-place on TPU: per execution the traffic is the
                    # updated window, ≈ buffer/trips inside a loop — so one
                    # UNWEIGHTED 2×buffer covers the whole loop
                    ops_ = _OPERAND_RE.findall(s.split("(", 1)[1])
                    if op == "dynamic-update-slice" and len(ops_) > 1:
                        out.bytes += 2 * _shape_bytes(
                            symtab.get(ops_[1], "")) * w
                    else:
                        out.bytes += 2 * res_bytes
                    continue
                if op == "dynamic-slice" or (
                        op == "fusion" and "dynamic-slice" in _res_name):
                    # slice result IS the window: read + write it
                    out.bytes += 2 * res_bytes * w
                    continue
                b = res_bytes
                for oname in _OPERAND_RE.findall(s.split("(", 1)[1])[:8]:
                    b += _shape_bytes(symtab.get(oname, ""))
                out.bytes += b * w
    return out


# ---------------------------------------------------------------------------
# useful ("model") FLOPs
# ---------------------------------------------------------------------------


def _param_counts(params_sds, pattern_active: Optional[Tuple[float, str]] = None):
    """(total, active) param counts; ``pattern_active`` = (keep_fraction,
    regex) applied to expert weights for MoE."""
    total = 0
    expert = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(params_sds)
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx",
                        getattr(p, "name", p)))) for p in path)
        n = math.prod(leaf.shape)
        total += n
        if pattern_active and re.search(pattern_active[1], name):
            expert += n
    active = total
    if pattern_active:
        active = total - expert * (1.0 - pattern_active[0])
    return total, active


def lm_model_flops(arch, cell, params_sds) -> Dict[str, float]:
    cfg_active = None
    if "moe" in arch.family:
        # keep fraction of expert weights that fire per token
        from repro.configs.registry import get_arch  # noqa: F401 (doc aid)
        moe = arch.make_config(cell).moe
        cfg_active = (moe.top_k / moe.n_experts, r"moe/w_(gate|up|down)")
    total, active = _param_counts(params_sds, cfg_active)
    tokens = (cell.global_batch * cell.seq_len if cell.kind != "decode"
              else cell.global_batch)
    mult = 6.0 if cell.kind == "train" else 2.0
    return {"params_total": float(total), "params_active": float(active),
            "model_flops": mult * active * tokens,
            "formula": f"{mult:.0f}*N_active*D (N={active:.3g}, D={tokens})"}


_FWD_CACHE: Dict[Tuple, float] = {}


def measured_fwd_flops(apply_fn, args_sds, cache_key: Tuple) -> float:
    """Unsharded single-device forward FLOPs at batch=1 (linear in batch for
    conv/diffusion nets) — the 'useful compute' reference for non-LM archs.
    Uses the loop-weighted instruction model (layer scans!)."""
    if cache_key not in _FWD_CACHE:
        lowered = jax.jit(apply_fn).lower(*args_sds)
        _FWD_CACHE[cache_key] = hlo_cost(lowered.compile().as_text()).flops
    return _FWD_CACHE[cache_key]


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    collective_wire_s: float
    hlo_flops_global: float
    hlo_bytes_global: float
    collective_bytes: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        """Roofline step-time estimate = max of the three terms (perfectly
        overlapped model; the sum would be the no-overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops_global, 1.0)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        denom = self.step_seconds * self.chips * PEAK_FLOPS
        return self.model_flops / max(denom, 1e-30)


def roofline_terms(cost: Dict[str, float], coll: CollectiveStats,
                   chips: int, model_flops: float,
                   weighted: Optional[HloCost] = None) -> RooflineTerms:
    """Terms from the loop-weighted instruction model when available
    (XLA's cost_analysis counts while bodies once — wrong for scanned
    layers/microbatches); falls back to cost_analysis numbers."""
    if weighted is not None and weighted.flops > 0:
        flops_pp = weighted.flops
        bytes_pp = weighted.bytes
    else:
        flops_pp = float(cost.get("flops", 0.0))
        bytes_pp = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops_pp / PEAK_FLOPS,
        memory_s=bytes_pp / HBM_BW,
        collective_s=coll.operand_bytes / ICI_BW,
        collective_wire_s=coll.wire_bytes / ICI_BW,
        hlo_flops_global=flops_pp * chips,
        hlo_bytes_global=bytes_pp * chips,
        collective_bytes=coll.operand_bytes,
        model_flops=model_flops,
        chips=chips,
    )
