"""CacheGenius serving driver — the paper's full request path on CPU.

Builds the edge fleet (N node VDBs via the K-means storage classifier over
a synthetic reference corpus), trains-or-loads the tiny diffusion model,
AOT-precompiles the serving buckets, then replays a Zipf request trace
through the hybrid pipeline and prints the paper's headline numbers
(route mix, hit rate, Eq. 8 latency, $ cost vs. always-full-generation).

    PYTHONPATH=src python -m repro.launch.serve --requests 300 --nodes 4
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.latency_model import CostModel, LatencyModel
from repro.core.lcu import POLICIES
from repro.core.policy import GenerationPolicy, Route
from repro.core.system import CacheGenius
from repro.core.trace import RequestTrace
from repro.core.vdb import BlobStore
from repro.core.embeddings import ProxyClipEmbedder
from repro.core.storage_classifier import StorageClassifier
from repro.data.synthetic import make_corpus, render_caption
from repro.runtime.serving import ServingEngine


def build_system(*, n_nodes: int = 4, corpus_n: int = 600,
                 capacity_per_node: int = 400, policy=None,
                 eviction="LCU", use_scheduler=True,
                 use_prompt_optimizer=True, backend=None, seed=0,
                 node_speeds=None):
    """Assemble the full CacheGenius stack over the synthetic corpus."""
    images, captions, _ = make_corpus(corpus_n, res=32, seed=seed)
    embedder = ProxyClipEmbedder(render_caption)
    img_vecs = embedder.embed_image(images)
    txt_vecs = embedder.embed_text(captions)
    embedder.set_corpus_anchor(img_vecs)

    blob = BlobStore()
    payloads = np.array([blob.put(im) for im in images], np.int64)
    classifier = StorageClassifier(n_nodes)
    dbs = classifier.build_node_dbs(img_vecs, txt_vecs, payloads,
                                    capacity_per_node=capacity_per_node)
    if backend is None:
        backend = _null_backend(images)
    base_speeds = [1.0, 1.0, 0.82, 0.45]   # 4090D/4090D/3090/2070S
    speeds = node_speeds or [base_speeds[i % len(base_speeds)]
                             for i in range(n_nodes)]
    system = CacheGenius(
        embedder=embedder, dbs=dbs, blob_store=blob, backend=backend,
        classifier=classifier, policy=policy or GenerationPolicy(),
        latency_model=LatencyModel(), cost_model=CostModel(),
        eviction=POLICIES[eviction], node_speeds=speeds,
        use_scheduler=use_scheduler,
        use_prompt_optimizer=use_prompt_optimizer)
    return system, embedder, images, captions


def _null_backend(corpus_images):
    """Render-based stand-in backend for latency/routing experiments that
    don't need a trained model (benchmarks train the real tiny DiT)."""
    from repro.core.system import GenerationBackend
    from repro.data.synthetic import render_caption as rc

    def txt2img(prompt, steps, seed):
        return rc(prompt, res=corpus_images.shape[1])

    def img2img(prompt, ref, steps, seed):
        target = rc(prompt, res=corpus_images.shape[1])
        return 0.75 * target + 0.25 * ref[: target.shape[0], : target.shape[1]]

    # loop-based batch entry points: bit-identical per element, so the
    # grouped serve_batch path stays exactly comparable to sequential serve
    def txt2img_batch(prompts, steps, seeds):
        return np.stack([txt2img(p, steps, s) for p, s in zip(prompts, seeds)])

    def img2img_batch(prompts, refs, steps, seeds):
        return np.stack([img2img(p, r, steps, s)
                         for p, r, s in zip(prompts, refs, seeds)])

    return GenerationBackend(txt2img=txt2img, img2img=img2img,
                             txt2img_batch=txt2img_batch,
                             img2img_batch=img2img_batch)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--eviction", default="LCU",
                    choices=sorted(POLICIES))
    ap.add_argument("--no-scheduler", action="store_true")
    ap.add_argument("--no-prompt-optimizer", action="store_true")
    ap.add_argument("--fail-node", type=int, default=None,
                    help="kill node N after half the requests")
    args = ap.parse_args()

    system, _, _, _ = build_system(
        n_nodes=args.nodes, eviction=args.eviction,
        use_scheduler=not args.no_scheduler,
        use_prompt_optimizer=not args.no_prompt_optimizer)
    engine = ServingEngine(system)

    trace = RequestTrace(seed=1)
    reqs = list(trace.generate(args.requests))
    half = len(reqs) // 2
    for i, r in enumerate(reqs):
        if args.fail_node is not None and i == half:
            print(f"--- failing node {args.fail_node} ---")
            engine.fail_node(args.fail_node)
        engine.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    done = engine.drain()

    st = system.stats
    lat = np.array(st.latencies)
    full_latency = system.latency_model.latency(
        Route.TXT2IMG, system.policy.steps_full)
    base_cost = CostModel()
    for i in range(st.requests):
        base_cost.charge(0, system.policy.steps_full *
                         system.latency_model.t_step)
    print(f"requests           : {st.requests}")
    print(f"route mix          : {st.route_counts}")
    print(f"hit rate           : {st.hit_rate:.3f}")
    print(f"mean latency (Eq.8): {lat.mean():.3f}s   "
          f"p50 {np.percentile(lat, 50):.3f}  p95 {np.percentile(lat, 95):.3f}")
    print(f"vs always-full     : {full_latency:.3f}s  "
          f"(reduction {100 * (1 - lat.mean() / full_latency):.1f}%)")
    cost = system.cost_model.total_cost()
    base = base_cost.total_cost()
    print(f"cost               : ${cost:.4f} vs ${base:.4f} "
          f"(reduction {100 * (1 - cost / max(base, 1e-12)):.1f}%)")
    print(f"queue mean delay   : "
          f"{np.mean([c.queue_delay for c in done]):.1f} ticks")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
