"""CacheGenius serving driver — the paper's full request path on CPU.

Builds the edge fleet (N node VDBs via the K-means storage classifier over
a synthetic reference corpus), trains-or-loads the tiny diffusion model,
AOT-precompiles the serving buckets, then replays a Zipf request trace
through the hybrid pipeline and prints the paper's headline numbers
(route mix, hit rate, Eq. 8 latency, $ cost vs. always-full-generation)
plus true queue-delay and per-stage wall-time percentiles.

    PYTHONPATH=src python -m repro.launch.serve --requests 300 --nodes 4
    PYTHONPATH=src python -m repro.launch.serve --continuous \\
        --arrival-rate 50 --requests 300      # Poisson offered load
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.latency_model import CostModel, LatencyModel
from repro.core.lcu import POLICIES
from repro.core.policy import GenerationPolicy, Route
from repro.core.system import CacheGenius, GenerationBackend
from repro.core.trace import RequestTrace, merge_arrivals, poisson_arrivals
from repro.core.vdb import BlobStore
from repro.core.embeddings import ProxyClipEmbedder
from repro.faults import FaultInjector, FaultSchedule, attach_journals
from repro.faults.schedule import PRESETS as FAULT_PRESETS
from repro.core.storage_classifier import StorageClassifier
from repro.data.synthetic import make_corpus, render_caption
from repro.runtime.serving import ServingEngine


def build_system(*, n_nodes: int = 4, corpus_n: int = 600,
                 capacity_per_node: int = 400, policy=None,
                 eviction="LCU", use_scheduler=True,
                 use_prompt_optimizer=True, backend=None, seed=0,
                 node_speeds=None, routing: str = "score",
                 latent_depths=None, mesh_nodes: int = 1):
    """Assemble the full CacheGenius stack over the synthetic corpus.

    ``routing`` selects the Schedule stage's mode: ``"score"`` (default)
    routes every request on its true best composite match per node from
    the cluster-wide fused scan; ``"centroid"`` keeps the paper's Eq. 6
    node-representation baseline.  ``latent_depths`` enables the
    latent-depth cache (``True`` = the policy's default {K/4, K/2, 3K/4}
    schedule, or an explicit depth tuple).  ``mesh_nodes > 1`` shards
    the cluster index's cache slabs over that many devices (a 1-D
    "nodes" mesh; results stay bitwise identical to ``mesh_nodes=1``) —
    on CPU force the devices with
    :func:`repro.launch.mesh.ensure_host_devices` BEFORE first jax
    use."""
    images, captions, _ = make_corpus(corpus_n, res=32, seed=seed)
    embedder = ProxyClipEmbedder(render_caption)
    img_vecs = embedder.embed_image(images)
    txt_vecs = embedder.embed_text(captions)
    embedder.set_corpus_anchor(img_vecs)

    blob = BlobStore()
    payloads = np.array([blob.put(im) for im in images], np.int64)
    classifier = StorageClassifier(n_nodes)
    dbs = classifier.build_node_dbs(img_vecs, txt_vecs, payloads,
                                    capacity_per_node=capacity_per_node)
    if backend is None:
        backend = _null_backend(images)
    base_speeds = [1.0, 1.0, 0.82, 0.45]   # 4090D/4090D/3090/2070S
    speeds = node_speeds or [base_speeds[i % len(base_speeds)]
                             for i in range(n_nodes)]
    system = CacheGenius(
        embedder=embedder, dbs=dbs, blob_store=blob, backend=backend,
        classifier=classifier, policy=policy or GenerationPolicy(),
        latency_model=LatencyModel(), cost_model=CostModel(),
        eviction=POLICIES[eviction], node_speeds=speeds,
        use_scheduler=use_scheduler,
        use_prompt_optimizer=use_prompt_optimizer, routing=routing,
        latent_depths=latent_depths, mesh_nodes=mesh_nodes)
    return system, embedder, images, captions


class NullBackend(GenerationBackend):
    """Render-based stand-in backend for latency/routing experiments that
    don't need a trained model (benchmarks train the real tiny DiT).
    Deterministic per element (steps/seed are ignored), so batched and
    sequential drains stay exactly comparable.

    Latent-depth support mirrors the real backend's contract with the
    cheapest possible model: the "latent" archived at EVERY depth is the
    finished image itself, and ``resume_batch`` applies the same blend as
    ``img2img_batch`` — so resuming from depth 0 bitwise-equals full
    img2img (the parity invariant the real backend must also satisfy),
    and any-depth resumes stay deterministic."""

    supports_latent_resume = True

    def __init__(self, res: int):
        super().__init__()
        self.res = int(res)

    def txt2img_batch(self, prompts, steps, seeds):
        from repro.data.synthetic import render_caption as rc
        return np.stack([rc(p, res=self.res) for p in prompts])

    def img2img_batch(self, prompts, references, steps, seeds):
        from repro.data.synthetic import render_caption as rc
        out = []
        for p, ref in zip(prompts, references):
            target = rc(p, res=self.res)
            out.append(0.75 * target
                       + 0.25 * ref[: target.shape[0], : target.shape[1]])
        return np.stack(out)

    def archive_latents_batch(self, images, seeds, depths, steps_total):
        return np.stack([np.asarray(images)] * len(depths))

    def resume_batch(self, prompts, latents, steps_total, k, seeds):
        return self.img2img_batch(prompts, latents, steps_total - k, seeds)


def _null_backend(corpus_images):
    return NullBackend(res=corpus_images.shape[1])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--eviction", default="LCU",
                    choices=sorted(POLICIES))
    ap.add_argument("--no-scheduler", action="store_true")
    ap.add_argument("--routing", default="score",
                    choices=("score", "centroid"),
                    help="request-scheduler mode: 'score' routes on each "
                    "node's true best composite match from the fused "
                    "cluster scan; 'centroid' is the Eq. 6 "
                    "node-representation baseline")
    ap.add_argument("--no-prompt-optimizer", action="store_true")
    ap.add_argument("--mesh-nodes", type=int, default=1,
                    help="shard the cluster index's cache slabs over "
                    "this many devices (1-D 'nodes' mesh; scan results "
                    "stay bitwise identical to the single-device path); "
                    "on CPU host devices are forced automatically")
    ap.add_argument("--latent-cache", action="store_true",
                    help="archive noised img2img intermediates alongside "
                    "finished images and resume denoising from them "
                    "(policy default depths {K/4, K/2, 3K/4})")
    ap.add_argument("--latent-depths", default=None,
                    help="comma-separated resume depths, e.g. '5,10,15' "
                    "(implies --latent-cache)")
    ap.add_argument("--fail-node", type=int, default=None,
                    help="kill node N after half the requests")
    ap.add_argument("--max-batch", "--batch", dest="max_batch", type=int,
                    default=8, help="engine micro-batch size (1 reproduces "
                    "the request-at-a-time numbers)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a Poisson arrival "
                    "process (ServingEngine.run) instead of the "
                    "submit-everything-then-drain loop")
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="offered load for --continuous, requests/second "
                    "on the virtual serving clock")
    ap.add_argument("--step-level", action="store_true",
                    help="with --continuous: step-level continuous "
                    "batching — a persistent slot engine admits arrivals "
                    "at ANY denoising-step boundary instead of waiting "
                    "for the in-flight step group; prints slot-occupancy "
                    "p50/p95 alongside the queue-delay percentiles")
    ap.add_argument("--slot-capacity", type=int, default=None,
                    help="slot-buffer capacity for --step-level "
                    "(default: --max-batch)")
    ap.add_argument("--fault-schedule", default=None,
                    choices=sorted(FAULT_PRESETS),
                    help="with --continuous: run under a scripted chaos "
                    "schedule (repro.faults preset, scaled to the fleet "
                    "and trace), print the injector's audit report, and "
                    "exit nonzero if ANY accepted job is lost")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault schedule's deterministic "
                    "random draws (which blobs corrupt, etc.)")
    ap.add_argument("--journal-dir", default=None,
                    help="attach a per-node cache durability journal "
                    "(WAL + snapshots) under this directory; crashed "
                    "nodes in a --fault-schedule run then rejoin with "
                    "their journal-replayed cache instead of cold")
    ap.add_argument("--tenants", type=int, default=0,
                    help="with --continuous: split the trace round-robin "
                    "across N tagged tenants (tiers cycle premium/"
                    "standard/batch), merge their Poisson processes "
                    "deterministically, and print per-tenant/tier "
                    "queue-delay + wall percentiles")
    args = ap.parse_args()
    if args.max_batch < 1:
        ap.error("--max-batch must be >= 1")
    if args.arrival_rate <= 0:
        ap.error("--arrival-rate must be > 0")
    if args.tenants < 0:
        ap.error("--tenants must be >= 0")
    if args.tenants > 1 and not args.continuous:
        ap.error("--tenants requires --continuous")
    if args.step_level and not args.continuous:
        ap.error("--step-level requires --continuous")
    if args.fault_schedule is not None and not args.continuous:
        ap.error("--fault-schedule requires --continuous")
    if args.fault_schedule is not None and args.fail_node is not None:
        ap.error("--fault-schedule already scripts failures; "
                 "drop --fail-node")
    if args.slot_capacity is not None and not args.step_level:
        ap.error("--slot-capacity requires --step-level")
    if args.slot_capacity is not None and args.slot_capacity < 1:
        ap.error("--slot-capacity must be >= 1")
    if args.mesh_nodes < 1:
        ap.error("--mesh-nodes must be >= 1")
    if args.mesh_nodes > 1:
        # must happen before any jax device use below (backend init is
        # lazy — an already-initialised smaller backend falls back)
        from repro.launch.mesh import ensure_host_devices
        if not ensure_host_devices(args.mesh_nodes):
            print(f"# mesh-nodes={args.mesh_nodes} unavailable "
                  "(backend already initialised); running unsharded")
            args.mesh_nodes = 1

    if args.latent_depths is not None:
        latent_depths = tuple(int(d) for d in args.latent_depths.split(","))
    elif args.latent_cache:
        latent_depths = True
    else:
        latent_depths = None
    system, _, _, _ = build_system(
        n_nodes=args.nodes, eviction=args.eviction,
        use_scheduler=not args.no_scheduler,
        use_prompt_optimizer=not args.no_prompt_optimizer,
        routing=args.routing, latent_depths=latent_depths,
        mesh_nodes=args.mesh_nodes)
    engine = ServingEngine(system, max_batch=args.max_batch)

    journals = (attach_journals(system, args.journal_dir)
                if args.journal_dir is not None else None)
    injector = None
    if args.fault_schedule is not None:
        # horizon = injection boundaries the run will see: every
        # denoising step in step-level mode, every admission group
        # otherwise (events land at fixed fractions of it)
        horizon = (args.requests if args.step_level
                   else max(10, args.requests // args.max_batch))
        schedule = FaultSchedule.preset(
            args.fault_schedule, nodes=args.nodes, horizon=horizon,
            seed=args.fault_seed)
        injector = FaultInjector(system, schedule, journals=journals)

    trace = RequestTrace(seed=1)
    reqs = list(trace.generate(args.requests))
    half = len(reqs) // 2
    if args.continuous:
        if args.tenants > 1:
            # one client among many: each tenant is its own tagged
            # Poisson process, interleaved deterministically
            tier_cycle = ("premium", "standard", "batch")
            procs, offset = [], 0
            for ti in range(args.tenants):
                chunk = reqs[ti::args.tenants]
                procs.append(poisson_arrivals(
                    chunk, args.arrival_rate / args.tenants, seed=1 + ti,
                    seed_base=offset, tenant=f"tenant{ti}",
                    tier=tier_cycle[ti % len(tier_cycle)]))
                offset += len(chunk)
            arrivals = merge_arrivals(*procs)
        else:
            arrivals = poisson_arrivals(reqs, args.arrival_rate, seed=1)
        step_kw = (dict(step_level=True, slot_capacity=args.slot_capacity)
                   if args.step_level else {})
        if injector is not None:
            step_kw["on_step"] = injector.on_step
        occupancy = []
        if args.fail_node is not None:
            done = engine.run(arrivals[:half], **step_kw)
            occupancy += engine.slot_occupancy
            print(f"--- failing node {args.fail_node} ---")
            engine.fail_node(args.fail_node)
            # resume on the same timeline: backlog from the first half
            # (service overrunning the arrival spread) carries over
            done += engine.run(
                arrivals[half:],
                start=max((c.finished_at for c in done), default=0.0),
                **step_kw)
            occupancy += engine.slot_occupancy
        else:
            done = engine.run(arrivals, **step_kw)
            occupancy = list(engine.slot_occupancy)
    else:
        for i, r in enumerate(reqs):
            if args.fail_node is not None and i == half:
                print(f"--- failing node {args.fail_node} ---")
                engine.fail_node(args.fail_node)
            engine.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
        done = engine.drain()

    st = system.stats
    lat = np.array(st.latencies)
    full_latency = system.latency_model.latency(
        Route.TXT2IMG, system.policy.steps_full)
    base_cost = CostModel()
    for i in range(st.requests):
        base_cost.charge(0, system.policy.steps_full *
                         system.latency_model.t_step)
    print(f"requests           : {st.requests}")
    print(f"routing            : {args.routing}"
          + ("" if not args.no_scheduler else " (scheduler disabled)"))
    print(f"route mix          : {st.route_counts}")
    print(f"hit rate           : {st.hit_rate:.3f}")
    print(f"mean steps/request : {st.mean_steps:.2f}"
          + (f"   (latent resumes: {st.latent_resumes}, depths "
             f"{system.latent_depths})" if system.latent_depths else ""))
    print(f"mean latency (Eq.8): {lat.mean():.3f}s   "
          f"p50 {np.percentile(lat, 50):.3f}  p95 {np.percentile(lat, 95):.3f}")
    wall = np.array(st.wall_latencies)
    print(f"wall latency       : mean {wall.mean() * 1e3:.2f}ms   "
          f"p50 {np.percentile(wall, 50) * 1e3:.2f}ms  "
          f"p95 {np.percentile(wall, 95) * 1e3:.2f}ms  "
          f"(batch-amortised, max_batch={args.max_batch}, "
          f"{len(st.batch_wall_latencies)} micro-batches, "
          f"total {sum(st.batch_wall_latencies):.2f}s)")
    print(f"vs always-full     : {full_latency:.3f}s  "
          f"(reduction {100 * (1 - lat.mean() / full_latency):.1f}%)")
    cost = system.cost_model.total_cost()
    base = base_cost.total_cost()
    print(f"cost               : ${cost:.4f} vs ${base:.4f} "
          f"(reduction {100 * (1 - cost / max(base, 1e-12)):.1f}%)")
    qd = np.array([c.queue_delay for c in done])
    mode = (f"continuous, {args.arrival_rate:g} req/s offered"
            if args.continuous else "drain path, actual wait")
    if args.step_level:
        mode = "step-level " + mode
    print(f"queue delay        : mean {qd.mean() * 1e3:.2f}ms   "
          f"p50 {np.percentile(qd, 50) * 1e3:.2f}ms  "
          f"p95 {np.percentile(qd, 95) * 1e3:.2f}ms  ({mode})")
    if args.step_level and occupancy:
        occ = np.array(occupancy)
        cap = args.slot_capacity or args.max_batch
        print(f"slot occupancy     : p50 {np.percentile(occ, 50):.0f}  "
              f"p95 {np.percentile(occ, 95):.0f}  of {cap} slots  "
              f"({len(occ)} step launches)")
    print("stage walls        : " + "  ".join(
        f"{name} {np.percentile(v, 50) * 1e3:.1f}/"
        f"{np.percentile(v, 95) * 1e3:.1f}ms"
        for name, v in _stage_wall_arrays(done).items()))
    tagged = engine.tagged_stats()
    if tagged:
        print("per-tenant/tier    : (queue-delay, wall p50/p95 ms)")
        for (tenant, tier), s in tagged.items():
            print(f"  {tenant or '-'}/{tier or '-':<9} n={s['n']:<4.0f} "
                  f"qd {s['queue_delay_p50'] * 1e3:.2f}/"
                  f"{s['queue_delay_p95'] * 1e3:.2f}  "
                  f"wall {s['wall_p50'] * 1e3:.2f}/"
                  f"{s['wall_p95'] * 1e3:.2f}")
    if injector is not None:
        injector.finish()
        rep = injector.report()
        print(f"chaos schedule     : {args.fault_schedule} "
              f"(seed {args.fault_seed}, {rep['steps_seen']} injection "
              f"boundaries seen)")
        print(f"chaos actions      : {rep['actions']}")
        print(f"chaos absorbed     : "
              f"transient_retries={rep['transient_retries']}  "
              f"corrupt_hits={rep['corrupt_hits']}  "
              f"degraded_serves={rep['degraded_serves']}")
        lost = len(reqs) - len(done)
        if lost or any(c.result.image is None for c in done):
            print(f"CHAOS FAIL         : {lost} accepted jobs lost")
            return 1
        print("chaos invariant    : zero accepted-job loss")
    return 0


def _stage_wall_arrays(done):
    """Per-stage wall-time samples (p50/p95 inputs) across completions."""
    out = {}
    for c in done:
        for name, w in c.result.stage_walls.items():
            out.setdefault(name, []).append(w)
    return {k: np.asarray(v) for k, v in out.items()}


if __name__ == "__main__":
    raise SystemExit(main())
