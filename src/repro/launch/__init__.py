"""Launch layer: production mesh, multi-pod dry-run, roofline analysis,
and the CPU-scale train/serve drivers."""
