"""CPU-scale end-to-end training driver (the e2e deliverable).

Trains the paper's reproduction model (``sd15-small``: tiny DiT + tiny VAE
on the synthetic captioned corpus) — or any ``--arch`` at its reduced
config — through the fault-tolerant loop: checkpoints, resume, NaN
rollback, straggler accounting.

    PYTHONPATH=src python -m repro.launch.train --steps 300
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 50
    PYTHONPATH=src python -m repro.launch.train --resume   # restart path
"""
from __future__ import annotations

import argparse
import os
import shutil

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, get_shape
from repro.core.embeddings import ProxyClipEmbedder
from repro.data.pipeline import ShardedDataLoader
from repro.data.synthetic import make_corpus, render_caption
from repro.data.tokenizer import HashTokenizer
from repro.runtime.steps import build_cell_program
from repro.runtime.train_loop import LoopConfig, run_training


def make_diffusion_loader(prog, n_corpus: int = 512, seed: int = 0):
    """Synthetic corpus → (images, ctx) batches matching the program SDS."""
    batch_sds = prog.args_sds[1]
    b, res = batch_sds["images"].shape[0], batch_sds["images"].shape[1]
    images, captions, _ = make_corpus(n_corpus, res=res, seed=seed)
    embedder = ProxyClipEmbedder(render_caption)
    ctx = embedder.embed_text(captions).astype(np.float32)
    return ShardedDataLoader({"images": images, "ctx": ctx},
                             global_batch=b, seed=seed)


def make_lm_loader(prog, n_corpus: int = 512, seed: int = 0):
    batch_sds = prog.args_sds[1]
    b, s1 = batch_sds["tokens"].shape
    _, captions, _ = make_corpus(n_corpus, res=8, seed=seed)
    tok = HashTokenizer(vocab_size=512)
    tokens = tok.encode_batch(captions, max_len=s1)
    return ShardedDataLoader({"tokens": tokens}, global_batch=b, seed=seed)


def make_vision_loader(prog, n_corpus: int = 512, seed: int = 0):
    batch_sds = prog.args_sds[1]
    b, res = batch_sds["images"].shape[0], batch_sds["images"].shape[1]
    images, _, specs = make_corpus(n_corpus, res=res, seed=seed)
    from repro.data.synthetic import SHAPES
    labels = np.array([SHAPES.index(s.shape) for s in specs], np.int32)
    return ShardedDataLoader({"images": images, "labels": labels},
                             global_batch=b, seed=seed)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sd15-small")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at step N (tests the restart)")
    ap.add_argument("--fresh", action="store_true",
                    help="wipe the checkpoint dir first")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    shape = args.shape or {"lm": "train_4k", "diffusion": "train_256",
                           "vision": "cls_224"}[arch.family_group]
    cell = get_shape(arch.family_group, shape)
    prog = build_cell_program(arch, cell, reduced=True)

    if args.fresh and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    state = prog.init_fn(jax.random.key(0))
    if arch.family_group == "diffusion":
        loader = make_diffusion_loader(prog)
    elif arch.family_group == "lm":
        loader = make_lm_loader(prog)
    else:
        loader = make_vision_loader(prog)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    cfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                     fail_at=args.fail_at)

    def on_metrics(step, m):
        print(f"step {step:5d}  loss {m['loss']:.5f}  "
              f"gnorm {m.get('grad_norm', float('nan')):.3f}")

    state, report = run_training(prog.step_fn, state, loader, ckpt, cfg,
                                 on_metrics=on_metrics)
    print(f"\ndone: steps={report.steps_done} restarts={report.restarts} "
          f"rollbacks={report.rollbacks} stragglers={report.straggler_steps} "
          f"final_loss={report.final_loss:.5f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
