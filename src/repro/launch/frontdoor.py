"""Front-door load driver: N concurrent synthetic tenants, wall-clock.

Where ``repro.launch.serve --continuous`` replays ONE arrival process on
a virtual clock, this driver runs the production shape end to end: it
builds the CacheGenius fleet, puts the async multi-tenant
:class:`~repro.frontdoor.gateway.Gateway` in front of it, and launches
one asyncio CLIENT PER TENANT — each with its own arrival process from
``repro.core.trace`` (Poisson and bursty generators alternate across
tenants), its own SLA tier, and optionally a token-bucket quota.  The
trace generators become one client among many.

Virtual trace seconds are mapped to wall seconds by ``--time-scale``
(0.01 ⇒ a 40 req/s trace offers 4000 req/s of wall pressure), so a CI
smoke finishes in seconds while still exercising real concurrency, real
queueing and the worker-thread group loop.

    PYTHONPATH=src python -m repro.launch.frontdoor --tenants 3 \\
        --requests 60 --nodes 2 --time-scale 0.005
    PYTHONPATH=src python -m repro.launch.frontdoor --tenants 3 \\
        --quota 20,10 --leave-node 1          # drain node 1 mid-run
"""
from __future__ import annotations

import argparse
import asyncio
import time
from typing import Dict, List, Sequence

import numpy as np

from repro.core.trace import RequestTrace, bursty_arrivals, poisson_arrivals
from repro.frontdoor import (BackpressureError, Gateway, FileResultStore,
                             QuotaExceededError, ResultHandle)
from repro.launch.serve import build_system
from repro.runtime.serving import ServingEngine

TIER_CYCLE = ("premium", "standard", "batch")


def jain_fairness(values: Sequence[float]) -> float:
    """Jain's fairness index over per-tenant allocations: 1.0 = perfectly
    fair, 1/n = one tenant takes everything.  Empty/zero input -> 1.0."""
    x = np.asarray(list(values), np.float64)
    if x.size == 0 or float(np.sum(x * x)) == 0.0:
        return 1.0
    return float(np.sum(x) ** 2 / (x.size * np.sum(x * x)))


def tenant_arrivals(ti: int, reqs, rate: float, *, tier: str,
                    seed_base: int):
    """Tenant ``ti``'s arrival process — generators alternate so tenants
    are DISTINCT clients (even tenants Poisson, odd tenants bursty at
    the same mean rate)."""
    tenant = f"tenant{ti}"
    if ti % 2 == 0:
        return poisson_arrivals(reqs, rate, seed=101 + ti,
                                seed_base=seed_base, tenant=tenant,
                                tier=tier)
    burst = max(2, int(round(rate / 10)) or 2)
    return bursty_arrivals(reqs, burst_size=burst,
                           burst_gap=burst / max(rate, 1e-9),
                           seed_base=seed_base, tenant=tenant, tier=tier)


async def _client(gateway: Gateway, arrivals, time_scale: float,
                  t0: float, tally: Dict[str, int]) -> List[ResultHandle]:
    handles: List[ResultHandle] = []
    for a in arrivals:
        await asyncio.sleep(max(0.0, t0 + a.arrival_time * time_scale
                                - time.perf_counter()))
        try:
            handles.append(await gateway.submit_async(
                a.prompt, tenant=a.tenant, tier=a.tier, seed=a.seed,
                quality_tier=a.quality_tier or None))
        except QuotaExceededError:
            tally["quota"] = tally.get("quota", 0) + 1
        except BackpressureError:
            tally["backpressure"] = tally.get("backpressure", 0) + 1
    return handles


async def _drive(gateway: Gateway, processes, time_scale: float,
                 capacity_op, capacity_at: float):
    t0 = time.perf_counter()
    tallies = [dict() for _ in processes]
    tasks = [asyncio.create_task(_client(gateway, p, time_scale, t0, tl))
             for p, tl in zip(processes, tallies)]
    if capacity_op is not None:
        async def _cap():
            await asyncio.sleep(capacity_at * time_scale)
            capacity_op()
        tasks.append(asyncio.create_task(_cap()))
        handles_per_client = await asyncio.gather(*tasks)
        handles_per_client = handles_per_client[:-1]
    else:
        handles_per_client = await asyncio.gather(*tasks)
    # every accepted job must complete (graceful drain)
    for handles in handles_per_client:
        for h in handles:
            await h.wait_async()
    return handles_per_client, tallies


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=60,
                    help="requests per tenant")
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--arrival-rate", type=float, default=40.0,
                    help="per-tenant offered load, requests per VIRTUAL "
                    "second (scaled to wall time by --time-scale)")
    ap.add_argument("--time-scale", type=float, default=0.005,
                    help="wall seconds per virtual trace second")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-depth", type=int, default=512,
                    help="admission-control bound on the queue")
    ap.add_argument("--quota", default=None,
                    help="per-tenant token bucket 'rate,burst' in "
                    "VIRTUAL req/s (applied to every tenant)")
    ap.add_argument("--store", default=None,
                    help="directory for the filesystem result store "
                    "(default: in-memory)")
    ap.add_argument("--leave-node", type=int, default=None,
                    help="gracefully drain this node mid-run")
    ap.add_argument("--join-node", action="store_true",
                    help="join a fresh node mid-run")
    args = ap.parse_args()
    if args.tenants < 1:
        ap.error("--tenants must be >= 1")
    if args.time_scale <= 0:
        ap.error("--time-scale must be > 0")

    system, _, _, _ = build_system(n_nodes=args.nodes)
    engine = ServingEngine(system, max_batch=args.max_batch)

    quotas = None
    if args.quota:
        rate, burst = (float(v) for v in args.quota.split(","))
        # virtual req/s -> wall req/s under the time scale
        quotas = {f"tenant{i}": (rate / args.time_scale, burst)
                  for i in range(args.tenants)}
    store = FileResultStore(args.store) if args.store else None
    gateway = Gateway(engine, max_depth=args.max_depth, quotas=quotas,
                      store=store)

    processes = []
    for ti in range(args.tenants):
        trace = RequestTrace(seed=11 + ti)
        reqs = list(trace.generate(args.requests))
        processes.append(tenant_arrivals(
            ti, reqs, args.arrival_rate,
            tier=TIER_CYCLE[ti % len(TIER_CYCLE)],
            seed_base=ti * args.requests))

    capacity_op = None
    if args.leave_node is not None:
        capacity_op = lambda: gateway.leave_node(args.leave_node)
    elif args.join_node:
        capacity_op = lambda: gateway.join_node()
    half = max(p[-1].arrival_time for p in processes) / 2

    t_start = time.perf_counter()
    with gateway:
        handles_per_client, tallies = asyncio.run(
            _drive(gateway, processes, args.time_scale, capacity_op, half))
    wall = time.perf_counter() - t_start

    st = gateway.stats()
    n_done = sum(len(h) for h in handles_per_client)
    print(f"tenants            : {args.tenants}  "
          f"(tiers {', '.join(TIER_CYCLE[i % len(TIER_CYCLE)] for i in range(args.tenants))})")
    print(f"accepted/served    : {st['accepted']}/{st['jobs_served']} in "
          f"{st['groups_served']} groups over {wall:.2f}s wall "
          f"({n_done / max(wall, 1e-9):.1f} done/s)")
    print(f"rejections         : quota {st['rejected_quota']}  "
          f"backpressure {st['rejected_backpressure']}  "
          f"escalations {st['escalations']}")
    if args.leave_node is not None:
        print(f"capacity           : node {args.leave_node} left mid-run "
              f"(accepted-job loss: "
              f"{st['accepted'] - st['jobs_served']})")
    if args.join_node:
        print(f"capacity           : node joined mid-run -> "
              f"{len(system.dbs)} nodes")
    print("per-tenant/tier    : (queue-delay, wall p50/p95 ms)")
    for (tenant, tier), s in st["per_tenant_tier"].items():
        print(f"  {tenant}/{tier:<9} n={s['n']:<4.0f} "
              f"qd {s['queue_delay_p50'] * 1e3:.2f}/"
              f"{s['queue_delay_p95'] * 1e3:.2f}  "
              f"wall {s['wall_p50'] * 1e3:.2f}/{s['wall_p95'] * 1e3:.2f}")
    served = [len(h) for h in handles_per_client]
    print(f"fairness (Jain)    : {jain_fairness(served):.3f} over "
          f"completed-per-tenant {served}")
    print(f"result store       : {st['stored_results']} results "
          f"({'fs:' + args.store if args.store else 'memory'})")
    # engine memory holds no pixels after offload
    assert all(c.result.image is None for c in engine.completed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
