import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lower a cell under named variants and record
hypothesis → change → before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.perf --cell llama4 --variant v1_chunked_ce

Variants are defined per hillclimb cell below; every run writes
``experiments/perf/<cell>__<variant>.json`` with the same record schema as
the dry-run, so before/after diffs come straight from the artifacts.
"""
import argparse
import json
import time
from typing import Any, Dict

from repro.launch import dryrun as dr

# The three hillclimb cells (§Perf): worst roofline fraction / most
# collective-bound / most representative of the paper's technique.
HILLCLIMB = {
    "llama4": ("llama4-maverick-400b-a17b", "train_4k"),
    "flux": ("flux-dev", "gen_1024"),
    "unet": ("unet-sd15", "train_256"),
}

# variant name -> (options dict, hypothesis string)
VARIANTS: Dict[str, Dict[str, tuple]] = {
    "llama4": {
        "baseline": ({}, "paper-faithful baseline (4 microbatches, "
                         "full-vocab CE)"),
        "v1_chunked_ce": ({"vocab_chunks": 4},
                          "fp32 (B,S,V) logits never materialise → memory "
                          "term down by ~2×0.83GB/chip of HBM traffic per "
                          "microbatch; no flop change"),
        "v2_micro2": ({"microbatches": 2, "vocab_chunks": 4},
                      "FSDP re-gathers params once per microbatch: halving "
                      "microbatches halves the all-gather volume; activation "
                      "memory doubles (remat keeps it transient)"),
        "v3_micro8": ({"microbatches": 8, "vocab_chunks": 4},
                      "counter-probe: more microbatches should INCREASE the "
                      "collective term ~2× if the re-gather hypothesis holds"),
        "v4_no_remat": ({"vocab_chunks": 4, "remat": False},
                        "remat recomputes the forward inside backward — "
                        "dropping it cuts compute ~25% and the re-gather "
                        "volume by 1/3, at the cost of saved activations"),
        "v5_shard_heads": ({"vocab_chunks": 4, "shard_heads": True},
                           "HLO shows 6× fp32 (4,5,4096,4096) logits "
                           "ALL-REDUCES × 96 trips (≈770 GB/chip): GSPMD "
                           "sharded the attention contraction because 40 "
                           "heads don't divide the 16-way model axis. "
                           "Pinning q/k/v/out to head-sharding (padded "
                           "40→48) eliminates the logits all-reduce "
                           "entirely → predicted X down ~40%"),
        "v6_combined": ({"vocab_chunks": 4, "shard_heads": True,
                         "microbatches": 8},
                        "deploy config: head-sharding (X win) + 8 "
                        "microbatches (memory win, X-neutral per v2/v3) + "
                        "chunked CE (memory win) — the confirmed variants "
                        "composed; predicted ≈ v5 terms at ≈ v3 memory"),
    },
    "flux": {
        "baseline": ({}, "paper-faithful baseline (spatial-sharded batch-4 "
                         "latents, TP over model axis)"),
        "v1_seq_parallel": ({"seq_shard": True},
                            "Megatron-style sequence parallelism: the "
                            "residual stream stays token-sharded over the "
                            "model axis between blocks, so the per-block TP "
                            "all-reduce decomposes into reduce-scatter + "
                            "all-gather and the norm/pointwise work "
                            "parallelises 16-way → collective term down, "
                            "memory term down"),
        "v2_submesh16": ({"submesh": (1, 16)},
                         "serving-throughput variant: one request on a "
                         "16-chip TP sub-mesh (batch replicated), 16 "
                         "concurrent requests per pod. Per-request step "
                         "time worsens ~3×, but pod throughput ≈ "
                         "16/(3×) ≈ 5× — the latency/throughput tradeoff "
                         "the paper's node-level scheduler exploits"),
    },
    "unet": {
        "baseline": ({}, "paper-faithful baseline (channel-TP convs)"),
        "v1_dp_only": ({"dp_only": True},
                       "0.86B params fit replicated: pure DP over all 256 "
                       "chips (1 img/chip) replaces per-conv TP collectives "
                       "with ONE 3.4GB gradient all-reduce → predicted "
                       "X ≈ 69ms vs 118ms baseline"),
        "v2_dp_bf16": ({"dp_only": True, "bf16_params": True},
                       "on top of v1: bf16 params → bf16 gradients halve "
                       "the all-reduce volume → predicted X ≈ 45ms"),
    },
}


def run_variant(cell_key: str, variant: str, out_dir: str) -> Dict[str, Any]:
    arch_name, shape_name = HILLCLIMB[cell_key]
    options, hypothesis = VARIANTS[cell_key][variant]
    options = dict(options)
    submesh = options.pop("submesh", None)
    t0 = time.perf_counter()
    rec = dr.run_cell(arch_name, shape_name, multi_pod=False,
                      skip_model_flops=False, options=options,
                      submesh=submesh)
    rec["variant"] = variant
    rec["hypothesis"] = hypothesis
    rec["options"] = options
    rec["wall_s"] = time.perf_counter() - t0
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{cell_key}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    t = rec["terms"]
    print(f"[{cell_key}/{variant}] C={t['compute_s']*1e3:.1f}ms "
          f"M={t['memory_s']*1e3:.1f}ms X={t['collective_s']*1e3:.1f}ms "
          f"dom={t['dominant']} mfu={t['mfu']:.4f} "
          f"mem={rec['memory']['peak_estimate_bytes']/2**30:.1f}GiB")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(HILLCLIMB))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    variants = [args.variant] if args.variant else \
        list(VARIANTS[args.cell])
    for v in variants:
        run_variant(args.cell, v, args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
