"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init, and
smoke tests must keep seeing 1 device.

Topology: v5e pod of 256 chips as (data=16, model=16); two pods add a
leading ``pod`` axis used as an outer data axis (pure DP across pods — the
only cross-pod collective is the gradient all-reduce, the right shape when
inter-pod DCI bandwidth ≪ intra-pod ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(multi_pod: bool = False):
    return ({"pod": 2, "data": 16, "model": 16} if multi_pod
            else {"data": 16, "model": 16})


def n_chips(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
