"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init, and
smoke tests must keep seeing 1 device.

Topology: v5e pod of 256 chips as (data=16, model=16); two pods add a
leading ``pod`` axis used as an outer data axis (pure DP across pods — the
only cross-pod collective is the gradient all-reduce, the right shape when
inter-pod DCI bandwidth ≪ intra-pod ICI).
"""
from __future__ import annotations

import os
import re

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(multi_pod: bool = False):
    return ({"pod": 2, "data": 16, "model": 16} if multi_pod
            else {"data": 16, "model": 16})


def n_chips(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256


def make_node_mesh(mesh_nodes: int):
    """1-D ``("nodes",)`` mesh for the sharded cluster-retrieval scans
    (core/cluster_index.py): the embarrassingly-parallel node axis of the
    stacked cache slabs maps one shard of nodes per device.  Raises
    ``ValueError`` when the backend has fewer devices than requested —
    callers that want graceful degradation (tests, CLI) check
    ``len(jax.devices())`` first or force host devices with
    :func:`ensure_host_devices`."""
    if mesh_nodes < 1:
        raise ValueError(f"mesh_nodes must be >= 1, got {mesh_nodes}")
    avail = len(jax.devices())
    if avail < mesh_nodes:
        raise ValueError(
            f"mesh_nodes={mesh_nodes} needs that many devices, backend has "
            f"{avail}; on CPU force more with ensure_host_devices() BEFORE "
            "first jax use")
    return jax.make_mesh((mesh_nodes,), ("nodes",))


def ensure_host_devices(n: int) -> bool:
    """Best-effort: force ``n`` host-platform XLA devices by appending
    ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS`` — only
    effective BEFORE the XLA backend initialises (jax import alone does
    not initialise it; first device/array use does).  Returns True when
    the flag is in place or the backend already exposes >= n devices,
    False when the backend is already up with fewer (callers skip their
    sharded path instead of erroring)."""
    from jax._src import xla_bridge

    if xla_bridge._backends:                      # backend already up
        return len(jax.devices()) >= n
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m and int(m.group(1)) >= n:
        return True
    if m:                                         # raise an existing, smaller count
        flags = flags.replace(m.group(0), "")
    os.environ["XLA_FLAGS"] = (
        flags + f" --xla_force_host_platform_device_count={n}").strip()
    return True
