"""Architecture registry: ``--arch <id>`` resolves here.

Each ``src/repro/configs/<id>.py`` module defines an ``ARCH: ArchSpec``.
``make_config(shape)`` returns the family config tuned for one shape cell
(e.g. the latent resolution of a diffusion cell, remat on for train cells);
``make_reduced()`` returns a tiny same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.configs.shapes import ShapeCell, get_shape, shapes_for_family

ARCH_IDS: Tuple[str, ...] = (
    # LM family
    "llama4-maverick-400b-a17b",
    "moonshot-v1-16b-a3b",
    "qwen3-14b",
    "qwen2-0.5b",
    # diffusion
    "dit-b2",
    "unet-sd15",
    "flux-dev",
    "dit-l2",
    # vision
    "convnext-b",
    "efficientnet-b7",
    # the paper's own CPU-scale reproduction model
    "sd15-small",
)

_MODULE_OF = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
              for a in ARCH_IDS}


@dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                       # lm | diffusion-dit | diffusion-unet |
    #                                   diffusion-mmdit | vision-convnext |
    #                                   vision-effnet
    make_config: Callable[[ShapeCell], Any]
    make_reduced: Callable[[], Any]
    shapes: Tuple[str, ...]
    optimizer: str = "adamw"          # adamw | adafactor
    fsdp_params: bool = False         # additionally shard params over data
    param_dtype: str = "float32"      # storage dtype at full scale
    train_microbatches: Optional[int] = None  # override the cell's count
    technique: str = ""               # how CacheGenius applies (§Arch-applicability)
    source: str = ""

    @property
    def family_group(self) -> str:
        return ("lm" if self.family.startswith("lm")
                else "vision" if self.family.startswith("vision")
                else "diffusion")

    def cells(self) -> Tuple[ShapeCell, ...]:
        return tuple(get_shape(self.family_group, s) for s in self.shapes)


_cache: Dict[str, ArchSpec] = {}


def get_arch(name: str) -> ArchSpec:
    if name not in _cache:
        if name not in _MODULE_OF:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_OF)}")
        mod = importlib.import_module(_MODULE_OF[name])
        _cache[name] = mod.ARCH
    return _cache[name]


def list_archs(include_paper_model: bool = False) -> Tuple[str, ...]:
    out = tuple(a for a in ARCH_IDS if a != "sd15-small")
    return out + (("sd15-small",) if include_paper_model else ())


def all_cells(include_paper_model: bool = False):
    """Yield every assigned (arch, shape) pair — the 40 dry-run cells."""
    for a in list_archs(include_paper_model):
        arch = get_arch(a)
        for cell in arch.cells():
            yield arch, cell
