"""unet-sd15 — the paper's own base model (Stable Diffusion v1.5 UNet).

[arXiv:2112.10752; paper]
img_res=512 latent_res=64 ch=320 ch_mult=1-2-4-4 n_res_blocks=2
attn_res=4-2-1 ctx_dim=768.  ≈0.86B UNet params.

This is the arch the paper runs: SDEdit partial-noise start in latent
space (§III-C) with K=20 < N=30/50 steps.
"""
from __future__ import annotations

from repro.configs.diffusion_common import (DiffusionConfig, FULL_VAE,
                                            REDUCED_VAE)
from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.diffusion.unet import UNetConfig


def make_config(cell: ShapeCell) -> DiffusionConfig:
    return DiffusionConfig(
        backbone="unet",
        net=UNetConfig(in_ch=FULL_VAE.z_ch, ch=320, ch_mult=(1, 2, 4, 4),
                       n_res=2, attn_factors=(1, 2, 4), n_heads=8,
                       ctx_dim=768, remat=(cell.kind == "train")),
        vae=FULL_VAE,
        ctx_len=77, ctx_dim=768,
    )


def make_reduced() -> DiffusionConfig:
    return DiffusionConfig(
        backbone="unet",
        net=UNetConfig(in_ch=REDUCED_VAE.z_ch, ch=16, ch_mult=(1, 2),
                       n_res=1, attn_factors=(2,), n_heads=2, ctx_dim=64,
                       groups=8),
        vae=REDUCED_VAE,
        ctx_len=8, ctx_dim=64,
    )


ARCH = ArchSpec(
    name="unet-sd15",
    family="diffusion-unet",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_256", "gen_1024", "gen_fast", "train_1024"),
    optimizer="adamw",
    technique="The paper's own model: SDEdit img2img in latent space.",
    source="arXiv:2112.10752; paper",
)
