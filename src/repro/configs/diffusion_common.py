"""Shared diffusion-config plumbing: every diffusion arch bundles a
backbone + the f8 VAE + its conditioning interface."""
from __future__ import annotations

from typing import Any, NamedTuple

from repro.models.diffusion.vae import VAEConfig

# Stable-Diffusion-class f8 autoencoder (3 stride-2 stages).
FULL_VAE = VAEConfig(in_ch=3, base_ch=128, ch_mult=(1, 2, 4), z_ch=4, n_res=2)
REDUCED_VAE = VAEConfig(in_ch=3, base_ch=16, ch_mult=(1, 2), z_ch=4, n_res=1)


class DiffusionConfig(NamedTuple):
    backbone: str          # "dit" | "unet" | "mmdit"
    net: Any               # DiTConfig | UNetConfig | MMDiTConfig
    vae: VAEConfig
    ctx_len: int = 77      # text tokens (unet / mmdit conditioning)
    ctx_dim: int = 768
    pooled_dim: int = 512  # pooled conditioning (dit / mmdit vec)

    @property
    def latent_res(self) -> int:
        if self.backbone == "unet":
            return self._unet_latent
        return self.net.img_res

    @property
    def _unet_latent(self) -> int:
        # UNetConfig carries no resolution; steps.py passes it explicitly.
        raise AttributeError("UNet latent res comes from the shape cell")


def latent_res_of(img_res: int, vae: VAEConfig) -> int:
    return img_res // vae.downsample
