"""qwen2-0.5b — small dense decoder with QKV bias and tied embeddings.

[arXiv:2407.10671; hf]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias,
tied input/output embeddings.  ≈0.49B params.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.transformer.lm import LMConfig


def make_config(cell: ShapeCell) -> LMConfig:
    return LMConfig(
        vocab=151_936,
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        pattern=("dense",),
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        max_seq=max(cell.seq_len, 8192),
        remat=(cell.kind == "train"),
    )


def make_reduced() -> LMConfig:
    return LMConfig(vocab=512, n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=128, qkv_bias=True,
                    tie_embeddings=True, max_seq=128)


ARCH = ArchSpec(
    name="qwen2-0.5b",
    family="lm-dense",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    optimizer="adamw",
    technique=("Partial (beyond-paper): semantic response cache in serving."),
    source="arXiv:2407.10671; hf",
)
