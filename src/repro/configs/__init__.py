"""Assigned architecture configs + input-shape cells (``--arch <id>``)."""
from repro.configs.registry import (ARCH_IDS, ArchSpec, all_cells, get_arch,
                                    list_archs)  # noqa: F401
from repro.configs.shapes import (DIFFUSION_SHAPES, LM_SHAPES, ShapeCell,
                                  VISION_SHAPES, get_shape,
                                  shapes_for_family)  # noqa: F401
