"""sd15-small — the CPU-scale reproduction model the benchmarks train.

A tiny DiT + tiny VAE over the 32×32 synthetic captioned corpus; this is
the "Stable Diffusion" stand-in that the CacheGenius experiments
(benchmarks/) actually run end-to-end on this container.  Not part of the
40 assigned dry-run cells.
"""
from __future__ import annotations

from repro.configs.diffusion_common import DiffusionConfig
from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.diffusion.dit import DiTConfig
from repro.models.diffusion.vae import VAEConfig

TINY_VAE = VAEConfig(in_ch=3, base_ch=16, ch_mult=(1, 2), z_ch=4, n_res=1)


def make_config(cell: ShapeCell = None) -> DiffusionConfig:  # noqa: ARG001
    return DiffusionConfig(
        backbone="dit",
        net=DiTConfig(img_res=8, in_ch=TINY_VAE.z_ch, patch=1,
                      n_layers=4, d_model=128, n_heads=4, ctx_dim=512),
        vae=TINY_VAE,
    )


make_reduced = make_config

ARCH = ArchSpec(
    name="sd15-small",
    family="diffusion-dit",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_256", "gen_fast"),
    optimizer="adamw",
    technique="The reproduction substrate for every paper benchmark.",
    source="this repo",
)
