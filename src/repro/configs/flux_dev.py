"""flux-dev — MMDiT rectified-flow model. [BFL tech report; unverified]

img_res=1024 latent_res=128, 19 double + 38 single blocks, d_model=3072,
24 heads, ≈12B params.  CacheGenius adapted: the rectified-flow analogue
of SDEdit starts integration at x_t = (1−t)·z_ref + t·ε with t = strength
(``rf_edit`` in models/diffusion/sampler.py); same cache policy.
"""
from __future__ import annotations

from repro.configs.diffusion_common import (DiffusionConfig, FULL_VAE,
                                            REDUCED_VAE, latent_res_of)
from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.diffusion.mmdit import MMDiTConfig


def make_config(cell: ShapeCell) -> DiffusionConfig:
    latent = latent_res_of(cell.img_res or 1024, FULL_VAE)
    return DiffusionConfig(
        backbone="mmdit",
        net=MMDiTConfig(img_res=latent, in_ch=FULL_VAE.z_ch, patch=2,
                        n_double=19, n_single=38, d_model=3072, n_heads=24,
                        txt_len=256, txt_dim=768, vec_dim=512,
                        remat=(cell.kind == "train")),
        vae=FULL_VAE,
        ctx_len=256, ctx_dim=768,
    )


def make_reduced() -> DiffusionConfig:
    return DiffusionConfig(
        backbone="mmdit",
        net=MMDiTConfig(img_res=8, in_ch=REDUCED_VAE.z_ch, patch=2,
                        n_double=2, n_single=2, d_model=96, n_heads=4,
                        txt_len=8, txt_dim=64, vec_dim=64),
        vae=REDUCED_VAE,
        ctx_len=8, ctx_dim=64, pooled_dim=64,
    )


ARCH = ArchSpec(
    name="flux-dev",
    family="diffusion-mmdit",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_256", "gen_1024", "gen_fast", "train_1024"),
    optimizer="adamw",
    fsdp_params=True,
    param_dtype="bfloat16",
    technique=("Adapted: rf_edit — rectified-flow SDEdit analogue; same "
               "Algorithm 1 thresholds."),
    source="BFL tech report; unverified",
)
