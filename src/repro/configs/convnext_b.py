"""convnext-b — ConvNeXt-Base. [arXiv:2201.03545; paper]

img_res=224 depths=3-3-27-3 dims=128-256-512-1024.  Classification is one
forward pass — no multi-step loop for CacheGenius to shorten; supported
with an embedding-keyed prediction cache for near-duplicate inputs, but
reported baseline-only (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.vision.convnext import ConvNeXtConfig


def make_config(cell: ShapeCell) -> ConvNeXtConfig:
    return ConvNeXtConfig(depths=(3, 3, 27, 3), dims=(128, 256, 512, 1024),
                          n_classes=1000, remat=(cell.kind == "train"))


def make_reduced() -> ConvNeXtConfig:
    return ConvNeXtConfig(depths=(1, 1, 2, 1), dims=(16, 32, 64, 128),
                          n_classes=10)


ARCH = ArchSpec(
    name="convnext-b",
    family="vision-convnext",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("cls_224", "cls_384", "serve_b1", "serve_b128"),
    optimizer="adamw",
    technique=("Mostly inapplicable: single forward pass; prediction cache "
               "only. Reported baseline-only."),
    source="arXiv:2201.03545; paper",
)
