"""qwen3-14b — dense decoder with qk-norm and GQA.

[hf:Qwen/Qwen3-8B; hf]
40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk_norm.
≈14.8B params (measured via eval_shape in the smoke tests).
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.transformer.lm import LMConfig


def make_config(cell: ShapeCell) -> LMConfig:
    return LMConfig(
        vocab=151_936,
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17_408,
        pattern=("dense",),
        qk_norm=True,
        rope_theta=1_000_000.0,
        max_seq=max(cell.seq_len, 8192),
        remat=(cell.kind == "train"),
    )


def make_reduced() -> LMConfig:
    return LMConfig(vocab=512, n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=160, qk_norm=True,
                    max_seq=128)


ARCH = ArchSpec(
    name="qwen3-14b",
    family="lm-dense",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    optimizer="adamw",
    technique=("Partial (beyond-paper): semantic response cache in serving."),
    source="hf:Qwen/Qwen3-8B; hf",
)
