"""llama4-maverick-400b-a17b — interleaved dense/MoE decoder.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts
top-1 + shared expert, early fusion (the modality frontend is a stub —
``input_specs`` provides token ids; LM backbone only).

Param audit (measured by tests/test_arch_smoke.py at full config via
eval_shape): ≈400B total, ≈17B active per token (top-1 of 128 + shared).

Scale notes: trains with Adafactor (factored second moment) + bf16 params
+ FSDP param sharding over the data axis — full fp32 Adam moments for 400B
params (3.2TB) cannot fit a 256-chip v5e pod.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.transformer.lm import LMConfig
from repro.models.transformer.moe import MoEConfig


def make_config(cell: ShapeCell) -> LMConfig:
    return LMConfig(
        vocab=202_048,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,                      # dense interleaved layers
        pattern=("dense", "moe"),       # early-fusion interleaving
        moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192,
                      shared_expert_ff=8192, capacity_factor=1.25),
        rope_theta=500_000.0,
        max_seq=max(cell.seq_len, 8192),
        remat=(cell.kind == "train"),
    )


def make_reduced() -> LMConfig:
    return LMConfig(vocab=512, n_layers=4, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=128,
                    pattern=("dense", "moe"),
                    moe=MoEConfig(n_experts=8, top_k=1, d_ff=128,
                                  shared_expert_ff=128),
                    max_seq=128)


ARCH = ArchSpec(
    name="llama4-maverick-400b-a17b",
    family="lm-moe",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    optimizer="adafactor",
    fsdp_params=True,
    param_dtype="bfloat16",
    # FSDP re-gathers params once per microbatch: 4 microbatches is the
    # memory/collective sweet spot at 400B on 256 chips (see §Perf).
    train_microbatches=4,
    technique=("Partial (beyond-paper): GPTCache-style semantic response "
               "cache in the serving front-end; no img2img analog for "
               "discrete tokens. Storage-classifier K-means mirrors "
               "expert-affinity routing."),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
