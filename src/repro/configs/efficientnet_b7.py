"""efficientnet-b7 — compound-scaled EfficientNet. [arXiv:1905.11946; paper]

width_mult=2.0 depth_mult=3.1 over the B0 block table (native img_res=600;
the assigned shape cells run 224/384 per the vision shape set).
Same CacheGenius applicability note as convnext-b: baseline-only.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.vision.efficientnet import EffNetConfig


def make_config(cell: ShapeCell) -> EffNetConfig:
    return EffNetConfig(width_mult=2.0, depth_mult=3.1, n_classes=1000,
                        remat=(cell.kind == "train"))


def make_reduced() -> EffNetConfig:
    return EffNetConfig(width_mult=0.35, depth_mult=0.35, n_classes=10)


ARCH = ArchSpec(
    name="efficientnet-b7",
    family="vision-effnet",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("cls_224", "cls_384", "serve_b1", "serve_b128"),
    optimizer="adamw",
    technique=("Mostly inapplicable: single forward pass; prediction cache "
               "only. Reported baseline-only."),
    source="arXiv:1905.11946; paper",
)
