"""dit-l2 — Diffusion Transformer L/2 (Peebles & Xie). [arXiv:2212.09748; paper]

img_res=256 patch=2 n_layers=24 d_model=1024 n_heads=16.
"""
from __future__ import annotations

from repro.configs.diffusion_common import (DiffusionConfig, FULL_VAE,
                                            REDUCED_VAE, latent_res_of)
from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.diffusion.dit import DiTConfig


def make_config(cell: ShapeCell) -> DiffusionConfig:
    latent = latent_res_of(cell.img_res or 256, FULL_VAE)
    return DiffusionConfig(
        backbone="dit",
        net=DiTConfig(img_res=latent, in_ch=FULL_VAE.z_ch, patch=2,
                      n_layers=24, d_model=1024, n_heads=16,
                      ctx_dim=512, remat=(cell.kind == "train")),
        vae=FULL_VAE,
    )


def make_reduced() -> DiffusionConfig:
    return DiffusionConfig(
        backbone="dit",
        net=DiTConfig(img_res=8, in_ch=REDUCED_VAE.z_ch, patch=2,
                      n_layers=3, d_model=96, n_heads=4, ctx_dim=512),
        vae=REDUCED_VAE,
    )


ARCH = ArchSpec(
    name="dit-l2",
    family="diffusion-dit",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_256", "gen_1024", "gen_fast", "train_1024"),
    optimizer="adamw",
    technique="Primary: full Algorithm 1 serve path (0/K/N steps).",
    source="arXiv:2212.09748; paper",
)
