"""moonshot-v1-16b-a3b — kimi/moonlight-style all-MoE decoder.

[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16 → MHA) d_ff=1408 per expert,
vocab=163840, MoE 64 experts top-6 every layer.

Active ≈3.3B per token (6/64 experts × 48 layers) — matches the a3b tag;
total follows from the assigned layer count as listed.
"""
from __future__ import annotations

from repro.configs.registry import ArchSpec
from repro.configs.shapes import ShapeCell
from repro.models.transformer.lm import LMConfig
from repro.models.transformer.moe import MoEConfig


def make_config(cell: ShapeCell) -> LMConfig:
    return LMConfig(
        vocab=163_840,
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        pattern=("moe",),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408,
                      capacity_factor=1.25),
        rope_theta=50_000.0,
        max_seq=max(cell.seq_len, 8192),
        remat=(cell.kind == "train"),
    )


def make_reduced() -> LMConfig:
    return LMConfig(vocab=512, n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=4, head_dim=16, d_ff=96, pattern=("moe",),
                    moe=MoEConfig(n_experts=8, top_k=2, d_ff=96),
                    max_seq=128)


ARCH = ArchSpec(
    name="moonshot-v1-16b-a3b",
    family="lm-moe",
    make_config=make_config,
    make_reduced=make_reduced,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    optimizer="adamw",
    technique=("Partial (beyond-paper): semantic response cache in serving; "
               "decode compute itself uncached."),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
