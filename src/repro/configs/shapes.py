"""Input-shape cells per architecture family (40 assigned cells total).

Every cell names the step it lowers:
  * ``train``    — train_step (forward + backward + optimizer update)
  * ``prefill``  — LM prefill: full-sequence forward returning KV caches
  * ``decode``   — LM serve_step: one new token against a seq_len KV cache
  * ``gen``      — diffusion serve_step: ONE denoising step (the sampler
                   multiplies by ``steps``; that multiplier is exactly where
                   CacheGenius acts: N→K→0)
  * ``infer``    — vision forward pass

``shard_kv`` picks how the decode KV cache is partitioned (DESIGN.md §4):
decode_32k shards the cache sequence over ``model`` (batch over data);
long_500k (batch=1) shards the 524288-long cache over ``data``+``model`` —
the softmax reduction then lowers to an all-reduce: flash-decoding derived
by SPMD instead of hand-written, so full-attention archs run the cell.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                       # train | prefill | decode | gen | infer
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # diffusion / vision fields
    img_res: int = 0
    steps: int = 0                  # sampler step count (gen) / train steps
    # execution knobs
    microbatches: int = 1           # grad-accumulation chunks for train cells
    shard_kv: Optional[str] = None  # None | "model" | "data_model"
    shard_spatial: bool = False     # shard image H dim instead of batch
    notes: str = ""


LM_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", seq_len=4096, global_batch=256,
              microbatches=16),
    ShapeCell("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeCell("decode_32k", "decode", seq_len=32768, global_batch=128,
              shard_kv="model"),
    ShapeCell("long_500k", "decode", seq_len=524288, global_batch=1,
              shard_kv="data_model",
              notes="KV cache sequence-sharded over data+model; softmax "
                    "reduction = SPMD-derived flash-decoding"),
)

DIFFUSION_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_256", "train", img_res=256, global_batch=256,
              steps=1000, microbatches=1),
    ShapeCell("gen_1024", "gen", img_res=1024, global_batch=4, steps=50,
              shard_spatial=True),
    ShapeCell("gen_fast", "gen", img_res=512, global_batch=16, steps=4),
    ShapeCell("train_1024", "train", img_res=1024, global_batch=32,
              steps=1000, microbatches=2),
)

VISION_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("cls_224", "train", img_res=224, global_batch=256),
    ShapeCell("cls_384", "train", img_res=384, global_batch=64),
    ShapeCell("serve_b1", "infer", img_res=224, global_batch=1,
              shard_spatial=True),
    ShapeCell("serve_b128", "infer", img_res=224, global_batch=128),
)

_BY_FAMILY = {"lm": LM_SHAPES, "diffusion": DIFFUSION_SHAPES,
              "vision": VISION_SHAPES}


def shapes_for_family(family: str) -> Tuple[ShapeCell, ...]:
    key = "lm" if family.startswith("lm") else \
          "vision" if family.startswith("vision") else "diffusion"
    return _BY_FAMILY[key]


def get_shape(family: str, name: str) -> ShapeCell:
    for c in shapes_for_family(family):
        if c.name == name:
            return c
    raise KeyError(f"{family} has no shape {name!r}")
