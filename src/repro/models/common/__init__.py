"""Shared building blocks for every model family."""
from repro.models.common.layers import (  # noqa: F401
    dense,
    groupnorm,
    init_dense,
    init_groupnorm,
    init_layernorm,
    init_rmsnorm,
    layernorm,
    modulate,
    patchify,
    rmsnorm,
    rope_freqs,
    apply_rope,
    timestep_embedding,
    unpatchify,
)
