"""Foundational layers shared by every model family.

Conventions
-----------
* Parameters are plain nested dicts of jnp arrays ("functional" style; no
  framework).  ``init_*`` functions build the dict, the lower-case twin
  applies it.  All ``init_*`` functions are pure so they can run under
  ``jax.eval_shape`` — the multi-pod dry-run materialises parameter
  *specs* only, never the arrays.
* ``dtype`` is the computation dtype, ``param_dtype`` the storage dtype.
* Matmuls use ``jnp.einsum`` with explicit subscripts so XLA/GSPMD sees
  clean contractions to partition.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------


def _normal(key, shape, stddev, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def lecun_normal(key, shape, in_axis_size, dtype=jnp.float32):
    return _normal(key, shape, 1.0 / math.sqrt(max(1, in_axis_size)), dtype)


# ---------------------------------------------------------------------------
# dense / linear
# ---------------------------------------------------------------------------


def init_dense(key, in_dim: int, out_dim: int, *, use_bias: bool = False,
               param_dtype=jnp.float32, scale: float | None = None):
    stddev = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": _normal(key, (in_dim, out_dim), stddev, param_dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), param_dtype)
    return p


def dense(p, x, *, dtype=None):
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = jnp.einsum("...i,io->...o", x, w)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, param_dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), param_dtype)}


def rmsnorm(p, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim: int, param_dtype=jnp.float32, *, use_scale=True, use_bias=True):
    p = {}
    if use_scale:
        p["scale"] = jnp.ones((dim,), param_dtype)
    if use_bias:
        p["bias"] = jnp.zeros((dim,), param_dtype)
    return p


def layernorm(p, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    if p and "scale" in p:
        y = y * p["scale"].astype(jnp.float32)
    if p and "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


def init_groupnorm(channels: int, param_dtype=jnp.float32):
    return {"scale": jnp.ones((channels,), param_dtype),
            "bias": jnp.zeros((channels,), param_dtype)}


def groupnorm(p, x, *, groups: int = 32, eps: float = 1e-5):
    """GroupNorm over NHWC (or N...C) tensors."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    c = x.shape[-1]
    g = min(groups, c)
    while c % g:  # channels must divide; shrink groups if needed (reduced configs)
        g -= 1
    shape = x.shape[:-1] + (g, c // g)
    xg = x.reshape(shape)
    red_axes = tuple(range(1, len(shape) - 2)) + (len(shape) - 1,)
    mean = jnp.mean(xg, axis=red_axes, keepdims=True)
    var = jnp.mean(jnp.square(xg - mean), axis=red_axes, keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(x.shape)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


def modulate(x, shift, scale):
    """adaLN modulation: x * (1 + scale) + shift; shift/scale broadcast over tokens."""
    return x * (1.0 + scale[..., None, :]) + shift[..., None, :]


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, max_len: int, *, theta: float = 10000.0):
    """Return (cos, sin) of shape (max_len, head_dim//2) in float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin, positions=None):
    """x: (..., seq, heads, head_dim). cos/sin: (max_len, head_dim//2).

    positions: optional (..., seq) int positions (for decode); default arange.
    """
    seq = x.shape[-3]
    if positions is None:
        c = cos[:seq][None, :, None, :]
        s = sin[:seq][None, :, None, :]
    else:
        c = jnp.take(cos, positions, axis=0)[..., :, None, :]
        s = jnp.take(sin, positions, axis=0)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# diffusion helpers
# ---------------------------------------------------------------------------


def timestep_embedding(t, dim: int, *, max_period: float = 10000.0):
    """Sinusoidal timestep embedding. t: (batch,) float; returns (batch, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None, :]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


def patchify(x, patch: int):
    """(B, H, W, C) -> (B, H/p * W/p, p*p*C)."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // patch, patch, w // patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (h // patch) * (w // patch), patch * patch * c)


def unpatchify(x, patch: int, h: int, w: int, c: int):
    """(B, H/p * W/p, p*p*C) -> (B, H, W, C)."""
    b = x.shape[0]
    x = x.reshape(b, h // patch, w // patch, patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, h, w, c)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, dim: int, hidden: int, *, use_bias=True, param_dtype=jnp.float32,
             out_dim: int | None = None):
    k1, k2 = jax.random.split(key)
    out_dim = dim if out_dim is None else out_dim
    return {
        "fc1": init_dense(k1, dim, hidden, use_bias=use_bias, param_dtype=param_dtype),
        "fc2": init_dense(k2, hidden, out_dim, use_bias=use_bias, param_dtype=param_dtype),
    }


def mlp(p, x, *, act=jax.nn.gelu):
    return dense(p["fc2"], act(dense(p["fc1"], x)))


def init_swiglu(key, dim: int, hidden: int, *, param_dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, dim, hidden, param_dtype=param_dtype),
        "up": init_dense(k2, dim, hidden, param_dtype=param_dtype),
        "down": init_dense(k3, hidden, dim, param_dtype=param_dtype),
    }


def swiglu(p, x):
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


# ---------------------------------------------------------------------------
# convolution wrappers (NHWC)
# ---------------------------------------------------------------------------


def init_conv(key, in_ch: int, out_ch: int, kernel: int | Sequence[int], *,
              use_bias=True, param_dtype=jnp.float32, feature_group_count: int = 1):
    if isinstance(kernel, int):
        kernel = (kernel, kernel)
    fan_in = (in_ch // feature_group_count) * kernel[0] * kernel[1]
    p = {"w": _normal(key, kernel + (in_ch // feature_group_count, out_ch),
                      1.0 / math.sqrt(fan_in), param_dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_ch,), param_dtype)
    return p


def conv(p, x, *, stride: int | Sequence[int] = 1, padding="SAME",
         feature_group_count: int = 1):
    if isinstance(stride, int):
        stride = (stride, stride)
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=feature_group_count)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y
