"""Grouped-query attention used across the LM and diffusion families.

Supports:
  * GQA (n_kv_heads <= n_heads), MHA as the special case,
  * optional QK-RMSNorm (qwen3), optional QKV bias (qwen2),
  * RoPE,
  * causal or full attention,
  * single-token decode against a KV cache (flash-decoding style: the
    KV cache may be sequence-sharded; the softmax reduction then lowers
    to an all-reduce under GSPMD),
  * dispatch to the Pallas flash-attention kernel on TPU
    (``repro.kernels.ops.flash_attention``), jnp fallback elsewhere.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.runtime.pspec import current_rules, logical_constraint


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    causal: bool = True
    rope_theta: float = 10000.0
    use_pallas: bool = False  # dispatch to Pallas flash attention


def init_attention(key, cfg: AttnConfig, *, param_dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": L.init_dense(kq, cfg.d_model, cfg.n_heads * cfg.head_dim,
                           use_bias=cfg.qkv_bias, param_dtype=param_dtype),
        "wk": L.init_dense(kk, cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                           use_bias=cfg.qkv_bias, param_dtype=param_dtype),
        "wv": L.init_dense(kv, cfg.d_model, cfg.n_kv_heads * cfg.head_dim,
                           use_bias=cfg.qkv_bias, param_dtype=param_dtype),
        "wo": L.init_dense(ko, cfg.n_heads * cfg.head_dim, cfg.d_model,
                           param_dtype=param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_rmsnorm(cfg.head_dim, param_dtype)
        p["k_norm"] = L.init_rmsnorm(cfg.head_dim, param_dtype)
    return p


def _repeat_kv(x, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D)."""
    if n_rep == 1:
        return x
    b, s, h, d = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, n_rep, d))
    return x.reshape(b, s, h * n_rep, d)


# Sequences at or above this length use the chunked (flash-style) jnp path:
# the naive form materialises a (B, H, S, S) tensor — 5.5 PB at the 32k
# prefill cell — while the chunked scan keeps memory at O(B·H·S·block).
CHUNKED_SEQ_THRESHOLD = 8192


def _chunked_sdpa(q, k, v, *, causal: bool, block_k: int = 2048):
    """Online-softmax attention via lax.scan over K/V chunks.

    Pure-jnp flash attention: the same recurrence the Pallas kernel runs in
    VMEM, expressed so XLA never materialises more than one (B, H, Sq,
    block_k) logits tile.  Used for long-sequence prefill (Sq == Sk)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = dh ** -0.5
    block_k = min(block_k, sk)
    pad = (-sk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (sk + pad) // block_k
    # (nk, b, block, h, d) so scan's leading axis is the chunk index
    kc = jnp.moveaxis(k.reshape(b, nk, block_k, h, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, block_k, h, dh), 1, 0)
    rows = jnp.arange(sq, dtype=jnp.int32)[None, None, :, None]

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        cols = ci * block_k + jnp.arange(block_k, dtype=jnp.int32)[None, None, None, :]
        mask = cols < sk
        if causal:
            mask = mask & (rows >= cols)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhqk,bkhd->bhqd",
                                      p.astype(v.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nk, dtype=jnp.int32)))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return jnp.moveaxis(out, 1, 2)  # (B, Sq, H, D)


def sdpa(q, k, v, *, causal: bool, use_pallas: bool = False):
    """Scaled dot-product attention over (B, S, H, D) tensors."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal)
    if k.shape[1] >= CHUNKED_SEQ_THRESHOLD:
        return _chunked_sdpa(q, k, v, causal=causal)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), jnp.bool_), k=sk - sq)
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention(p, cfg: AttnConfig, x, *, rope=None, positions=None):
    """Full (prefill/training) attention. x: (B, S, d_model)."""
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    if rope is not None:
        cos, sin = rope
        q = L.apply_rope(q, cos, sin, positions)
        k = L.apply_rope(k, cos, sin, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    # "heads" rule (None by default; "model" under the §Perf head-sharding
    # variant): pins the attention math to head parallelism — without it,
    # GSPMD may shard the contraction instead and all-reduce the fp32
    # (B, H, S, S) logits every layer (measured: 6 × 1.25 GB × 96 trips at
    # the 400B train cell).
    kf = _repeat_kv(k, n_rep)
    vf = _repeat_kv(v, n_rep)
    if (current_rules() or {}).get("heads") is not None:
        q = logical_constraint(q, "batch", "seq", "heads", None)
        kf = logical_constraint(kf, "batch", "seq", "heads", None)
        vf = logical_constraint(vf, "batch", "seq", "heads", None)
    out = sdpa(q, kf, vf, causal=cfg.causal, use_pallas=cfg.use_pallas)
    if (current_rules() or {}).get("heads") is not None:
        out = logical_constraint(out, "batch", "seq", "heads", None)
    return L.dense(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.head_dim)), (k, v)


def decode_attention(p, cfg: AttnConfig, x, kv_cache, cache_len, *, rope=None):
    """Single-token decode. x: (B, 1, d_model); kv_cache: (k, v) each
    (B, S_max, Hkv, D). ``cache_len``: scalar or (B,) — number of valid
    cache entries. Returns (out, new_kv_cache).

    The contraction over the cache sequence axis is a plain reduction, so
    a sequence-sharded cache (long-context cells) lowers to partial
    attention + all-reduce — flash-decoding derived by SPMD rather than
    hand-written.
    """
    b = x.shape[0]
    q = L.dense(p["wq"], x).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k_new = L.dense(p["wk"], x).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v_new = L.dense(p["wv"], x).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k_new = L.rmsnorm(p["k_norm"], k_new)
    if rope is not None:
        cos, sin = rope
        pos = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1, 1), (b, 1))
        q = L.apply_rope(q, cos, sin, pos)
        k_new = L.apply_rope(k_new, cos, sin, pos)

    k_cache, v_cache = kv_cache
    s_max = k_cache.shape[1]
    # Insert the new K/V at position cache_len via a one-hot scatter-add:
    # dynamic_update_slice would force gather/scatter patterns that resist
    # sequence sharding; the one-hot formulation is a matmul-like update
    # GSPMD partitions cleanly along S_max.
    onehot = jax.nn.one_hot(jnp.asarray(cache_len).reshape(-1), s_max,
                            dtype=k_cache.dtype)  # (B, S_max) or (1, S_max)
    onehot = jnp.broadcast_to(onehot, (b, s_max))
    k_cache = k_cache * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * k_new
    v_cache = v_cache * (1 - onehot)[:, :, None, None] + onehot[:, :, None, None] * v_new

    n_rep = cfg.n_heads // cfg.n_kv_heads
    k_full = _repeat_kv(k_cache, n_rep)
    v_full = _repeat_kv(v_cache, n_rep)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_full).astype(jnp.float32) * scale
    # mask out positions beyond cache_len (inclusive of the new token)
    valid = jnp.arange(s_max)[None, :] <= jnp.asarray(cache_len).reshape(-1, 1)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_full)
    out = L.dense(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.head_dim))
    return out, (k_cache, v_cache)
