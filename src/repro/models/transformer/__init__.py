"""Decoder-only LM family: dense (qwen) and MoE (llama4 / moonshot)."""
from repro.models.transformer.lm import LMConfig, MoEConfig, init_lm, apply_lm  # noqa: F401
