"""Decoder-only LM covering the four assigned LM archs.

* qwen2-0.5b  — dense, GQA kv=2, QKV bias, tied embeddings
* qwen3-14b   — dense, GQA kv=8, qk-norm
* moonshot-v1-16b-a3b — MoE 64e top-6 every layer
* llama4-maverick-400b-a17b — interleaved (dense, MoE-128e-top-1 + shared
  expert) layer pattern

Layers are grouped by the repeating ``pattern`` (e.g. ``("dense","moe")``)
and stacked per pattern position, so the whole trunk lowers as one
``lax.scan`` over groups — compact HLO even at 48 layers / 400B params.

Three entry points:
  ``apply_lm``          — training/prefill forward → logits (+ KV caches)
  ``apply_lm_decode``   — single-token decode against stacked KV caches
  ``lm_loss``           — next-token cross-entropy with vocab-sharded
                          logsumexp (never materialises fp32 logits)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.models.common.attention import (AttnConfig, attention,
                                           decode_attention, init_attention)
from repro.models.transformer.moe import MoEConfig, init_moe, moe_ffn
from repro.runtime.pspec import logical_constraint


class LMConfig(NamedTuple):
    vocab: int
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    pattern: Tuple[str, ...] = ("dense",)
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    max_seq: int = 8192
    tie_embeddings: bool = False
    remat: bool = False
    use_pallas: bool = False

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0
        return self.n_layers // len(self.pattern)

    def attn_config(self, causal: bool = True) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                          self.head_dim, qk_norm=self.qk_norm,
                          qkv_bias=self.qkv_bias, causal=causal,
                          rope_theta=self.rope_theta, use_pallas=self.use_pallas)


def _init_layer(key, cfg: LMConfig, kind: str, param_dtype):
    ka, kf = jax.random.split(key)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, param_dtype),
        "attn": init_attention(ka, cfg.attn_config(), param_dtype=param_dtype),
        "ffn_norm": L.init_rmsnorm(cfg.d_model, param_dtype),
    }
    if kind == "dense":
        p["ffn"] = L.init_swiglu(kf, cfg.d_model, cfg.d_ff, param_dtype=param_dtype)
    elif kind == "moe":
        assert cfg.moe is not None
        p["moe"] = init_moe(kf, cfg.d_model, cfg.moe, param_dtype=param_dtype)
    else:  # pragma: no cover
        raise ValueError(kind)
    return p


def init_lm(key, cfg: LMConfig, *, param_dtype=jnp.float32):
    keys = jax.random.split(key, 4 + len(cfg.pattern))
    params = {
        "embed": L._normal(keys[0], (cfg.vocab, cfg.d_model), 0.02, param_dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_dense(keys[1], cfg.d_model, cfg.vocab,
                                         param_dtype=param_dtype)
    for pi, kind in enumerate(cfg.pattern):
        gkeys = jax.random.split(keys[3 + pi], cfg.n_groups)
        params[f"group{pi}"] = jax.vmap(
            lambda k: _init_layer(k, cfg, kind, param_dtype))(gkeys)
    return params


def _layer_fwd(lp, cfg: LMConfig, kind: str, x, rope, positions):
    h, kv = attention(lp["attn"], cfg.attn_config(), L.rmsnorm(lp["attn_norm"], x),
                      rope=rope, positions=positions)
    x = x + h
    x = logical_constraint(x, "batch", "seq", None)
    hn = L.rmsnorm(lp["ffn_norm"], x)
    if kind == "dense":
        y, aux = L.swiglu(lp["ffn"], hn), {}
    else:
        y, aux = moe_ffn(lp["moe"], cfg.moe, hn)
    x = x + y
    x = logical_constraint(x, "batch", "seq", None)
    return x, kv, aux


def apply_lm(params, cfg: LMConfig, tokens, *, positions=None,
             return_kv: bool = False):
    """tokens: (B, S) int32 → logits (B, S, vocab) [, kv caches].

    KV caches (prefill output) come back as a dict
    {pattern_idx: (k, v)} with k/v shaped (G, B, S, Hkv, Dh).

    Remat structure: the WHOLE group body is one ``jax.checkpoint`` with
    ``nothing_saveable``, so the only per-iteration residency is the scan
    carry — one (G, B, S, D) stack.  (Checkpointing each sublayer instead
    saves the residual stream at every tap point: 6× the activation
    memory at 400B scale, measured via buffer assignment.)  KV stacks are
    only emitted when the caller wants them (prefill).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    x = logical_constraint(x, "batch", "seq", None)
    rope = L.rope_freqs(cfg.head_dim, max(cfg.max_seq, tokens.shape[1]),
                        theta=cfg.rope_theta)

    def group_body(carry, gp):
        h, aux_sum = carry
        kvs = []
        for pi, kind in enumerate(cfg.pattern):
            h, kv, aux = _layer_fwd(gp[f"group{pi}"], cfg, kind, h, rope,
                                    positions)
            kvs.append(kv)
            for k_ in aux:
                aux_sum[k_] = aux_sum.get(k_, 0.0) + aux[k_]
        return (h, aux_sum), (kvs if return_kv else None)

    if cfg.remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)

    groups = {f"group{pi}": params[f"group{pi}"] for pi in range(len(cfg.pattern))}
    aux0 = {"lb_loss": 0.0, "z_loss": 0.0, "dropped_frac": 0.0} \
        if cfg.moe is not None else {}
    (x, aux), kvs = jax.lax.scan(group_body, (x, aux0), groups)

    x = L.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = L.dense(params["unembed"], x)
    logits = logical_constraint(logits, "batch", "seq", "model")
    if return_kv:
        caches = {pi: kvs[pi] for pi in range(len(cfg.pattern))}
        return logits, caches, aux
    return logits, aux


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked decode caches: {pattern_idx: (k, v)} with
    k/v: (G, B, S_max, Hkv, Dh)."""
    shape = (cfg.n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {pi: (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
            for pi in range(len(cfg.pattern))}


def apply_lm_decode(params, cfg: LMConfig, token, caches, cache_len):
    """One decode step. token: (B, 1) int32; caches from init_kv_cache;
    cache_len: () or (B,) current lengths. Returns (logits, new_caches)."""
    x = jnp.take(params["embed"], token, axis=0)
    rope = L.rope_freqs(cfg.head_dim, cfg.max_seq, theta=cfg.rope_theta)

    def group_body(h, inputs):
        new_kvs = {}
        for pi, kind in enumerate(cfg.pattern):
            lp = inputs[f"group{pi}"]
            kv = inputs[f"kv{pi}"]
            kv = (logical_constraint(kv[0], "batch", "kv_seq", None, None),
                  logical_constraint(kv[1], "batch", "kv_seq", None, None))
            a, new_kv = decode_attention(lp["attn"], cfg.attn_config(),
                                         L.rmsnorm(lp["attn_norm"], h), kv,
                                         cache_len, rope=rope)
            h = h + a
            hn = L.rmsnorm(lp["ffn_norm"], h)
            if kind == "dense":
                y = L.swiglu(lp["ffn"], hn)
            else:
                # decode must never drop tokens: capacity = all tokens
                # could route to one expert (T is tiny at decode)
                y, _ = moe_ffn(lp["moe"], cfg.moe, hn,
                               capacity=h.shape[0] * cfg.moe.top_k)
            h = h + y
            new_kvs[f"kv{pi}"] = new_kv
        return h, new_kvs

    inputs = {f"group{pi}": params[f"group{pi}"] for pi in range(len(cfg.pattern))}
    for pi in range(len(cfg.pattern)):
        inputs[f"kv{pi}"] = caches[pi]
    x, new_kvs = jax.lax.scan(group_body, x, inputs)

    x = L.rmsnorm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = L.dense(params["unembed"], x)
    new_caches = {pi: new_kvs[f"kv{pi}"] for pi in range(len(cfg.pattern))}
    return logits, new_caches


def apply_lm_hidden(params, cfg: LMConfig, tokens, *, positions=None):
    """Trunk forward WITHOUT the unembedding: final hidden (B, S, D) + aux.
    Used by the chunked-CE loss so full-vocab logits never materialise."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = logical_constraint(x, "batch", "seq", None)
    rope = L.rope_freqs(cfg.head_dim, max(cfg.max_seq, tokens.shape[1]),
                        theta=cfg.rope_theta)

    def group_body(carry, gp):
        h, aux_sum = carry
        for pi, kind in enumerate(cfg.pattern):
            h, _kv, aux = _layer_fwd(gp[f"group{pi}"], cfg, kind, h, rope,
                                     positions)
            for k_ in aux:
                aux_sum[k_] = aux_sum.get(k_, 0.0) + aux[k_]
        return (h, aux_sum), None

    if cfg.remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    groups = {f"group{pi}": params[f"group{pi}"]
              for pi in range(len(cfg.pattern))}
    aux0 = {"lb_loss": 0.0, "z_loss": 0.0, "dropped_frac": 0.0} \
        if cfg.moe is not None else {}
    (x, aux), _ = jax.lax.scan(group_body, (x, aux0), groups)
    return L.rmsnorm(params["final_norm"], x), aux


def _chunked_ce(x, w_unembed, targets, n_chunks: int):
    """Streaming log-sum-exp over vocab chunks.  x: (B, S, D);
    w_unembed: (D, V); targets: (B, S).  Never materialises more than a
    (B, S, V/n_chunks) logits tile — the fp32 (B, S, V) buffer of the
    naive path is ~0.8 GB/chip at the 400B train cell."""
    b, s, d = x.shape
    v = w_unembed.shape[1]
    assert v % n_chunks == 0, (v, n_chunks)
    cs = v // n_chunks
    wc = jnp.moveaxis(w_unembed.reshape(d, n_chunks, cs), 1, 0)  # (nc, D, cs)

    def body(carry, inp):
        m, l, tgt = carry
        w, ci = inp
        logits = jnp.einsum("bsd,dc->bsc", x, w).astype(jnp.float32)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        # target logit if it falls in this chunk
        local = targets - ci * cs
        in_chunk = (local >= 0) & (local < cs)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, cs - 1)[..., None], axis=-1)[..., 0]
        tgt = jnp.where(in_chunk, picked, tgt)
        return (m_new, l, tgt), None

    m0 = jnp.full((b, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, s), jnp.float32)
    t0 = jnp.zeros((b, s), jnp.float32)
    (m, l, tgt), _ = jax.lax.scan(
        body, (m0, l0, t0), (wc, jnp.arange(n_chunks)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return jnp.mean(lse - tgt)


def lm_loss(params, cfg: LMConfig, tokens, targets, *,
            aux_weight: float = 1e-2, vocab_chunks: int = 1):
    """Next-token CE.

    ``vocab_chunks=1`` — reference path: fp32 log-sum-exp over the
    vocab-sharded logits (all-reduce under GSPMD).
    ``vocab_chunks>1`` — streaming chunked CE (§Perf): the unembedding and
    the log-sum-exp run per vocab chunk under ``lax.scan``, so neither the
    bf16 nor the fp32 full-vocab logits ever materialise.
    """
    if vocab_chunks > 1:
        x, aux = apply_lm_hidden(params, cfg, tokens)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["unembed"]["w"]).astype(x.dtype)
        nll = _chunked_ce(x, w, targets, vocab_chunks)
    else:
        logits, aux = apply_lm(params, cfg, tokens)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = jnp.mean(lse - tgt)
    loss = nll
    if cfg.moe is not None:
        loss = loss + aux_weight * (aux["lb_loss"] + aux["z_loss"]) / cfg.n_layers
    return loss, {"nll": nll, **{k: v for k, v in aux.items()}}
