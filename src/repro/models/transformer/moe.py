"""Mixture-of-Experts FFN with sort-free scatter/gather dispatch.

Dispatch is pure data movement (gathers + one int scatter), NOT a one-hot
matmul — so compiled HLO FLOPs stay ≈ the *active*-parameter FLOPs and the
roofline's MODEL_FLOPS/HLO_FLOPs ratio is honest.  Token→expert routing:

  1. top-k router probabilities per token,
  2. rank-within-expert via a cumulative sum over the (T·k, E) one-hot
     (memory-cheap int32; GSPMD partitions the cumsum),
  3. capacity-dropped scatter of token *indices* into an (E·C,) slot map,
  4. gather tokens into (E, C, d) expert buffers  → batched expert einsum
     (experts sharded over the ``expert`` logical axis = EP on `model`),
  5. gather-back + gate-weighted combine (dropped tokens contribute 0,
     residual stream carries them unchanged).

Supports llama4-maverick (128e top-1 + shared expert, interleaved with
dense layers) and moonshot (64e top-6).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.runtime.pspec import logical_constraint


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    capacity_factor: float = 1.25
    shared_expert_ff: int = 0      # 0 → no shared expert
    router_zloss: float = 1e-3


def init_moe(key, d_model: int, cfg: MoEConfig, *, param_dtype=jnp.float32):
    k_r, k_g, k_u, k_d, k_s = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff
    scale_in = 1.0 / jnp.sqrt(d_model)
    scale_out = 1.0 / jnp.sqrt(f)
    p = {
        "router": L.init_dense(k_r, d_model, e, param_dtype=param_dtype),
        "w_gate": (jax.random.normal(k_g, (e, d_model, f), jnp.float32)
                   * scale_in).astype(param_dtype),
        "w_up": (jax.random.normal(k_u, (e, d_model, f), jnp.float32)
                 * scale_in).astype(param_dtype),
        "w_down": (jax.random.normal(k_d, (e, f, d_model), jnp.float32)
                   * scale_out).astype(param_dtype),
    }
    if cfg.shared_expert_ff:
        p["shared"] = L.init_swiglu(k_s, d_model, cfg.shared_expert_ff,
                                    param_dtype=param_dtype)
    return p


def moe_ffn(p, cfg: MoEConfig, x, *, capacity: Optional[int] = None):
    """x: (B, S, d) -> (B, S, d); plus aux losses dict."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    xf = x.reshape(t, d)

    router_logits = L.dense(p["router"], xf).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, k)                          # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # aux losses: load balance (Switch) + router z-loss
    density = jnp.mean(jax.nn.one_hot(sel[:, 0], e, dtype=jnp.float32), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    lb_loss = e * jnp.sum(density * density_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))

    if capacity is None:
        capacity = max(int(cfg.capacity_factor * t * k / e), 1)
    c = capacity

    # rank within expert ----------------------------------------------------
    sel_flat = sel.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(sel_flat, e, dtype=jnp.int32)        # (T*k, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    rank = jnp.sum(ranks * onehot, axis=-1)                      # (T*k,)
    keep = rank < c
    slot = jnp.where(keep, sel_flat * c + rank, e * c)           # overflow slot

    # scatter token indices, gather tokens into expert buffers --------------
    token_idx = jnp.arange(t * k, dtype=jnp.int32) // k
    slot_token = jnp.zeros((e * c + 1,), jnp.int32).at[slot].set(token_idx + 1)
    slot_token = slot_token[: e * c]
    occupied = slot_token > 0
    buf = jnp.where(occupied[:, None],
                    jnp.take(xf, jnp.maximum(slot_token - 1, 0), axis=0),
                    jnp.zeros((1, d), x.dtype))
    buf = buf.reshape(e, c, d)
    buf = logical_constraint(buf, "expert", None, None)

    # expert swiglu ----------------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out_buf = logical_constraint(out_buf, "expert", None, None)

    # combine ----------------------------------------------------------------
    flat_out = out_buf.reshape(e * c, d)
    picked = jnp.take(flat_out, jnp.minimum(slot, e * c - 1), axis=0)  # (T*k, d)
    picked = jnp.where(keep[:, None], picked, 0.0)
    y = jnp.sum(picked.reshape(t, k, d) * gates[..., None].astype(x.dtype), axis=1)

    if "shared" in p:
        y = y + L.swiglu(p["shared"], xf)

    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y.reshape(b, s, d), aux
