"""Model zoo: LM transformers (dense + MoE), diffusion backbones, vision nets."""
