"""Samplers: DDIM (Eq. 3), SDEdit image-to-image (Eq. 4 + partial reverse),
and rectified flow (Flux).  These implement the paper's two workflows:

  * ``ddim_sample``      — text-to-image: N steps from pure noise (Fig. 2a),
  * ``sdedit_sample``    — image-to-image: noise a reference to step K, then
                           K denoising steps (Fig. 2b / Fig. 4),
  * ``rf_sample`` / ``rf_edit`` — the rectified-flow analogues for MMDiT.

All samplers take ``eps_fn(x_t, t, ctx) -> eps`` (or ``v_fn`` for RF) so
any backbone plugs in, and run the step loop under ``lax.scan`` so a full
sampling trajectory jits into one XLA program.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.diffusion.schedule import DiffusionSchedule


def ddim_timesteps(T: int, steps: int, *, t_start: Optional[int] = None):
    """Strided DDIM sub-sequence, descending. ``t_start`` truncates the chain
    for SDEdit (start at noise level t_start instead of T).

    Computed in host numpy: every input is a static Python int, and the
    archive map (:func:`resume_noise_levels`) indexes the result inside a
    jitted trace, where a device-side constant would turn into a tracer."""
    hi = T if t_start is None else int(t_start)
    ts = np.linspace(0, hi - 1, steps).round().astype(np.int32)
    return ts[::-1]


def ddim_step(sched: DiffusionSchedule, x, eps, t, t_prev, *, eta: float = 0.0):
    """One DDIM update (Eq. 3), eta=0 → deterministic."""
    ab_t = sched.alphas_bar[t]
    ab_p = jnp.where(t_prev >= 0, sched.alphas_bar[jnp.maximum(t_prev, 0)], 1.0)
    x0_pred = (x - jnp.sqrt(1.0 - ab_t) * eps) / jnp.sqrt(ab_t)
    x0_pred = jnp.clip(x0_pred, -4.0, 4.0)
    dir_xt = jnp.sqrt(jnp.maximum(1.0 - ab_p, 0.0)) * eps
    return jnp.sqrt(ab_p) * x0_pred + dir_xt


def ddim_step_slots(sched: DiffusionSchedule, x, eps, t, t_prev, *,
                    eta: float = 0.0):
    """One DDIM update (Eq. 3) with PER-ELEMENT timesteps: ``t`` and
    ``t_prev`` are ``(B,)`` int32 vectors, so every batch element can sit
    at a different point of a different-length chain.  This is the ragged
    counterpart of :func:`ddim_step` — same x0-clip / direction math, with
    the schedule coefficients gathered per element and broadcast over the
    spatial axes.  ``t_prev < 0`` marks an element's final update (alpha-bar
    snaps to 1), exactly as the scalar step treats the chain tail."""
    shape = (-1,) + (1,) * (x.ndim - 1)
    ab_t = sched.alphas_bar[t].reshape(shape)
    ab_p = jnp.where(t_prev >= 0,
                     sched.alphas_bar[jnp.maximum(t_prev, 0)],
                     1.0).reshape(shape)
    x0_pred = (x - jnp.sqrt(1.0 - ab_t) * eps) / jnp.sqrt(ab_t)
    x0_pred = jnp.clip(x0_pred, -4.0, 4.0)
    dir_xt = jnp.sqrt(jnp.maximum(1.0 - ab_p, 0.0)) * eps
    return jnp.sqrt(ab_p) * x0_pred + dir_xt


def step_slots(eps_fn: Callable, sched: DiffusionSchedule, x, ctx, t, t_prev,
               active, *, dtype=jnp.float32):
    """ONE denoising step over a ragged slot buffer — the step-level
    continuous-batching primitive.

    ``x`` is the fixed-capacity ``(S, ...)`` latent buffer, ``ctx`` the
    per-slot conditioning, ``t``/``t_prev`` the per-slot schedule
    timesteps (host-supplied from each slot's own chain — txt2img,
    truncated img2img, or a ``resume@k`` tail), and ``active`` a ``(S,)``
    bool mask.  Inactive slots pass through UNCHANGED, so retired/free
    slots cost one masked select, never a recompile: the whole serving
    engine advances with a single compiled program per slot capacity,
    whatever mix of chains is in flight."""
    eps = eps_fn(x, t, ctx)
    x_new = ddim_step_slots(sched, x, eps, t, t_prev).astype(dtype)
    mask = active.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, x_new, x)


def _ddim_scan(eps_fn: Callable, sched: DiffusionSchedule, x, ctx, ts,
               *, eta: float = 0.0, dtype=jnp.float32):
    """The shared DDIM step loop over an explicit descending timestep
    vector — one ``lax.scan`` whether the chain is full, truncated
    (SDEdit), or resumed mid-way (the latent-depth cache)."""
    b = x.shape[0]
    ts_prev = jnp.concatenate([ts[1:], jnp.array([-1], jnp.int32)])

    def body(x, tt):
        t, t_prev = tt
        t_b = jnp.full((b,), t, jnp.int32)
        eps = eps_fn(x, t_b, ctx)
        return ddim_step(sched, x, eps, t, t_prev, eta=eta).astype(dtype), None

    x, _ = jax.lax.scan(body, x, (ts, ts_prev))
    return x


def ddim_sample(eps_fn: Callable, sched: DiffusionSchedule, shape, ctx, key,
                *, steps: int, eta: float = 0.0, x_init=None,
                t_start: Optional[int] = None, dtype=jnp.float32):
    """DDIM sampling loop.

    Text-to-image: x_init=None → start from N(0, I) at t=T.
    SDEdit:        pass x_init = q_sample(reference, t_start) and t_start < T.
    """
    k_noise, key = jax.random.split(key)
    x = jax.random.normal(k_noise, shape, dtype) if x_init is None else x_init
    ts = ddim_timesteps(sched.T, steps, t_start=t_start)
    return _ddim_scan(eps_fn, sched, x, ctx, ts, eta=eta, dtype=dtype)


def sdedit_start(sched: DiffusionSchedule, reference, noise, *,
                 strength: float, dtype=jnp.float32):
    """The SDEdit noising map (Eq. 4), shared by :func:`sdedit_sample` and
    the serving backend's batched img2img core: noise ``reference`` to
    t = strength·(T-1) with the given ``noise`` draw.

    Returns ``(x_init, t_start)`` where ``t_start`` is the (static int)
    truncation point for the DDIM chain — keeping the two strength→time
    conversions in ONE place so callers cannot drift apart."""
    t_noise = jnp.int32(strength * (sched.T - 1))
    x_init = sched.q_sample(reference.astype(dtype),
                            jnp.full((reference.shape[0],), t_noise), noise)
    return x_init.astype(dtype), int(strength * sched.T)


def sdedit_sample(eps_fn: Callable, sched: DiffusionSchedule, reference, ctx,
                  key, *, steps: int, strength: float = 0.6,
                  dtype=jnp.float32):
    """SDEdit image-to-image (paper §III-C): noise the cached reference to
    t_start = strength·T (Eq. 4), then run ``steps`` DDIM steps down.

    ``strength`` trades reference fidelity against prompt flexibility — the
    paper's t ("noise injection strength")."""
    k1, k2 = jax.random.split(key)
    noise = jax.random.normal(k1, reference.shape, dtype)
    x_init, t_start = sdedit_start(sched, reference, noise,
                                   strength=strength, dtype=dtype)
    return ddim_sample(eps_fn, sched, reference.shape, ctx, k2, steps=steps,
                       x_init=x_init, t_start=t_start, dtype=dtype)


def resume_noise_levels(sched: DiffusionSchedule, *, steps: int,
                        strength: float):
    """Forward-noise level (schedule timestep) of each depth of the
    truncated img2img DDIM chain — the latent-depth cache's archive map.

    Depth ``k`` means "k chain steps already absorbed": the archived
    latent for depth k is ``q_sample(z0_finished, levels[k], noise)`` and
    :func:`resume_sample` runs the remaining ``steps - k`` steps.  Level 0
    is EXACTLY :func:`sdedit_start`'s ``t_noise`` (``strength·(T-1)``), so
    resuming from depth 0 replays the full img2img chain; level k >= 1 is
    ``ts[k]`` of the truncated chain — the noise level the chain sits at
    after its k-th update.  Keeping both conversions here (one place)
    pins archive and resume to the same chain geometry."""
    ts = np.asarray(ddim_timesteps(sched.T, steps,
                                   t_start=int(strength * sched.T)))
    levels = [int(strength * (sched.T - 1))]
    levels += [int(ts[k]) for k in range(1, steps)]
    return levels


def resume_sample(eps_fn: Callable, sched: DiffusionSchedule, latent, ctx,
                  *, steps: int, k: int, strength: float = 0.6,
                  dtype=jnp.float32):
    """Resume the truncated img2img DDIM chain from depth ``k``: run the
    last ``steps - k`` updates of the SAME ``steps``-step chain
    :func:`sdedit_sample` would run, starting from an archived latent
    noised to ``resume_noise_levels(...)[k]``.  ``k == 0`` is the full
    img2img chain from the SDEdit initial state (identical step sequence
    and ops to ``ddim_sample(x_init=..., t_start=strength·T)``)."""
    ts = ddim_timesteps(sched.T, steps, t_start=int(strength * sched.T))
    return _ddim_scan(eps_fn, sched, latent.astype(dtype), ctx, ts[k:],
                      dtype=dtype)


# ---------------------------------------------------------------------------
# rectified flow (Flux-class MMDiT)
# ---------------------------------------------------------------------------


def rf_timesteps(steps: int, *, t_start: float = 1.0, shift: float = 1.0):
    """Descending σ ∈ (t_start .. 0]; ``shift`` is the resolution-dependent
    time-shift used by Flux (s·t / (1 + (s-1)·t))."""
    t = jnp.linspace(t_start, 0.0, steps + 1)
    if shift != 1.0:
        t = shift * t / (1.0 + (shift - 1.0) * t)
    return t


def rf_sample(v_fn: Callable, shape, ctx, key, *, steps: int,
              shift: float = 1.0, x_init=None, t_start: float = 1.0,
              dtype=jnp.float32):
    """Euler integration of dx/dt = v(x, t) from t_start down to 0.
    v_fn(x, t, ctx) predicts the velocity (x1 - x0 direction)."""
    x = jax.random.normal(key, shape, dtype) if x_init is None else x_init
    ts = rf_timesteps(steps, t_start=t_start, shift=shift)

    def body(x, i):
        t_cur, t_nxt = ts[i], ts[i + 1]
        t_b = jnp.full((shape[0],), t_cur, dtype)
        v = v_fn(x, t_b, ctx)
        return (x + (t_nxt - t_cur) * v).astype(dtype), None

    x, _ = jax.lax.scan(body, x, jnp.arange(steps))
    return x


def rf_edit(v_fn: Callable, reference, ctx, key, *, steps: int,
            strength: float = 0.6, shift: float = 1.0, dtype=jnp.float32):
    """Rectified-flow SDEdit analogue: start at the straight-line
    interpolant x_t = (1-t)·ref + t·ε with t = strength, integrate down."""
    noise = jax.random.normal(key, reference.shape, dtype)
    t0 = strength
    x_init = (1.0 - t0) * reference.astype(dtype) + t0 * noise
    return rf_sample(v_fn, reference.shape, ctx, key, steps=steps, shift=shift,
                     x_init=x_init, t_start=t0, dtype=dtype)


# ---------------------------------------------------------------------------
# training losses
# ---------------------------------------------------------------------------


def ddpm_loss(eps_fn: Callable, sched: DiffusionSchedule, x0, ctx, key):
    """Simple eps-prediction MSE (Ho et al.)."""
    kt, kn = jax.random.split(key)
    b = x0.shape[0]
    t = jax.random.randint(kt, (b,), 0, sched.T)
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    x_t = sched.q_sample(x0, t, noise)
    eps = eps_fn(x_t, t, ctx)
    return jnp.mean(jnp.square(eps.astype(jnp.float32) - noise.astype(jnp.float32)))


def rf_loss(v_fn: Callable, x0, ctx, key):
    """Rectified-flow matching loss: v ≈ ε - x0 along the interpolant."""
    kt, kn = jax.random.split(key)
    b = x0.shape[0]
    t = jax.random.uniform(kt, (b,), x0.dtype)
    noise = jax.random.normal(kn, x0.shape, x0.dtype)
    shape = (-1,) + (1,) * (x0.ndim - 1)
    x_t = (1.0 - t.reshape(shape)) * x0 + t.reshape(shape) * noise
    v = v_fn(x_t, t, ctx)
    target = noise - x0
    return jnp.mean(jnp.square(v.astype(jnp.float32) - target.astype(jnp.float32)))
