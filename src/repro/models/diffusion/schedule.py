"""DDPM noise schedule (paper §III-A, Eq. 1-2) and the SDEdit forward map
(Eq. 4).  Everything is precomputed into arrays so samplers stay jittable."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DiffusionSchedule(NamedTuple):
    betas: jax.Array          # (T,)
    alphas: jax.Array         # (T,)
    alphas_bar: jax.Array     # (T,) cumulative ᾱ_t

    @property
    def T(self) -> int:
        return self.betas.shape[0]

    @classmethod
    def linear(cls, T: int = 1000, beta_start: float = 1e-4,
               beta_end: float = 0.02) -> "DiffusionSchedule":
        betas = jnp.linspace(beta_start, beta_end, T, dtype=jnp.float32)
        alphas = 1.0 - betas
        return cls(betas, alphas, jnp.cumprod(alphas))

    @classmethod
    def cosine(cls, T: int = 1000, s: float = 8e-3) -> "DiffusionSchedule":
        t = jnp.arange(T + 1, dtype=jnp.float32) / T
        f = jnp.cos((t + s) / (1 + s) * jnp.pi / 2) ** 2
        abar = f / f[0]
        betas = jnp.clip(1 - abar[1:] / abar[:-1], 1e-8, 0.999)
        alphas = 1.0 - betas
        return cls(betas, alphas, jnp.cumprod(alphas))

    # -- forward process -----------------------------------------------------

    def q_sample(self, x0, t, noise):
        """Eq. 4: x_t = sqrt(ᾱ_t) x_0 + sqrt(1-ᾱ_t) ε.  This is also the
        SDEdit noising map that turns a cached reference into the img2img
        starting point.  t: int array broadcastable to x0's batch."""
        ab = self.alphas_bar[t]
        shape = (-1,) + (1,) * (x0.ndim - 1)
        ab = ab.reshape(shape)
        return jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * noise

    def snr(self, t):
        ab = self.alphas_bar[t]
        return ab / (1.0 - ab)
