"""Convolutional VAE (the LDM autoencoder, §III-B "latent variable space").

f8 spatial compression (three stride-2 stages), GroupNorm+SiLU residual
blocks, 4 latent channels — the Stable-Diffusion layout at configurable
width.  ``encode``/``decode`` are used by every latent-diffusion arch
(unet-sd15, flux-dev, and the DiT configs); the small reproduction model
trains it jointly on the synthetic corpus.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import layers as L


class VAEConfig(NamedTuple):
    in_ch: int = 3
    base_ch: int = 64
    ch_mult: tuple = (1, 2, 4)   # one stride-2 per extra stage → f = 2^(len-1) * 2
    z_ch: int = 4
    n_res: int = 1

    @property
    def downsample(self) -> int:
        return 2 ** len(self.ch_mult)


def _init_resblock(key, in_ch, out_ch, param_dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "norm1": L.init_groupnorm(in_ch, param_dtype),
        "conv1": L.init_conv(k1, in_ch, out_ch, 3, param_dtype=param_dtype),
        "norm2": L.init_groupnorm(out_ch, param_dtype),
        "conv2": L.init_conv(k2, out_ch, out_ch, 3, param_dtype=param_dtype),
    }
    if in_ch != out_ch:
        p["skip"] = L.init_conv(k3, in_ch, out_ch, 1, param_dtype=param_dtype)
    return p


def _resblock(p, x, *, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops
        h = kops.groupnorm_silu(x, p["norm1"]["scale"], p["norm1"]["bias"])
    else:
        h = jax.nn.silu(L.groupnorm(p["norm1"], x))
    h = L.conv(p["conv1"], h)
    if use_pallas:
        from repro.kernels import ops as kops
        h = kops.groupnorm_silu(h, p["norm2"]["scale"], p["norm2"]["bias"])
    else:
        h = jax.nn.silu(L.groupnorm(p["norm2"], h))
    h = L.conv(p["conv2"], h)
    skip = L.conv(p["skip"], x) if "skip" in p else x
    return h + skip


def init_vae(key, cfg: VAEConfig, *, param_dtype=jnp.float32):
    keys = iter(jax.random.split(key, 64))
    enc = {"stem": L.init_conv(next(keys), cfg.in_ch, cfg.base_ch, 3,
                               param_dtype=param_dtype)}
    ch = cfg.base_ch
    for si, mult in enumerate(cfg.ch_mult):
        out = cfg.base_ch * mult
        stage = {"down": L.init_conv(next(keys), ch, out, 3, param_dtype=param_dtype)}
        for ri in range(cfg.n_res):
            stage[f"res{ri}"] = _init_resblock(next(keys), out, out, param_dtype)
        enc[f"stage{si}"] = stage
        ch = out
    enc["norm_out"] = L.init_groupnorm(ch, param_dtype)
    enc["to_moments"] = L.init_conv(next(keys), ch, 2 * cfg.z_ch, 1,
                                    param_dtype=param_dtype)

    dec = {"from_z": L.init_conv(next(keys), cfg.z_ch, ch, 1, param_dtype=param_dtype)}
    for si, mult in enumerate(reversed(cfg.ch_mult)):
        out = cfg.base_ch * mult
        stage = {"up": L.init_conv(next(keys), ch, out * 4, 3, param_dtype=param_dtype)}
        for ri in range(cfg.n_res):
            stage[f"res{ri}"] = _init_resblock(next(keys), out, out, param_dtype)
        dec[f"stage{si}"] = stage
        ch = out
    dec["norm_out"] = L.init_groupnorm(ch, param_dtype)
    dec["to_img"] = L.init_conv(next(keys), ch, cfg.in_ch, 3, param_dtype=param_dtype)
    return {"enc": enc, "dec": dec}


def encode(p, cfg: VAEConfig, x, *, use_pallas: bool = False):
    """x: (B, H, W, 3) -> latent moments; returns (mean, logvar)."""
    h = L.conv(p["enc"]["stem"], x)
    for si in range(len(cfg.ch_mult)):
        stage = p["enc"][f"stage{si}"]
        h = L.conv(stage["down"], h, stride=2)
        for ri in range(cfg.n_res):
            h = _resblock(stage[f"res{ri}"], h, use_pallas=use_pallas)
    h = jax.nn.silu(L.groupnorm(p["enc"]["norm_out"], h))
    moments = L.conv(p["enc"]["to_moments"], h)
    mean, logvar = jnp.split(moments, 2, axis=-1)
    return mean, jnp.clip(logvar, -30.0, 20.0)


def sample_latent(key, mean, logvar):
    return mean + jnp.exp(0.5 * logvar) * jax.random.normal(key, mean.shape, mean.dtype)


def decode(p, cfg: VAEConfig, z, *, use_pallas: bool = False):
    """z: (B, h, w, z_ch) -> image (B, H, W, 3) in [-1, 1] (tanh-free)."""
    h = L.conv(p["dec"]["from_z"], z)
    for si in range(len(cfg.ch_mult)):
        stage = p["dec"][f"stage{si}"]
        h = L.conv(stage["up"], h)
        b, hh, ww, c4 = h.shape
        h = h.reshape(b, hh, ww, 2, 2, c4 // 4).transpose(0, 1, 3, 2, 4, 5)
        h = h.reshape(b, hh * 2, ww * 2, c4 // 4)  # pixel-shuffle upsample
        for ri in range(cfg.n_res):
            h = _resblock(stage[f"res{ri}"], h, use_pallas=use_pallas)
    h = jax.nn.silu(L.groupnorm(p["dec"]["norm_out"], h))
    return L.conv(p["dec"]["to_img"], h)


def kl_loss(mean, logvar):
    return 0.5 * jnp.mean(jnp.square(mean) + jnp.exp(logvar) - 1.0 - logvar)
