"""SD1.5-class latent UNet (Rombach et al., arXiv:2112.10752).

Stable Diffusion v1.5 layout: conv stem into ``ch``, channel multipliers
``ch_mult`` with ``n_res`` residual blocks per level, spatial transformer
(self-attn + cross-attn over text tokens + GEGLU FF) at the levels whose
downsample factor is in ``attn_factors`` (the assigned config's
``attn_res=4-2-1``), a mid block, skip-connected decoder, GroupNorm+SiLU
throughout, timestep embedding injected into every residual block.

The assigned ``unet-sd15`` config is exactly: ch=320, ch_mult=(1,2,4,4),
n_res=2, attn at factors {1,2,4}, ctx_dim=768 — ≈0.86B parameters.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.models.common.attention import sdpa


class UNetConfig(NamedTuple):
    in_ch: int = 4
    ch: int = 320
    ch_mult: Sequence[int] = (1, 2, 4, 4)
    n_res: int = 2
    attn_factors: Sequence[int] = (1, 2, 4)
    n_heads: int = 8
    ctx_dim: int = 768
    tembed_dim: int = 1280
    groups: int = 32
    use_pallas: bool = False
    remat: bool = False

    def level_ch(self, i: int) -> int:
        return self.ch * self.ch_mult[i]

    def has_attn(self, level: int) -> bool:
        return (2 ** level) in tuple(self.attn_factors)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _init_res(key, cfg, in_ch, out_ch, param_dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "norm1": L.init_groupnorm(in_ch, param_dtype),
        "conv1": L.init_conv(k1, in_ch, out_ch, 3, param_dtype=param_dtype),
        "temb": L.init_dense(k2, cfg.tembed_dim, out_ch, use_bias=True,
                             param_dtype=param_dtype),
        "norm2": L.init_groupnorm(out_ch, param_dtype),
        "conv2": L.init_conv(k3, out_ch, out_ch, 3, param_dtype=param_dtype),
    }
    if in_ch != out_ch:
        p["skip"] = L.init_conv(k4, in_ch, out_ch, 1, param_dtype=param_dtype)
    return p


def _res(p, cfg, x, temb):
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        h = kops.groupnorm_silu(x, p["norm1"]["scale"], p["norm1"]["bias"],
                                groups=cfg.groups)
    else:
        h = jax.nn.silu(L.groupnorm(p["norm1"], x, groups=cfg.groups))
    h = L.conv(p["conv1"], h)
    h = h + L.dense(p["temb"], jax.nn.silu(temb))[:, None, None, :]
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        h = kops.groupnorm_silu(h, p["norm2"]["scale"], p["norm2"]["bias"],
                                groups=cfg.groups)
    else:
        h = jax.nn.silu(L.groupnorm(p["norm2"], h, groups=cfg.groups))
    h = L.conv(p["conv2"], h)
    return h + (L.conv(p["skip"], x) if "skip" in p else x)


def _init_spatial_transformer(key, cfg, ch, param_dtype):
    ks = jax.random.split(key, 9)
    inner = ch
    return {
        "norm": L.init_groupnorm(ch, param_dtype),
        "proj_in": L.init_conv(ks[0], ch, inner, 1, param_dtype=param_dtype),
        "ln1": L.init_layernorm(inner, param_dtype),
        "self_qkv": L.init_dense(ks[1], inner, 3 * inner, param_dtype=param_dtype),
        "self_out": L.init_dense(ks[2], inner, inner, param_dtype=param_dtype),
        "ln2": L.init_layernorm(inner, param_dtype),
        "cross_q": L.init_dense(ks[3], inner, inner, param_dtype=param_dtype),
        "cross_kv": L.init_dense(ks[4], cfg.ctx_dim, 2 * inner, param_dtype=param_dtype),
        "cross_out": L.init_dense(ks[5], inner, inner, param_dtype=param_dtype),
        "ln3": L.init_layernorm(inner, param_dtype),
        "geglu": L.init_dense(ks[6], inner, 8 * inner, param_dtype=param_dtype),
        "ff_out": L.init_dense(ks[7], 4 * inner, inner, param_dtype=param_dtype),
        "proj_out": L.init_conv(ks[8], inner, ch, 1, param_dtype=param_dtype),
    }


def _spatial_transformer(p, cfg, x, ctx):
    """x: (B, H, W, C); ctx: (B, S_txt, ctx_dim)."""
    b, hh, ww, c = x.shape
    heads = cfg.n_heads
    hd = c // heads
    h = L.groupnorm(p["norm"], x, groups=cfg.groups)
    h = L.conv(p["proj_in"], h).reshape(b, hh * ww, c)
    # self-attention
    qkv = L.dense(p["self_qkv"], L.layernorm(p["ln1"], h))
    q, k, v = [u.reshape(b, hh * ww, heads, hd) for u in jnp.split(qkv, 3, -1)]
    h = h + L.dense(p["self_out"],
                    sdpa(q, k, v, causal=False, use_pallas=cfg.use_pallas)
                    .reshape(b, hh * ww, c))
    # cross-attention over text tokens
    q = L.dense(p["cross_q"], L.layernorm(p["ln2"], h)).reshape(b, hh * ww, heads, hd)
    kv = L.dense(p["cross_kv"], ctx.astype(h.dtype))
    k, v = [u.reshape(b, ctx.shape[1], heads, hd) for u in jnp.split(kv, 2, -1)]
    h = h + L.dense(p["cross_out"],
                    sdpa(q, k, v, causal=False, use_pallas=cfg.use_pallas)
                    .reshape(b, hh * ww, c))
    # GEGLU feed-forward
    u = L.dense(p["geglu"], L.layernorm(p["ln3"], h))
    a, g = jnp.split(u, 2, -1)
    h = h + L.dense(p["ff_out"], a * jax.nn.gelu(g))
    h = L.conv(p["proj_out"], h.reshape(b, hh, ww, c))
    return x + h


# ---------------------------------------------------------------------------
# full UNet
# ---------------------------------------------------------------------------


def init_unet(key, cfg: UNetConfig, *, param_dtype=jnp.float32):
    keys = iter(jax.random.split(key, 256))
    nl = len(cfg.ch_mult)
    p = {
        "conv_in": L.init_conv(next(keys), cfg.in_ch, cfg.ch, 3, param_dtype=param_dtype),
        "t_mlp": L.init_mlp(next(keys), cfg.ch, cfg.tembed_dim,
                            out_dim=cfg.tembed_dim, param_dtype=param_dtype),
    }
    # -- encoder
    ch = cfg.ch
    skip_chs = [ch]
    for li in range(nl):
        out = cfg.level_ch(li)
        level = {}
        for ri in range(cfg.n_res):
            level[f"res{ri}"] = _init_res(next(keys), cfg, ch, out, param_dtype)
            ch = out
            if cfg.has_attn(li):
                level[f"attn{ri}"] = _init_spatial_transformer(next(keys), cfg, ch,
                                                               param_dtype)
            skip_chs.append(ch)
        if li != nl - 1:
            level["down"] = L.init_conv(next(keys), ch, ch, 3, param_dtype=param_dtype)
            skip_chs.append(ch)
        p[f"down{li}"] = level
    # -- mid
    p["mid_res1"] = _init_res(next(keys), cfg, ch, ch, param_dtype)
    p["mid_attn"] = _init_spatial_transformer(next(keys), cfg, ch, param_dtype)
    p["mid_res2"] = _init_res(next(keys), cfg, ch, ch, param_dtype)
    # -- decoder
    for li in reversed(range(nl)):
        out = cfg.level_ch(li)
        level = {}
        for ri in range(cfg.n_res + 1):
            skip = skip_chs.pop()
            level[f"res{ri}"] = _init_res(next(keys), cfg, ch + skip, out, param_dtype)
            ch = out
            if cfg.has_attn(li):
                level[f"attn{ri}"] = _init_spatial_transformer(next(keys), cfg, ch,
                                                               param_dtype)
        if li != 0:
            level["up"] = L.init_conv(next(keys), ch, ch * 4, 3, param_dtype=param_dtype)
        p[f"up{li}"] = level
    p["norm_out"] = L.init_groupnorm(ch, param_dtype)
    p["conv_out"] = L.init_conv(next(keys), ch, cfg.in_ch, 3, param_dtype=param_dtype)
    return p


def apply_unet(p, cfg: UNetConfig, x, t, ctx):
    """eps-prediction. x: (B, h, w, in_ch) latent; t: (B,); ctx: (B, S, ctx_dim)."""
    nl = len(cfg.ch_mult)
    temb = L.timestep_embedding(t, cfg.ch).astype(x.dtype)
    temb = L.mlp(p["t_mlp"], temb)

    def maybe_remat(fn):
        return jax.checkpoint(fn) if cfg.remat else fn

    h = L.conv(p["conv_in"], x)
    skips = [h]
    for li in range(nl):
        level = p[f"down{li}"]
        for ri in range(cfg.n_res):
            h = maybe_remat(lambda hh, blk=level[f"res{ri}"]: _res(blk, cfg, hh, temb))(h)
            if cfg.has_attn(li):
                h = maybe_remat(lambda hh, blk=level[f"attn{ri}"]:
                                _spatial_transformer(blk, cfg, hh, ctx))(h)
            skips.append(h)
        if li != nl - 1:
            h = L.conv(level["down"], h, stride=2)
            skips.append(h)

    h = _res(p["mid_res1"], cfg, h, temb)
    h = _spatial_transformer(p["mid_attn"], cfg, h, ctx)
    h = _res(p["mid_res2"], cfg, h, temb)

    for li in reversed(range(nl)):
        level = p[f"up{li}"]
        for ri in range(cfg.n_res + 1):
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = maybe_remat(lambda hh, blk=level[f"res{ri}"]: _res(blk, cfg, hh, temb))(h)
            if cfg.has_attn(li):
                h = maybe_remat(lambda hh, blk=level[f"attn{ri}"]:
                                _spatial_transformer(blk, cfg, hh, ctx))(h)
        if li != 0:
            h = L.conv(level["up"], h)
            b, hh_, ww_, c4 = h.shape
            h = h.reshape(b, hh_, ww_, 2, 2, c4 // 4).transpose(0, 1, 3, 2, 4, 5)
            h = h.reshape(b, hh_ * 2, ww_ * 2, c4 // 4)

    h = jax.nn.silu(L.groupnorm(p["norm_out"], h, groups=cfg.groups))
    return L.conv(p["conv_out"], h)


def make_eps_fn(params, cfg: UNetConfig):
    def eps_fn(x, t, ctx):
        return apply_unet(params, cfg, x, t, ctx)
    return eps_fn
