"""Diffusion model family: noise schedules, samplers (DDIM / SDEdit /
rectified flow), VAE, DiT, SD1.5-class UNet, Flux-class MMDiT."""
from repro.models.diffusion.schedule import DiffusionSchedule  # noqa: F401
from repro.models.diffusion import sampler  # noqa: F401
