"""Diffusion model family: noise schedules, samplers (DDIM / SDEdit /
rectified flow), VAE, DiT, SD1.5-class UNet, Flux-class MMDiT.

``step_slots`` / ``ddim_step_slots`` are the step-level serving
primitives: one ragged denoising step over a fixed-capacity slot buffer
with per-slot timesteps (see ``repro.runtime.serving.DiffusionSlotEngine``
for the persistent engine built on them)."""
from repro.models.diffusion.schedule import DiffusionSchedule  # noqa: F401
from repro.models.diffusion import sampler  # noqa: F401
from repro.models.diffusion.sampler import (ddim_step_slots,  # noqa: F401
                                            step_slots)
