"""Flux-class MMDiT (rectified-flow multimodal DiT; BFL tech report).

Double-stream blocks (separate image/text streams with joint attention)
followed by single-stream blocks over the concatenated sequence, adaLN
modulation from (timestep ⊕ guidance ⊕ pooled text).  The assigned
``flux-dev`` config: 19 double + 38 single blocks, d_model=3072, 24 heads,
latent 128 with patch 2 → 4096 image tokens (+ text tokens), ~12B params.

Both block families run under ``lax.scan`` over stacked params so the
full-size model lowers to compact HLO.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.models.common.attention import sdpa
from repro.runtime.pspec import logical_constraint


class MMDiTConfig(NamedTuple):
    img_res: int = 128       # latent resolution
    in_ch: int = 4
    patch: int = 2
    n_double: int = 19
    n_single: int = 38
    d_model: int = 3072
    n_heads: int = 24
    mlp_ratio: float = 4.0
    txt_len: int = 256
    txt_dim: int = 768       # incoming text token dim (stub frontend)
    vec_dim: int = 512       # pooled conditioning (CLIP-ish)
    remat: bool = False
    use_pallas: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_img_tokens(self) -> int:
        return (self.img_res // self.patch) ** 2


def _init_stream(key, d, hidden, param_dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "mod": L.init_dense(k1, d, 6 * d, use_bias=True, param_dtype=param_dtype,
                            scale=0.0),
        "qkv": L.init_dense(k2, d, 3 * d, param_dtype=param_dtype),
        "proj": L.init_dense(k3, d, d, param_dtype=param_dtype),
        "mlp": L.init_mlp(k4, d, hidden, param_dtype=param_dtype),
        "q_norm": L.init_rmsnorm(d // 24 if d >= 24 else d, param_dtype),
        "k_norm": L.init_rmsnorm(d // 24 if d >= 24 else d, param_dtype),
    }


def _init_double(key, cfg: MMDiTConfig, param_dtype):
    ki, kt = jax.random.split(key)
    hidden = int(cfg.d_model * cfg.mlp_ratio)
    img = _init_stream(ki, cfg.d_model, hidden, param_dtype)
    txt = _init_stream(kt, cfg.d_model, hidden, param_dtype)
    # fix q/k norm dims to head_dim
    for s in (img, txt):
        s["q_norm"] = L.init_rmsnorm(cfg.head_dim, param_dtype)
        s["k_norm"] = L.init_rmsnorm(cfg.head_dim, param_dtype)
    return {"img": img, "txt": txt}


def _init_single(key, cfg: MMDiTConfig, param_dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    hidden = int(d * cfg.mlp_ratio)
    return {
        "mod": L.init_dense(k1, d, 3 * d, use_bias=True, param_dtype=param_dtype,
                            scale=0.0),
        # fused qkv+mlp_in / proj+mlp_out (flux single-block layout)
        "linear1": L.init_dense(k2, d, 3 * d + hidden, param_dtype=param_dtype),
        "linear2": L.init_dense(k3, d + hidden, d, param_dtype=param_dtype),
        "q_norm": L.init_rmsnorm(cfg.head_dim, param_dtype),
        "k_norm": L.init_rmsnorm(cfg.head_dim, param_dtype),
    }


def init_mmdit(key, cfg: MMDiTConfig, *, param_dtype=jnp.float32):
    keys = jax.random.split(key, 10)
    d = cfg.d_model
    patch_dim = cfg.patch * cfg.patch * cfg.in_ch
    dbl = jax.vmap(lambda k: _init_double(k, cfg, param_dtype))(
        jax.random.split(keys[0], cfg.n_double))
    sgl = jax.vmap(lambda k: _init_single(k, cfg, param_dtype))(
        jax.random.split(keys[1], cfg.n_single))
    return {
        "img_in": L.init_dense(keys[2], patch_dim, d, use_bias=True,
                               param_dtype=param_dtype),
        "txt_in": L.init_dense(keys[3], cfg.txt_dim, d, use_bias=True,
                               param_dtype=param_dtype),
        "time_mlp": L.init_mlp(keys[4], 256, d, out_dim=d, param_dtype=param_dtype),
        "vec_mlp": L.init_mlp(keys[5], cfg.vec_dim, d, out_dim=d,
                              param_dtype=param_dtype),
        "guidance_mlp": L.init_mlp(keys[6], 256, d, out_dim=d, param_dtype=param_dtype),
        "img_pos": L._normal(keys[7], (cfg.n_img_tokens, d), 0.02, param_dtype),
        "double": dbl,
        "single": sgl,
        "final_mod": L.init_dense(keys[8], d, 2 * d, use_bias=True,
                                  param_dtype=param_dtype, scale=0.0),
        "final_proj": {"w": jnp.zeros((d, patch_dim), param_dtype),
                       "b": jnp.zeros((patch_dim,), param_dtype)},
    }


def _stream_qkv(s, cfg, h, mod):
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
    hn = L.modulate(L.layernorm({}, h), sh1, sc1)
    b, t, d = hn.shape
    qkv = L.dense(s["qkv"], hn).reshape(b, t, 3, cfg.n_heads, cfg.head_dim)
    q = L.rmsnorm(s["q_norm"], qkv[:, :, 0])
    k = L.rmsnorm(s["k_norm"], qkv[:, :, 1])
    return q, k, qkv[:, :, 2], (sh2, sc2, g1, g2)


def _double_block(blk, cfg: MMDiTConfig, img, txt, cond):
    mod_i = L.dense(blk["img"]["mod"], jax.nn.silu(cond))
    mod_t = L.dense(blk["txt"]["mod"], jax.nn.silu(cond))
    qi, ki, vi, (shi, sci, gi1, gi2) = _stream_qkv(blk["img"], cfg, img, mod_i)
    qt, kt, vt, (sht, sct, gt1, gt2) = _stream_qkv(blk["txt"], cfg, txt, mod_t)
    # joint attention over [txt ; img]
    q = jnp.concatenate([qt, qi], axis=1)
    k = jnp.concatenate([kt, ki], axis=1)
    v = jnp.concatenate([vt, vi], axis=1)
    att = sdpa(q, k, v, causal=False, use_pallas=cfg.use_pallas)
    ta, ia = att[:, : txt.shape[1]], att[:, txt.shape[1]:]
    b = img.shape[0]
    img = img + gi1[:, None, :] * L.dense(blk["img"]["proj"],
                                          ia.reshape(b, -1, cfg.d_model))
    txt = txt + gt1[:, None, :] * L.dense(blk["txt"]["proj"],
                                          ta.reshape(b, -1, cfg.d_model))
    img = img + gi2[:, None, :] * L.mlp(blk["img"]["mlp"],
                                        L.modulate(L.layernorm({}, img), shi, sci))
    txt = txt + gt2[:, None, :] * L.mlp(blk["txt"]["mlp"],
                                        L.modulate(L.layernorm({}, txt), sht, sct))
    return img, txt


def _single_block(blk, cfg: MMDiTConfig, x, cond):
    mod = L.dense(blk["mod"], jax.nn.silu(cond))
    sh, sc, g = jnp.split(mod, 3, axis=-1)
    hn = L.modulate(L.layernorm({}, x), sh, sc)
    u = L.dense(blk["linear1"], hn)
    b, t, _ = u.shape
    d = cfg.d_model
    qkv, m = u[..., : 3 * d], u[..., 3 * d:]
    qkv = qkv.reshape(b, t, 3, cfg.n_heads, cfg.head_dim)
    q = L.rmsnorm(blk["q_norm"], qkv[:, :, 0])
    k = L.rmsnorm(blk["k_norm"], qkv[:, :, 1])
    att = sdpa(q, k, qkv[:, :, 2], causal=False, use_pallas=cfg.use_pallas)
    out = L.dense(blk["linear2"],
                  jnp.concatenate([att.reshape(b, t, d), jax.nn.gelu(m)], axis=-1))
    return x + g[:, None, :] * out


def apply_mmdit(p, cfg: MMDiTConfig, x_img, t, ctx):
    """Velocity prediction. x_img: (B, res, res, in_ch); t: (B,) in [0,1];
    ctx: dict(txt=(B, txt_len, txt_dim), vec=(B, vec_dim), guidance=(B,))."""
    b = x_img.shape[0]
    img = L.dense(p["img_in"], L.patchify(x_img, cfg.patch))
    img = img + p["img_pos"][None].astype(img.dtype)
    txt = L.dense(p["txt_in"], ctx["txt"].astype(img.dtype))
    cond = L.mlp(p["time_mlp"], L.timestep_embedding(t * 1000.0, 256).astype(img.dtype))
    cond = cond + L.mlp(p["vec_mlp"], ctx["vec"].astype(img.dtype))
    if "guidance" in ctx:
        cond = cond + L.mlp(p["guidance_mlp"],
                            L.timestep_embedding(ctx["guidance"], 256).astype(img.dtype))

    def dbl_body(carry, blk):
        im, tx = carry
        fn = _double_block
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(1,))
        im, tx = fn(blk, cfg, im, tx, cond)
        # block-boundary constraint: under sequence-parallel rules
        # ("seq" → model) the residual stream stays token-sharded between
        # blocks and the TP all-reduce decomposes into rs + ag (§Perf)
        im = logical_constraint(im, "batch", "seq", None)
        tx = logical_constraint(tx, "batch", "seq", None)
        return (im, tx), None

    (img, txt), _ = jax.lax.scan(dbl_body, (img, txt), p["double"])

    x = jnp.concatenate([txt, img], axis=1)

    def sgl_body(h, blk):
        fn = _single_block
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(1,))
        h = fn(blk, cfg, h, cond)
        return logical_constraint(h, "batch", "seq", None), None

    x, _ = jax.lax.scan(sgl_body, x, p["single"])
    img = x[:, txt.shape[1]:]

    sh, sc = jnp.split(L.dense(p["final_mod"], jax.nn.silu(cond)), 2, axis=-1)
    img = L.modulate(L.layernorm({}, img), sh, sc)
    img = L.dense(p["final_proj"], img)
    return L.unpatchify(img, cfg.patch, cfg.img_res, cfg.img_res, cfg.in_ch)


def make_v_fn(params, cfg: MMDiTConfig):
    def v_fn(x, t, ctx):
        return apply_mmdit(params, cfg, x, t, ctx)
    return v_fn
