"""DiT — Diffusion Transformer (Peebles & Xie, arXiv:2212.09748).

adaLN-Zero conditioning on (timestep ⊕ pooled text embedding), patchified
latent tokens, bidirectional attention.  Covers the assigned ``dit-b2``
(12L/768/12H) and ``dit-l2`` (24L/1024/16H) configs plus the tiny
reproduction model the CacheGenius benchmarks train on CPU.

Layers run under ``lax.scan`` over stacked parameters so the full-size
configs lower to compact HLO in the multi-pod dry-run; ``remat`` optionally
wraps the block for activation checkpointing.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.models.common.attention import sdpa


class DiTConfig(NamedTuple):
    img_res: int = 32          # latent resolution fed to the backbone
    in_ch: int = 4
    patch: int = 2
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    mlp_ratio: float = 4.0
    ctx_dim: int = 512         # pooled conditioning vector (text tower)
    remat: bool = False
    use_pallas: bool = False
    use_pallas_adaln: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_tokens(self) -> int:
        return (self.img_res // self.patch) ** 2


def _init_block(key, cfg: DiTConfig, param_dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d, h = cfg.d_model, int(cfg.d_model * cfg.mlp_ratio)
    return {
        "qkv": L.init_dense(k1, d, 3 * d, param_dtype=param_dtype),
        "proj": L.init_dense(k2, d, d, param_dtype=param_dtype),
        "mlp": L.init_mlp(k3, d, h, param_dtype=param_dtype),
        # adaLN-zero: 6 modulation vectors, zero-init projection (norms are
        # elementwise-affine-free, DiT style)
        "ada": {"w": jnp.zeros((d, 6 * d), param_dtype),
                "b": jnp.zeros((6 * d,), param_dtype)},
    }


def init_dit(key, cfg: DiTConfig, *, param_dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    patch_dim = cfg.patch * cfg.patch * cfg.in_ch
    # stacked per-layer params for lax.scan
    block_keys = jax.random.split(keys[0], cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, param_dtype))(block_keys)
    params = {
        "patch_embed": L.init_dense(keys[1], patch_dim, d, use_bias=True,
                                    param_dtype=param_dtype),
        "pos_embed": L._normal(keys[2], (cfg.n_tokens, d), 0.02, param_dtype),
        "t_mlp": L.init_mlp(keys[3], 256, d, out_dim=d, param_dtype=param_dtype),
        "ctx_proj": L.init_dense(keys[4], cfg.ctx_dim, d, use_bias=True,
                                 param_dtype=param_dtype),
        "blocks": blocks,
        "final_norm": {},
        "final_ada": {"w": jnp.zeros((d, 2 * d), param_dtype),
                      "b": jnp.zeros((2 * d,), param_dtype)},
        "final_proj": {"w": jnp.zeros((d, patch_dim), param_dtype),
                       "b": jnp.zeros((patch_dim,), param_dtype)},
    }
    return params


def _block_apply(p, cfg: DiTConfig, x, cond):
    """One DiT block. x: (B, T, D); cond: (B, D)."""
    ada = L.dense(p["ada"], jax.nn.silu(cond))
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
    if cfg.use_pallas_adaln:
        from repro.kernels import ops as kops
        h = kops.adaln_modulate(x, sh1, sc1)
    else:
        h = L.modulate(L.layernorm({}, x), sh1, sc1)
    b, t, d = h.shape
    qkv = L.dense(p["qkv"], h).reshape(b, t, 3, cfg.n_heads, cfg.head_dim)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    att = sdpa(q, k, v, causal=False, use_pallas=cfg.use_pallas)
    att = L.dense(p["proj"], att.reshape(b, t, d))
    x = x + g1[:, None, :] * att
    if cfg.use_pallas_adaln:
        from repro.kernels import ops as kops
        h2 = kops.adaln_modulate(x, sh2, sc2)
    else:
        h2 = L.modulate(L.layernorm({}, x), sh2, sc2)
    x = x + g2[:, None, :] * L.mlp(p["mlp"], h2)
    return x


def apply_dit(params, cfg: DiTConfig, x_img, t, ctx):
    """eps-prediction forward.

    x_img: (B, res, res, in_ch) latent; t: (B,) int/float timesteps;
    ctx: (B, ctx_dim) pooled conditioning. Returns eps of x_img's shape.
    """
    b = x_img.shape[0]
    x = L.patchify(x_img, cfg.patch)
    x = L.dense(params["patch_embed"], x) + params["pos_embed"][None].astype(x.dtype)
    t_emb = L.timestep_embedding(t, 256).astype(x.dtype)
    cond = L.mlp(params["t_mlp"], t_emb) + L.dense(params["ctx_proj"], ctx.astype(x.dtype))

    def body(h, block):
        fn = _block_apply
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(1,))
        return fn(block, cfg, h, cond), None

    x, _ = jax.lax.scan(body, x, params["blocks"])

    ada = L.dense(params["final_ada"], jax.nn.silu(cond))
    shift, scale = jnp.split(ada, 2, axis=-1)
    x = L.modulate(L.layernorm({}, x), shift, scale)
    x = L.dense(params["final_proj"], x)
    return L.unpatchify(x, cfg.patch, cfg.img_res, cfg.img_res, cfg.in_ch)


def make_eps_fn(params, cfg: DiTConfig):
    def eps_fn(x, t, ctx):
        return apply_dit(params, cfg, x, t, ctx)
    return eps_fn
