"""Small CLIP-style text encoder used for diffusion conditioning and the
trainable dual-tower embedder.  Bidirectional transformer over hash-token
ids → per-token context (for cross-attention) + pooled vector."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import layers as L
from repro.models.common.attention import sdpa


class TextEncoderConfig(NamedTuple):
    vocab: int = 32768
    max_len: int = 77
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    out_dim: int = 768       # ctx token dim handed to the diffusion backbone
    pool_dim: int = 512      # pooled embedding dim

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


def _init_block(key, cfg, param_dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": L.init_layernorm(d, param_dtype),
        "qkv": L.init_dense(k1, d, 3 * d, param_dtype=param_dtype),
        "proj": L.init_dense(k2, d, d, param_dtype=param_dtype),
        "ln2": L.init_layernorm(d, param_dtype),
        "mlp": L.init_mlp(k3, d, 4 * d, param_dtype=param_dtype),
    }


def init_text_encoder(key, cfg: TextEncoderConfig, *, param_dtype=jnp.float32):
    keys = jax.random.split(key, 6)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, param_dtype))(
        jax.random.split(keys[0], cfg.n_layers))
    return {
        "embed": L._normal(keys[1], (cfg.vocab, cfg.d_model), 0.02, param_dtype),
        "pos": L._normal(keys[2], (cfg.max_len, cfg.d_model), 0.02, param_dtype),
        "blocks": blocks,
        "ln_f": L.init_layernorm(cfg.d_model, param_dtype),
        "to_ctx": L.init_dense(keys[3], cfg.d_model, cfg.out_dim,
                               param_dtype=param_dtype),
        "to_pool": L.init_dense(keys[4], cfg.d_model, cfg.pool_dim,
                                param_dtype=param_dtype),
    }


def apply_text_encoder(p, cfg: TextEncoderConfig, tokens):
    """tokens: (B, S) -> (ctx (B, S, out_dim), pooled (B, pool_dim))."""
    mask = (tokens != 0).astype(jnp.float32)
    x = jnp.take(p["embed"], tokens, axis=0) + p["pos"][None, : tokens.shape[1]]

    def body(h, blk):
        hn = L.layernorm(blk["ln1"], h)
        b, s, d = hn.shape
        qkv = L.dense(blk["qkv"], hn).reshape(b, s, 3, cfg.n_heads, cfg.head_dim)
        att = sdpa(qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2], causal=False)
        h = h + L.dense(blk["proj"], att.reshape(b, s, d))
        h = h + L.mlp(blk["mlp"], L.layernorm(blk["ln2"], h))
        return h, None

    x, _ = jax.lax.scan(body, x, p["blocks"])
    x = L.layernorm(p["ln_f"], x)
    ctx = L.dense(p["to_ctx"], x)
    denom = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
    pooled = jnp.einsum("bsd,bs->bd", x, mask) / denom
    pooled = L.dense(p["to_pool"], pooled)
    return ctx, pooled
