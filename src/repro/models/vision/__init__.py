"""Vision family: ConvNeXt and EfficientNet classifiers."""
from repro.models.vision.convnext import ConvNeXtConfig, init_convnext, apply_convnext  # noqa: F401
from repro.models.vision.efficientnet import EffNetConfig, init_effnet, apply_effnet  # noqa: F401
