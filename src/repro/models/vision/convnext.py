"""ConvNeXt (Liu et al., arXiv:2201.03545).

Assigned config convnext-b: depths (3,3,27,3), dims (128,256,512,1024).
Patchify stem (4×4 s4), blocks = 7×7 depthwise conv → LN → 4× pointwise
MLP with GELU → layer-scale → residual; LN+2×2 s2 downsample between
stages; global-average-pool head.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.models.common import layers as L


class ConvNeXtConfig(NamedTuple):
    depths: Sequence[int] = (3, 3, 27, 3)
    dims: Sequence[int] = (128, 256, 512, 1024)
    n_classes: int = 1000
    layer_scale_init: float = 1e-6
    remat: bool = False


def _init_block(key, dim, cfg, param_dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "dwconv": L.init_conv(k1, dim, dim, 7, param_dtype=param_dtype,
                              feature_group_count=dim),
        "norm": L.init_layernorm(dim, param_dtype),
        "pw1": L.init_dense(k2, dim, 4 * dim, use_bias=True, param_dtype=param_dtype),
        "pw2": L.init_dense(k3, 4 * dim, dim, use_bias=True, param_dtype=param_dtype),
        "gamma": jnp.full((dim,), cfg.layer_scale_init, param_dtype),
    }


def _block(p, x, dim):
    h = L.conv(p["dwconv"], x, feature_group_count=dim)
    h = L.layernorm(p["norm"], h)
    h = L.dense(p["pw2"], jax.nn.gelu(L.dense(p["pw1"], h)))
    return x + p["gamma"].astype(x.dtype) * h


def init_convnext(key, cfg: ConvNeXtConfig, *, param_dtype=jnp.float32):
    keys = iter(jax.random.split(key, 16))
    p = {
        "stem": L.init_conv(next(keys), 3, cfg.dims[0], 4, param_dtype=param_dtype),
        "stem_norm": L.init_layernorm(cfg.dims[0], param_dtype),
    }
    for si, (depth, dim) in enumerate(zip(cfg.depths, cfg.dims)):
        bkeys = jax.random.split(next(keys), depth)
        p[f"stage{si}"] = jax.vmap(
            lambda k: _init_block(k, dim, cfg, param_dtype))(bkeys)
        if si < len(cfg.dims) - 1:
            p[f"down{si}"] = {
                "norm": L.init_layernorm(dim, param_dtype),
                "conv": L.init_conv(next(keys), dim, cfg.dims[si + 1], 2,
                                    param_dtype=param_dtype),
            }
    p["head_norm"] = L.init_layernorm(cfg.dims[-1], param_dtype)
    p["head"] = L.init_dense(next(keys), cfg.dims[-1], cfg.n_classes,
                             use_bias=True, param_dtype=param_dtype)
    return p


def apply_convnext(p, cfg: ConvNeXtConfig, x):
    """x: (B, H, W, 3) -> logits (B, n_classes)."""
    h = L.conv(p["stem"], x, stride=4, padding="VALID")
    h = L.layernorm(p["stem_norm"], h)
    for si, (depth, dim) in enumerate(zip(cfg.depths, cfg.dims)):

        def body(hh, bp, dim=dim):
            fn = _block
            if cfg.remat:
                fn = jax.checkpoint(fn, static_argnums=(2,))
            return fn(bp, hh, dim), None

        h, _ = jax.lax.scan(body, h, p[f"stage{si}"])
        if si < len(cfg.dims) - 1:
            d = p[f"down{si}"]
            h = L.conv(d["conv"], L.layernorm(d["norm"], h), stride=2,
                       padding="VALID")
    h = jnp.mean(h, axis=(1, 2))
    h = L.layernorm(p["head_norm"], h)
    return L.dense(p["head"], h)
