"""EfficientNet (Tan & Le, arXiv:1905.11946) with compound scaling.

Assigned config efficientnet-b7: width_mult=2.0, depth_mult=3.1 applied to
the B0 block table; MBConv blocks with expansion, depthwise conv,
squeeze-and-excitation, swish activations.

Normalisation note (DESIGN.md §8): canonical EfficientNet uses BatchNorm
with running statistics; we normalise with *batch* statistics in both
train and serve steps (the compute/roofline-relevant part is identical,
and the framework stays purely functional).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import layers as L

# B0 table: (expand_ratio, channels, repeats, stride, kernel)
_B0_BLOCKS: Tuple[Tuple[int, int, int, int, int], ...] = (
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
)


class EffNetConfig(NamedTuple):
    width_mult: float = 2.0
    depth_mult: float = 3.1
    n_classes: int = 1000
    se_ratio: float = 0.25
    stem_ch: int = 32
    head_ch: int = 1280
    remat: bool = False

    def round_ch(self, ch: float) -> int:
        ch *= self.width_mult
        new = max(8, int(ch + 4) // 8 * 8)
        if new < 0.9 * ch:
            new += 8
        return new

    def round_repeats(self, r: int) -> int:
        return int(math.ceil(self.depth_mult * r))

    def blocks(self):
        for expand, ch, rep, stride, kernel in _B0_BLOCKS:
            yield expand, self.round_ch(ch), self.round_repeats(rep), stride, kernel


def _init_bn(ch, param_dtype):
    return {"scale": jnp.ones((ch,), param_dtype), "bias": jnp.zeros((ch,), param_dtype)}


def _bn(p, x, *, eps=1e-3):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x32, axis=(0, 1, 2), keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def _init_mbconv(key, in_ch, out_ch, expand, kernel, se_ratio, param_dtype):
    ks = iter(jax.random.split(key, 8))
    mid = in_ch * expand
    p = {}
    if expand != 1:
        p["expand_conv"] = L.init_conv(next(ks), in_ch, mid, 1, use_bias=False,
                                       param_dtype=param_dtype)
        p["expand_bn"] = _init_bn(mid, param_dtype)
    p["dw_conv"] = L.init_conv(next(ks), mid, mid, kernel, use_bias=False,
                               param_dtype=param_dtype, feature_group_count=mid)
    p["dw_bn"] = _init_bn(mid, param_dtype)
    se_ch = max(1, int(in_ch * se_ratio))
    p["se_reduce"] = L.init_conv(next(ks), mid, se_ch, 1, param_dtype=param_dtype)
    p["se_expand"] = L.init_conv(next(ks), se_ch, mid, 1, param_dtype=param_dtype)
    p["project_conv"] = L.init_conv(next(ks), mid, out_ch, 1, use_bias=False,
                                    param_dtype=param_dtype)
    p["project_bn"] = _init_bn(out_ch, param_dtype)
    return p


def _mbconv(p, x, *, stride, expand, kernel):
    h = x
    mid_groups = None
    if expand != 1:
        h = jax.nn.silu(_bn(p["expand_bn"], L.conv(p["expand_conv"], h)))
    mid = h.shape[-1]
    h = L.conv(p["dw_conv"], h, stride=stride, feature_group_count=mid)
    h = jax.nn.silu(_bn(p["dw_bn"], h))
    # squeeze & excitation
    s = jnp.mean(h, axis=(1, 2), keepdims=True)
    s = jax.nn.silu(L.conv(p["se_reduce"], s))
    s = jax.nn.sigmoid(L.conv(p["se_expand"], s))
    h = h * s
    h = _bn(p["project_bn"], L.conv(p["project_conv"], h))
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    del mid_groups
    return h


def init_effnet(key, cfg: EffNetConfig, *, param_dtype=jnp.float32):
    keys = iter(jax.random.split(key, 128))
    stem_ch = cfg.round_ch(cfg.stem_ch / cfg.width_mult * cfg.width_mult) \
        if False else cfg.round_ch(cfg.stem_ch)
    p = {
        "stem_conv": L.init_conv(next(keys), 3, stem_ch, 3, use_bias=False,
                                 param_dtype=param_dtype),
        "stem_bn": _init_bn(stem_ch, param_dtype),
    }
    in_ch = stem_ch
    for bi, (expand, out_ch, repeats, stride, kernel) in enumerate(cfg.blocks()):
        for ri in range(repeats):
            p[f"block{bi}_{ri}"] = _init_mbconv(
                next(keys), in_ch, out_ch, expand, kernel, cfg.se_ratio,
                param_dtype)
            in_ch = out_ch
    head_ch = cfg.round_ch(cfg.head_ch)
    p["head_conv"] = L.init_conv(next(keys), in_ch, head_ch, 1, use_bias=False,
                                 param_dtype=param_dtype)
    p["head_bn"] = _init_bn(head_ch, param_dtype)
    p["fc"] = L.init_dense(next(keys), head_ch, cfg.n_classes, use_bias=True,
                           param_dtype=param_dtype)
    return p


def apply_effnet(p, cfg: EffNetConfig, x):
    """x: (B, H, W, 3) -> logits (B, n_classes)."""
    h = jax.nn.silu(_bn(p["stem_bn"], L.conv(p["stem_conv"], x, stride=2)))
    for bi, (expand, out_ch, repeats, stride, kernel) in enumerate(cfg.blocks()):
        for ri in range(repeats):
            s = stride if ri == 0 else 1
            fn = _mbconv
            if cfg.remat:
                fn = jax.checkpoint(
                    lambda pp, hh, s=s, expand=expand, kernel=kernel:
                    _mbconv(pp, hh, stride=s, expand=expand, kernel=kernel))
                h = fn(p[f"block{bi}_{ri}"], h)
                continue
            h = fn(p[f"block{bi}_{ri}"], h, stride=s, expand=expand, kernel=kernel)
    h = jax.nn.silu(_bn(p["head_bn"], L.conv(p["head_conv"], h)))
    h = jnp.mean(h, axis=(1, 2))
    return L.dense(p["fc"], h)
