"""PR 5: score-aware scheduling on the cluster-wide fused scan.

Pins the new contracts:

* the per-node cluster scan (``vdb_topk_pernode`` kernel, its jnp ref,
  and ``ClusterIndex.search_cluster_nodes``) matches the per-node masked
  oracle for every (query, node) pair;
* in score mode, Schedule+Retrieve issue exactly ONE fused device scan
  per micro-batch and the per-node ``VectorDB`` path never runs;
* score routing == centroid routing when every node holds an identical
  cache (routing mode is then irrelevant by symmetry);
* score routing beats centroid routing on cache hit-rate when content
  placement is skewed in a way node centroids cannot see;
* ``RequestScheduler.schedule_batch(node_scores=...)`` blends best-match
  score, load, and the latency model, and keeps the fast paths.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cluster_index import ClusterIndex
from repro.core.embeddings import ProxyClipEmbedder
from repro.core.latency_model import LatencyModel
from repro.core.policy import GenerationPolicy
from repro.core.scheduler import NodeInfo, RequestScheduler
from repro.core.system import CacheGenius
from repro.core.vdb import BlobStore, VectorDB
from repro.data.synthetic import make_corpus, render_caption
from repro.kernels.ref import vdb_topk_pernode_ref
from repro.kernels.vdb_topk import NEG_INF, vdb_topk_pernode
from repro.launch.serve import NullBackend, build_system


def _unit(rng, n, d):
    v = rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def _mixed_fleet(rng, dim=24):
    """Same node mix the PR-4 fused-scan suite uses: empty, partial,
    full, overfull (FIFO overwrite), non-uniform capacities."""
    dbs = [VectorDB(dim, 32, name="empty"),
           VectorDB(dim, 32, name="partial"),
           VectorDB(dim, 16, name="full"),
           VectorDB(dim, 48, name="overfull")]
    dbs[1].add(_unit(rng, 10, dim), _unit(rng, 10, dim), np.arange(10), 0.0)
    dbs[2].add(_unit(rng, 16, dim), _unit(rng, 16, dim), np.arange(16), 0.0)
    dbs[3].add(_unit(rng, 60, dim), _unit(rng, 60, dim), np.arange(60), 0.0)
    return dbs


# ---------------------------------------------------------------------------
# per-node scan: kernel vs ref vs per-node oracle
# ---------------------------------------------------------------------------


def test_pernode_kernel_matches_ref():
    rng = np.random.default_rng(0)
    slabs = rng.normal(size=(2, 3, 40, 16)).astype(np.float32)
    valid = rng.random((3, 40)) > 0.3
    Q = _unit(rng, 4, 16)
    s_ref, i_ref = vdb_topk_pernode_ref(
        jnp.asarray(Q), jnp.asarray(slabs), jnp.asarray(valid), 5)
    s_k, i_k = vdb_topk_pernode(
        jnp.asarray(Q), jnp.asarray(slabs), jnp.asarray(valid), 5,
        interpret=True)
    s_ref, s_k = np.asarray(s_ref), np.asarray(s_k)
    assert s_ref.shape == s_k.shape == (2, 3, 4, 5)
    # ref masks with -inf, the kernel with the NEG_INF sentinel
    fin_ref = np.isfinite(s_ref)
    np.testing.assert_array_equal(fin_ref, s_k > NEG_INF / 2)
    np.testing.assert_allclose(s_ref[fin_ref], s_k[fin_ref],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_ref)[fin_ref],
                                  np.asarray(i_k)[fin_ref])


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("index", ["both", "img", "txt"])
def test_search_cluster_nodes_matches_per_node_oracle(index, use_pallas):
    """out[q][n] must be bit-identical to what a masked per-node scan on
    node n would return — that is what lets the Retrieve stage reuse the
    scheduling scan's rows without changing any route."""
    rng = np.random.default_rng(1)
    dbs = _mixed_fleet(rng)
    Q = _unit(rng, 5, 24)
    # oracle rows from the standalone per-node path, BEFORE attaching
    oracle = [[db.search_batch(q[None], 6, index=index)[0] for db in dbs]
              for q in Q]
    ci = ClusterIndex.from_dbs(dbs, use_pallas=use_pallas,
                               interpret=True if use_pallas else None)
    rows = ci.search_cluster_nodes(Q, 6, index=index)
    assert len(rows) == 5 and all(len(r) == len(dbs) for r in rows)
    for q_oracle, q_rows in zip(oracle, rows):
        for (o_s, o_l), (f_s, f_l) in zip(q_oracle, q_rows):
            np.testing.assert_array_equal(o_l, f_l)
            np.testing.assert_allclose(o_s, f_s, rtol=1e-4, atol=1e-5)


def test_search_cluster_nodes_counts_one_fused_scan():
    rng = np.random.default_rng(2)
    ci = ClusterIndex.from_dbs(_mixed_fleet(rng))
    before = ci.stats["fused_scans"]
    ci.search_cluster_nodes(_unit(rng, 3, 24), 4)
    assert ci.stats["fused_scans"] == before + 1


# ---------------------------------------------------------------------------
# the acceptance gate: ONE fused scan for Schedule+Retrieve in score mode
# ---------------------------------------------------------------------------


def _prompts(n, seed=0):
    from repro.core.trace import RequestTrace
    return [r.prompt for r in RequestTrace(seed=seed).generate(n)]


def test_score_mode_schedule_plus_retrieve_is_one_scan(monkeypatch):
    system, _, _, _ = build_system(n_nodes=3, corpus_n=90,
                                   capacity_per_node=60)   # routing="score"
    ci = system.cluster_index
    assert system.routing == "score" and ci is not None
    calls = []
    orig = ci.search_cluster_nodes
    monkeypatch.setattr(ci, "search_cluster_nodes",
                        lambda *a, **kw: calls.append(a) or orig(*a, **kw))
    # neither the masked cluster scan nor the per-node path may run
    monkeypatch.setattr(
        ci, "search_batch",
        lambda *a, **kw: pytest.fail("masked Retrieve scan in score mode"))
    monkeypatch.setattr(
        VectorDB, "search_batch",
        lambda self, *a, **kw: pytest.fail("per-node search on serve path"))
    scans_before = ci.stats["fused_scans"]
    results = system.serve_batch(_prompts(8), seeds=list(range(8)))
    assert len(results) == 8
    assert len(calls) == 1                       # ONE schedule-stage call...
    assert ci.stats["fused_scans"] == scans_before + 1   # ...and ONE scan
    # the decisions actually carry per-node best-match routing
    assert any(r.score > 0 for r in results)


def test_score_mode_steady_state_has_zero_slab_uploads():
    system, _, _, _ = build_system(n_nodes=3, corpus_n=90,
                                   capacity_per_node=60)
    ci = system.cluster_index
    prompts = _prompts(24, seed=3)
    system.serve_batch(prompts[:8], seeds=list(range(8)))          # warmup
    uploads = ci.stats["slab_uploads"]
    scans = ci.stats["fused_scans"]
    for lo in (8, 16):
        system.serve_batch(prompts[lo:lo + 8],
                           seeds=list(range(lo, lo + 8)))
    assert ci.stats["slab_uploads"] == uploads   # ZERO steady-state uploads
    assert ci.stats["fused_scans"] == scans + 2  # one per micro-batch
    assert ci.stats["row_updates"] > 0           # archives flowed as rows


def test_score_mode_scores_each_request_once_at_schedule():
    """Score mode's scoring budget: EXACTLY one vectorised
    ``score_candidates`` call per request, at schedule time (its routing
    input, coalesced requests included — routing happens before
    coalescing is knowable).  The Score stage reuses the schedule-time
    argmax for the chosen node and never re-scores."""
    system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                   capacity_per_node=80, seed=0)
    calls = {"n": 0}
    orig = system.embedder.score_candidates

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    system.embedder.score_candidates = counting
    states = []
    reqs = _prompts(40, seed=1)
    for i in range(0, 40, 8):
        states.extend(system.pipeline.run(
            system, reqs[i:i + 8], seeds=list(range(i, i + 8))))
    # routing input is computed before fast paths are known, so every
    # request pays exactly one schedule-time call (warm caches: every
    # node row is non-empty) — and nothing else on the serve path scores
    assert calls["n"] == len(states)
    # the retrieval-path plans really did carry composite scores
    scored = [s for s in states
              if s.plan.kind in ("cached", "gen") and s.plan.fast is None]
    assert scored and all(s.best_slot >= 0 for s in scored)
    assert all(s.score_thunk is None for s in states)


# ---------------------------------------------------------------------------
# routing parity and routing quality
# ---------------------------------------------------------------------------


def _fleet_system(*, routing, placement, n_nodes=3, corpus_n=90,
                  capacity=90, node_speeds=None, seed=0):
    """CacheGenius over a hand-placed fleet: ``placement(node) -> corpus
    row indices`` controls exactly which node caches what."""
    images, captions, _ = make_corpus(corpus_n, res=32, seed=seed)
    embedder = ProxyClipEmbedder(render_caption)
    img_vecs = embedder.embed_image(images)
    txt_vecs = embedder.embed_text(captions)
    embedder.set_corpus_anchor(img_vecs)
    blob = BlobStore()
    payloads = np.array([blob.put(im) for im in images], np.int64)
    dbs = [VectorDB(embedder.dim, capacity, name=f"node{i}")
           for i in range(n_nodes)]
    for node in range(n_nodes):
        idxs = np.asarray(placement(node))
        dbs[node].add(img_vecs[idxs], txt_vecs[idxs], payloads[idxs], t=0.0)
    system = CacheGenius(
        embedder=embedder, dbs=dbs, blob_store=blob,
        backend=NullBackend(32), node_speeds=node_speeds, routing=routing)
    return system, captions


def test_score_equals_centroid_when_all_nodes_hold_identical_caches():
    """With every node caching the SAME entries (and equal speeds) the
    routing mode is irrelevant by symmetry: score and centroid modes must
    pick the same nodes, routes, and images."""
    def run(routing):
        system, _ = _fleet_system(
            routing=routing, placement=lambda node: np.arange(60),
            corpus_n=60, capacity=60)
        out = system.serve_batch(_prompts(32, seed=2),
                                 seeds=list(range(32)))
        return system, out

    s_score, r_score = run("score")
    s_cent, r_cent = run("centroid")
    for a, b in zip(r_score, r_cent):
        assert (a.fast_path or a.route.value) == (b.fast_path or b.route.value)
        assert a.node == b.node
        assert a.steps == b.steps
        np.testing.assert_array_equal(a.image, b.image)
    assert s_score.stats.route_counts == s_cent.stats.route_counts
    assert s_score.stats.hit_rate == pytest.approx(s_cent.stats.hit_rate)
    for db_a, db_b in zip(s_score.dbs, s_cent.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)


def test_score_routing_beats_centroid_on_skewed_caches():
    """The skewed-cache trace (acceptance gate): corpus rows are shuffled
    round-robin across nodes, so every node's centroid is ~the global
    mean (centroid routing is blind) while each prompt's best reference
    lives on exactly one node.  Score routing must find it — strictly
    higher cache hit-rate."""
    rng = np.random.default_rng(7)
    corpus_n, n_nodes = 90, 3
    perm = rng.permutation(corpus_n)
    order = rng.permutation(corpus_n)

    def run(routing):
        system, captions = _fleet_system(
            routing=routing,
            placement=lambda node: perm[node::n_nodes],
            corpus_n=corpus_n, capacity=corpus_n)
        # each cached scene requested once, in a shuffled order — every
        # prompt has a perfect reference SOMEWHERE, on one node only
        prompts = [captions[i] for i in order]
        for i in range(0, corpus_n, 8):
            system.serve_batch(prompts[i:i + 8],
                               seeds=list(range(i, i + 8)))
        return system

    sys_score = run("score")
    sys_cent = run("centroid")
    assert sys_score.stats.requests == sys_cent.stats.requests
    assert sys_score.stats.hit_rate > sys_cent.stats.hit_rate
    # score mode should serve essentially every request from cache
    assert sys_score.stats.hit_rate > 0.9


def test_centroid_is_the_no_cluster_fallback():
    """routing='score' without a cluster index degrades to the centroid
    path (and the per-node retrieval loop) instead of failing."""
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60)
    system.cluster_index = None
    ref, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                capacity_per_node=60, routing="centroid")
    ref.cluster_index = None
    prompts = _prompts(12, seed=4)
    a = [system.serve(p, seed=i) for i, p in enumerate(prompts)]
    b = [ref.serve(p, seed=i) for i, p in enumerate(prompts)]
    for ra, rb in zip(a, b):
        assert ra.node == rb.node
        assert (ra.fast_path or ra.route.value) == \
            (rb.fast_path or rb.route.value)


def test_routing_arg_is_validated():
    with pytest.raises(ValueError):
        build_system(n_nodes=2, corpus_n=40, capacity_per_node=40,
                     routing="round-robin")


# ---------------------------------------------------------------------------
# RequestScheduler.schedule_batch(node_scores=...) unit behaviour
# ---------------------------------------------------------------------------


def _sched(speeds=(1.0, 1.0, 1.0), **kw):
    return RequestScheduler(
        nodes=[NodeInfo(i, speed=s) for i, s in enumerate(speeds)], **kw)


def _empty_dbs(n=3, dim=8):
    return [VectorDB(dim, 4) for _ in range(n)]


def test_node_scores_dominate_routing():
    sched = _sched()
    vec = np.ones((1, 8), np.float32)
    scores = np.array([[0.1, 0.8, 0.3]])
    (d,) = sched.schedule_batch(vec, _empty_dbs(), node_scores=scores)
    assert d.node == 1
    assert d.match_score == pytest.approx(0.8)   # best composite, not util


def test_node_scores_skip_dead_nodes():
    sched = _sched()
    sched.mark_failed(1)
    scores = np.array([[0.1, 0.9, 0.3]])
    (d,) = sched.schedule_batch(np.ones((1, 8), np.float32), _empty_dbs(),
                                node_scores=scores)
    assert d.node == 2


def test_node_scores_load_penalty_breaks_ties():
    sched = _sched()
    sched.nodes[0].queue_depth = 5
    scores = np.array([[0.5, 0.5, 0.5]])
    (d,) = sched.schedule_batch(np.ones((1, 8), np.float32), _empty_dbs(),
                                node_scores=scores)
    assert d.node == 1                           # 0 is loaded, 1 beats 2 ties


def test_latency_model_prefers_fast_nodes_on_score_ties():
    sched = _sched(speeds=(0.45, 1.0, 0.82))
    sched.policy = GenerationPolicy()
    sched.latency_model = LatencyModel()
    scores = np.array([[0.2, 0.2, 0.2]])        # miss everywhere: full gen
    (d,) = sched.schedule_batch(np.ones((1, 8), np.float32), _empty_dbs(),
                                node_scores=scores)
    assert d.node == 1                           # cheapest expected latency
    # a real hit outweighs the latency edge of a faster node
    scores = np.array([[0.9, 0.2, 0.2]])
    (d,) = sched.schedule_batch(np.ones((1, 8), np.float32), _empty_dbs(),
                                node_scores=scores)
    assert d.node == 0


def test_fast_paths_survive_score_mode():
    sched = _sched()
    vec = np.ones((512,), np.float32) / np.sqrt(512.0)  # history dim = 512
    sched.record_result(vec, payload_id=42)
    scores = np.zeros((3, 3))
    ds = sched.schedule_batch(
        np.stack([vec, vec * 0.99, -vec]), _empty_dbs(dim=512),
        quality_tiers=[False, False, True],
        prompt_keys=[1, 1, 2], node_scores=scores)
    assert ds[0].fast_path == "history"
    assert ds[0].history_payload == 42
    assert ds[1].fast_path == "history"          # near-duplicate
    assert ds[2].fast_path is None               # tier but first occurrence
