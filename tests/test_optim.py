"""Optimizer stack: AdamW, Adafactor, schedules, int8 gradient compression
(hypothesis property: error feedback keeps the quantisation unbiased)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.optim.adafactor import (AdafactorConfig, adafactor_init,
                                   adafactor_update)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import (compress_grads, compression_init,
                                     decompress_grads)
from repro.optim.schedule import cosine_schedule, linear_warmup


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.array(0.5),
            "m": jnp.ones((256, 8)) * 2.0}


def _loss(p):
    return (jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])
            + jnp.sum(jnp.square(p["m"])) / p["m"].size)


def test_adamw_descends_quadratic():
    p = _quadratic_params()
    st_ = adamw_init(p)
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0)
    l0 = float(_loss(p))
    for _ in range(60):
        g = jax.grad(_loss)(p)
        p, st_, m = adamw_update(g, st_, p, cfg)
    assert float(_loss(p)) < 0.2 * l0
    assert np.isfinite(float(m["grad_norm"]))


def test_adafactor_descends_quadratic():
    p = _quadratic_params()
    cfg = AdafactorConfig(lr=0.3)
    st_ = adafactor_init(p, cfg)
    l0 = float(_loss(p))
    for _ in range(80):
        g = jax.grad(_loss)(p)
        p, st_, _ = adafactor_update(g, st_, p, cfg)
    assert float(_loss(p)) < 0.3 * l0


def test_adafactor_factors_large_matrices():
    cfg = AdafactorConfig(min_dim_size_to_factor=4)
    p = {"big": jnp.zeros((8, 16)), "vec": jnp.zeros((8,))}
    st_ = adafactor_init(p, cfg)
    from repro.optim.adafactor import _FactoredMoment
    assert isinstance(st_.v["big"], _FactoredMoment)
    assert st_.v["big"].row.shape == (8,)
    assert st_.v["big"].col.shape == (16,)
    assert st_.v["vec"].shape == (8,)          # too small → full moment


def test_adafactor_memory_is_sublinear():
    """The point of Adafactor at 400B: moment bytes ≪ 2×param bytes."""
    cfg = AdafactorConfig()
    p = {"w": jnp.zeros((512, 2048))}
    st_ = adafactor_init(p, cfg)
    moment_elems = sum(x.size for x in jax.tree_util.tree_leaves(st_.v))
    assert moment_elems < 0.01 * p["w"].size


def test_grad_clipping_bounds_update():
    p = {"w": jnp.array([1.0])}
    st_ = adamw_init(p)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    g = {"w": jnp.array([1e6])}
    p2, _, m = adamw_update(g, st_, p, cfg)
    assert float(m["grad_norm"]) == pytest.approx(1e6)
    assert abs(float(p2["w"][0] - p["w"][0])) < 10.0   # clipped step


def test_schedules():
    assert float(linear_warmup(0, 10)) == pytest.approx(0.1)
    assert float(linear_warmup(99, 10)) == 1.0
    s0 = float(cosine_schedule(0, total_steps=100, warmup_steps=10))
    s_mid = float(cosine_schedule(50, total_steps=100, warmup_steps=10))
    s_end = float(cosine_schedule(100, total_steps=100, warmup_steps=10,
                                  final_frac=0.1))
    assert s0 < s_mid < 1.01
    assert s_end == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------


def test_compression_roundtrip_error_bounded():
    g = {"w": jnp.linspace(-3, 3, 64).reshape(8, 8)}
    st_ = compression_init(g)
    q, scales, st_ = compress_grads(g, st_)
    back = decompress_grads(q, scales)
    err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    assert err <= float(scales["w"]) * 0.5 + 1e-7
    assert q["w"].dtype == jnp.int8


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100), steps=st.integers(2, 12))
def test_error_feedback_mean_converges(seed, steps):
    """Property: with a CONSTANT gradient, error feedback makes the running
    mean of dequantised gradients converge to the true gradient (the
    carried residual corrects the bias)."""
    rng = np.random.default_rng(seed)
    g_true = {"w": jnp.asarray(rng.normal(size=(16,)).astype(np.float32))}
    st_ = compression_init(g_true)
    total = jnp.zeros((16,))
    for _ in range(steps):
        q, s, st_ = compress_grads(g_true, st_)
        total = total + decompress_grads(q, s)["w"]
    mean_err = float(jnp.max(jnp.abs(total / steps - g_true["w"])))
    one_shot_scale = float(jnp.max(jnp.abs(g_true["w"]))) / 127.0
    assert mean_err <= one_shot_scale * (1.0 / steps) + 1e-6


def test_optimizers_match_shapes_with_tree_structure():
    """Moments mirror the parameter tree exactly (checkpoint contract)."""
    p = {"a": {"b": jnp.zeros((3, 3))}, "c": jnp.zeros((2,))}
    s1 = adamw_init(p)
    assert jax.tree_util.tree_structure(s1.m) == \
        jax.tree_util.tree_structure(p)
    s2 = adafactor_init(p)
    assert set(jax.tree_util.tree_leaves(s2.v)[0].shape) <= {2, 3}
