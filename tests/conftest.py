"""Shared fixtures: tiny synthetic corpus, proxy embedder, node VDB fleet.

NOTE: no XLA_FLAGS here — tests run on the single real CPU device; only
``repro.launch.dryrun`` (never imported by tests) forces 512 devices.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.embeddings import ProxyClipEmbedder
from repro.core.storage_classifier import StorageClassifier
from repro.core.vdb import BlobStore
from repro.data.synthetic import make_corpus, render_caption


@pytest.fixture(scope="session")
def corpus():
    images, captions, specs = make_corpus(240, res=32, seed=0)
    return images, captions, specs


@pytest.fixture(scope="session")
def embedder(corpus):
    images, _, _ = corpus
    e = ProxyClipEmbedder(render_caption)
    e.set_corpus_anchor(e.embed_image(images))
    return e


@pytest.fixture()
def fleet(corpus, embedder):
    """4-node VDB fleet built by the storage classifier + blob store."""
    images, captions, _ = corpus
    img_vecs = embedder.embed_image(images)
    txt_vecs = embedder.embed_text(captions)
    blob = BlobStore()
    payloads = np.array([blob.put(im) for im in images], np.int64)
    cls = StorageClassifier(4)
    # capacity ≥ corpus so cluster imbalance never truncates (the LCU
    # tests exercise capacity pressure explicitly)
    dbs = cls.build_node_dbs(img_vecs, txt_vecs, payloads,
                             capacity_per_node=240)
    return dbs, blob, cls, img_vecs, txt_vecs, payloads
