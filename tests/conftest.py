"""Shared fixtures: tiny synthetic corpus, proxy embedder, node VDB fleet.

Multi-device harness: this conftest forces 8 XLA host-platform CPU
devices (``--xla_force_host_platform_device_count=8``) at import — i.e.
before any test can initialise the backend — so the mesh-sharded
cluster-retrieval parity suite runs on any CI box.  The whole tier-1
suite runs under the forced-8 world (single-device tests are
device-count agnostic).  When forcing fails (JAX backend already up in
the hosting process, e.g. an embedding pytest runner), the
``mesh_devices`` fixture SKIPS the sharded tests instead of erroring,
and ``forced_subprocess`` offers a clean-interpreter escape hatch.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

# must run before the repro imports below can touch a jax device: the
# flag only takes effect if the XLA backend has not initialised yet
from repro.launch.mesh import ensure_host_devices

FORCED_DEVICES = 8
_FORCED_OK = ensure_host_devices(FORCED_DEVICES)

from repro.core.embeddings import ProxyClipEmbedder  # noqa: E402
from repro.core.storage_classifier import StorageClassifier  # noqa: E402
from repro.core.vdb import BlobStore  # noqa: E402
from repro.data.synthetic import make_corpus, render_caption  # noqa: E402


@pytest.fixture(scope="session")
def mesh_devices():
    """Number of XLA devices available for node-mesh sharding tests.
    Skips (never errors) when the backend came up with fewer than 2 —
    e.g. JAX was initialised before this conftest could force host
    devices."""
    import jax
    n = len(jax.devices())
    if not _FORCED_OK or n < 2:
        pytest.skip(
            f"sharding tests need >=2 XLA host devices, backend has {n} "
            "(JAX initialised before conftest could force them)")
    return min(n, FORCED_DEVICES)


def run_forced_subprocess(code: str, n_devices: int = FORCED_DEVICES,
                          timeout: float = 600.0):
    """Run ``code`` in a fresh interpreter with ``n_devices`` forced XLA
    host devices and ``src`` on PYTHONPATH — the escape hatch when the
    hosting process's backend is already up with too few devices (and
    the harness's own self-test)."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "--xla_force_host_platform_device_count" not in f]
    flags.append(f"--xla_force_host_platform_device_count={n_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.fixture(scope="session")
def forced_subprocess():
    return run_forced_subprocess


@pytest.fixture(scope="session")
def corpus():
    images, captions, specs = make_corpus(240, res=32, seed=0)
    return images, captions, specs


@pytest.fixture(scope="session")
def embedder(corpus):
    images, _, _ = corpus
    e = ProxyClipEmbedder(render_caption)
    e.set_corpus_anchor(e.embed_image(images))
    return e


@pytest.fixture()
def fleet(corpus, embedder):
    """4-node VDB fleet built by the storage classifier + blob store."""
    images, captions, _ = corpus
    img_vecs = embedder.embed_image(images)
    txt_vecs = embedder.embed_text(captions)
    blob = BlobStore()
    payloads = np.array([blob.put(im) for im in images], np.int64)
    cls = StorageClassifier(4)
    # capacity ≥ corpus so cluster imbalance never truncates (the LCU
    # tests exercise capacity pressure explicitly)
    dbs = cls.build_node_dbs(img_vecs, txt_vecs, payloads,
                             capacity_per_node=240)
    return dbs, blob, cls, img_vecs, txt_vecs, payloads
