"""PR 4: device-resident cross-node retrieval engine.

Pins the ClusterIndex contracts:

* fused cross-node ``search_batch`` == the per-node jnp oracle
  (``_masked_topk_batch`` + union) for every query, across node mixes
  including empty and over-capacity nodes and non-uniform capacities;
* the Pallas ``vdb_topk_sharded`` kernel == its jnp ref, masked and
  all-nodes modes;
* incremental device-slab state == rebuilt-from-numpy after randomized
  add/evict/overwrite sequences;
* the steady-state serve path performs ZERO host→device slab uploads
  and exactly ONE fused scan per micro-batch;
* the vectorised ``_union_topk`` and the cached ``centroid()`` keep
  their pre-PR semantics.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cluster_index import ClusterIndex
from repro.core.vdb import VectorDB, _union_topk
from repro.kernels.ref import vdb_topk_sharded_ref
from repro.kernels.vdb_topk import (NEG_INF, resolve_interpret, vdb_topk,
                                    vdb_topk_sharded)
from repro.launch.serve import build_system


def _unit(rng, n, d):
    v = rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def _mixed_fleet(rng, dim=24):
    """Node mix the fused scan must survive: empty node, partially full,
    exactly full, overfilled (FIFO overwrite), non-uniform capacity."""
    dbs = [VectorDB(dim, 32, name="empty"),
           VectorDB(dim, 32, name="partial"),
           VectorDB(dim, 16, name="full"),
           VectorDB(dim, 48, name="overfull")]
    dbs[1].add(_unit(rng, 10, dim), _unit(rng, 10, dim), np.arange(10), 0.0)
    dbs[2].add(_unit(rng, 16, dim), _unit(rng, 16, dim), np.arange(16), 0.0)
    dbs[3].add(_unit(rng, 60, dim), _unit(rng, 60, dim), np.arange(60), 0.0)
    return dbs


# ---------------------------------------------------------------------------
# fused scan vs per-node oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("index", ["both", "img", "txt"])
def test_fused_vs_per_node_oracle_parity(index):
    rng = np.random.default_rng(0)
    dbs = _mixed_fleet(rng)
    Q = _unit(rng, 7, 24)
    node_ids = [0, 1, 2, 3, 3, 1, 2]
    # oracle rows from the standalone per-node path, BEFORE attaching
    oracle = [dbs[n].search_batch(q[None], 8, index=index)[0]
              for q, n in zip(Q, node_ids)]
    ci = ClusterIndex.from_dbs(dbs)
    fused = ci.search_batch(Q, node_ids, 8, index=index)
    for (o_s, o_l), (f_s, f_l) in zip(oracle, fused):
        np.testing.assert_array_equal(o_l, f_l)
        np.testing.assert_allclose(o_s, f_s, rtol=1e-5, atol=1e-6)


def test_fused_pallas_vs_oracle_parity():
    rng = np.random.default_rng(1)
    dbs = _mixed_fleet(rng)
    Q = _unit(rng, 5, 24)
    node_ids = [1, 2, 3, 1, 3]
    oracle = [dbs[n].search_batch(q[None], 6)[0]
              for q, n in zip(Q, node_ids)]
    ci = ClusterIndex.from_dbs(dbs, use_pallas=True, interpret=True)
    fused = ci.search_batch(Q, node_ids, 6)
    for (o_s, o_l), (f_s, f_l) in zip(oracle, fused):
        np.testing.assert_array_equal(o_l, f_l)
        np.testing.assert_allclose(o_s, f_s, rtol=1e-4, atol=1e-5)


def test_empty_node_returns_no_candidates():
    rng = np.random.default_rng(2)
    dbs = _mixed_fleet(rng)
    ci = ClusterIndex.from_dbs(dbs)
    (scores, slots), = ci.search_batch(_unit(rng, 1, 24), [0], 4)
    assert len(scores) == 0 and len(slots) == 0


def test_attached_vdb_search_delegates_with_identical_results():
    rng = np.random.default_rng(3)
    dbs = _mixed_fleet(rng)
    q = _unit(rng, 1, 24)[0]
    legacy = [db.search(q, k=5) for db in dbs]
    ci = ClusterIndex.from_dbs(dbs)
    qc0 = [db.query_count for db in dbs]
    for db, (l_s, l_l) in zip(dbs, legacy):
        c_s, c_l = db.search(q, k=5)           # now the fused cluster path
        np.testing.assert_array_equal(l_l, c_l)
        np.testing.assert_allclose(l_s, c_s, rtol=1e-5, atol=1e-6)
    assert [db.query_count for db in dbs] == [c + 1 for c in qc0]
    assert ci.stats["fused_scans"] == len(dbs)


def test_search_cluster_all_nodes_mode_matches_flat_oracle():
    rng = np.random.default_rng(4)
    dbs = _mixed_fleet(rng)
    ci = ClusterIndex.from_dbs(dbs)
    Q = _unit(rng, 3, 24)
    rows = ci.search_cluster(Q, 5)
    slabs, valid = ci.device_state()
    for q, (scores, gslots) in zip(Q, rows):
        # oracle: per-plane top-k over the flattened cluster, then union
        s_ref, i_ref = vdb_topk_sharded_ref(
            jnp.asarray(q[None]), jnp.asarray(slabs), jnp.asarray(valid),
            jnp.zeros((1,), jnp.int32), 5, mask_nodes=False)
        o_s, o_l = _union_topk([np.asarray(s_ref[p][0]) for p in range(2)],
                               [np.asarray(i_ref[p][0]) for p in range(2)])
        np.testing.assert_array_equal(o_l, gslots)
        np.testing.assert_allclose(o_s, scores, rtol=1e-5, atol=1e-6)
        # global ids decompose into (node, col) within capacity
        assert ((gslots // ci.capacity) < ci.n_nodes).all()


# ---------------------------------------------------------------------------
# the sharded Pallas kernel vs its jnp ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mask_nodes", [True, False])
@pytest.mark.parametrize("qn,nodes,cap,k,block", [
    (4, 2, 32, 4, 16), (8, 3, 64, 8, 64), (2, 4, 24, 3, 16)])
def test_sharded_kernel_matches_ref(qn, nodes, cap, k, block, mask_nodes):
    rng = np.random.default_rng(qn * 100 + nodes * 10 + k)
    slabs = rng.normal(size=(2, nodes, cap, 16)).astype(np.float32)
    valid = rng.random((nodes, cap)) < 0.7
    Q = _unit(rng, qn, 16)
    nids = rng.integers(0, nodes, size=qn).astype(np.int32)
    s_k, i_k = vdb_topk_sharded(jnp.asarray(Q), jnp.asarray(slabs),
                                jnp.asarray(valid), jnp.asarray(nids), k,
                                block_n=block, mask_nodes=mask_nodes,
                                interpret=True)
    s_r, i_r = vdb_topk_sharded_ref(jnp.asarray(Q), jnp.asarray(slabs),
                                    jnp.asarray(valid), jnp.asarray(nids), k,
                                    mask_nodes=mask_nodes)
    s_k, i_k, s_r, i_r = map(np.asarray, (s_k, i_k, s_r, i_r))
    real = np.isfinite(s_r) & (s_r > NEG_INF / 2)
    np.testing.assert_array_equal(np.where(real, i_k, -1),
                                  np.where(real, i_r, -1))
    np.testing.assert_allclose(s_k[real], s_r[real], rtol=1e-5, atol=1e-6)
    # kernel sentinel: masked candidates sit at NEG_INF, never -inf
    assert np.isfinite(s_k).all()


def test_interpret_default_is_backend_aware():
    # on this container (no TPU) None must resolve to interpret mode and
    # produce the same results as an explicit interpret=True
    import jax
    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    rng = np.random.default_rng(7)
    db = rng.normal(size=(32, 8)).astype(np.float32)
    valid = rng.random(32) < 0.8
    q = _unit(rng, 2, 8)
    s_auto, i_auto = vdb_topk(jnp.asarray(q), jnp.asarray(db),
                              jnp.asarray(valid), 4)
    s_int, i_int = vdb_topk(jnp.asarray(q), jnp.asarray(db),
                            jnp.asarray(valid), 4, interpret=True)
    np.testing.assert_array_equal(np.asarray(i_auto), np.asarray(i_int))
    np.testing.assert_array_equal(np.asarray(s_auto), np.asarray(s_int))


# ---------------------------------------------------------------------------
# incremental device state
# ---------------------------------------------------------------------------


def test_incremental_state_matches_rebuild_after_random_mutations():
    rng = np.random.default_rng(11)
    dim = 12
    dbs = [VectorDB(dim, c) for c in (8, 16, 16)]
    ci = ClusterIndex.from_dbs(dbs)
    uploads0 = ci.stats["slab_uploads"]
    for step in range(60):
        ni = int(rng.integers(0, len(dbs)))
        db = dbs[ni]
        op = rng.integers(0, 3)
        if op < 2:          # add (incl. overwrite-oldest when full)
            n = int(rng.integers(1, db.capacity + 3))  # > capacity allowed
            db.add(_unit(rng, n, dim), _unit(rng, n, dim),
                   np.arange(n) + step * 1000, t=float(step))
        else:               # evict a random live subset
            live = np.flatnonzero(db.valid)
            if len(live):
                db.evict_slots(rng.choice(
                    live, size=int(rng.integers(1, len(live) + 1)),
                    replace=False))
    dev_slabs, dev_valid = ci.device_state()
    ref_slabs, ref_valid = ci.rebuild_reference()
    np.testing.assert_array_equal(dev_valid, ref_valid)
    np.testing.assert_array_equal(dev_slabs, ref_slabs)
    assert ci.stats["slab_uploads"] == uploads0      # rows only, no slabs
    assert ci.stats["row_updates"] > 0


def test_refresh_node_resyncs_out_of_band_mutation():
    rng = np.random.default_rng(12)
    dbs = [VectorDB(8, 8) for _ in range(2)]
    dbs[0].add(_unit(rng, 4, 8), _unit(rng, 4, 8), np.arange(4), 0.0)
    ci = ClusterIndex.from_dbs(dbs)
    dbs[0].img_vecs[0] = 0.0                         # behind the index's back
    ci.refresh_node(0)
    dev_slabs, dev_valid = ci.device_state()
    ref_slabs, ref_valid = ci.rebuild_reference()
    np.testing.assert_array_equal(dev_slabs, ref_slabs)
    np.testing.assert_array_equal(dev_valid, ref_valid)


def test_refresh_node_rebinds_restored_vdb():
    """`VectorDB.restore` returns a NEW object; refresh_node(node, db=...)
    must rebind the view so the index serves the restored state and
    subsequent mutations flow from the new object."""
    rng = np.random.default_rng(13)
    dbs = [VectorDB(8, 8) for _ in range(2)]
    dbs[0].add(_unit(rng, 4, 8), _unit(rng, 4, 8), np.arange(4), 0.0)
    snap = dbs[0].snapshot()
    ci = ClusterIndex.from_dbs(dbs)
    dbs[0].evict_slots(np.array([0, 1, 2, 3]))       # diverge, then restore
    restored = VectorDB.restore(8, 8, snap)
    ci.refresh_node(0, db=restored)
    assert ci.dbs[0] is restored
    dev_slabs, dev_valid = ci.device_state()
    ref_slabs, ref_valid = ci.rebuild_reference()
    np.testing.assert_array_equal(dev_slabs, ref_slabs)
    np.testing.assert_array_equal(dev_valid, ref_valid)
    # the old object no longer feeds the index; the new one does
    restored.add(_unit(rng, 1, 8), _unit(rng, 1, 8), np.array([99]), 1.0)
    dev_slabs, dev_valid = ci.device_state()
    ref_slabs, ref_valid = ci.rebuild_reference()
    np.testing.assert_array_equal(dev_slabs, ref_slabs)
    np.testing.assert_array_equal(dev_valid, ref_valid)
    q = restored.img_vecs[restored.valid][0]
    (scores, slots), = ci.search_batch(q[None], [0], 3)
    assert restored.valid[slots].all()


# ---------------------------------------------------------------------------
# serve-path integration: one scan per micro-batch, zero slab uploads
# ---------------------------------------------------------------------------


def _prompts(system, n, seed=0):
    from repro.core.trace import RequestTrace
    return [r.prompt for r in RequestTrace(seed=seed).generate(n)]


def test_retrieve_stage_issues_exactly_one_scan_per_microbatch(monkeypatch):
    """Centroid mode: the Retrieve stage's masked scan is the batch's one
    device scan.  (Score mode fuses Schedule+Retrieve into one
    ``search_cluster_nodes`` scan — pinned in
    ``tests/test_scheduling_score.py``.)"""
    system, _, _, _ = build_system(n_nodes=3, corpus_n=90,
                                   capacity_per_node=60, routing="centroid")
    ci = system.cluster_index
    assert ci is not None
    calls = []
    orig = ci.search_batch
    monkeypatch.setattr(ci, "search_batch",
                        lambda *a, **kw: calls.append(a) or orig(*a, **kw))
    # the per-node path must never run on the serve path
    monkeypatch.setattr(
        VectorDB, "search_batch",
        lambda self, *a, **kw: pytest.fail("per-node search on serve path"))
    prompts = _prompts(system, 8)
    results = system.serve_batch(prompts, seeds=list(range(8)))
    assert len(results) == 8
    assert len(calls) == 1                 # ONE fused scan for the batch
    nodes_touched = {d for d in calls[0][1]}
    assert len(nodes_touched) >= 1


def test_steady_state_serve_has_zero_slab_uploads():
    system, _, _, _ = build_system(n_nodes=3, corpus_n=90,
                                   capacity_per_node=60)
    ci = system.cluster_index
    prompts = _prompts(system, 24, seed=3)
    system.serve_batch(prompts[:8], seeds=list(range(8)))      # warmup
    uploads = ci.stats["slab_uploads"]
    scans = ci.stats["fused_scans"]
    for lo in (8, 16):
        system.serve_batch(prompts[lo:lo + 8],
                           seeds=list(range(lo, lo + 8)))
    assert ci.stats["slab_uploads"] == uploads   # ZERO steady-state uploads
    assert ci.stats["fused_scans"] >= scans + 2  # but the scans did run
    assert ci.stats["row_updates"] > 0           # archives flowed as rows


def test_serve_parity_with_and_without_cluster_index():
    """The fused engine is a pure perf change: routes, nodes and hit
    stats match a system running the per-node fallback on the same
    trace.  Centroid mode on both sides — score routing REQUIRES the
    cluster index (dropping it falls back to centroid routing), so the
    retrieval engine's pure-perf contract is a centroid-mode property."""
    kw = dict(n_nodes=3, corpus_n=90, capacity_per_node=60,
              routing="centroid")
    sys_a, _, _, _ = build_system(**kw)
    sys_b, _, _, _ = build_system(**kw)
    sys_b.cluster_index = None                   # force per-node fallback
    prompts = _prompts(sys_a, 20, seed=5)
    ra = [sys_a.serve(p, seed=i) for i, p in enumerate(prompts)]
    rb = [sys_b.serve(p, seed=i) for i, p in enumerate(prompts)]
    for a, b in zip(ra, rb):
        assert a.route == b.route and a.node == b.node
        np.testing.assert_array_equal(a.image, b.image)
    assert sys_a.stats.route_counts == sys_b.stats.route_counts
    assert sys_a.stats.cache_hits == sys_b.stats.cache_hits


# ---------------------------------------------------------------------------
# satellites: vectorised _union_topk + cached centroid
# ---------------------------------------------------------------------------


def test_union_topk_drops_sentinels_and_keeps_best_per_slot():
    scores = [np.array([0.9, -np.inf, 0.5, -2e30], np.float32),
              np.array([0.7, 0.9, np.inf, np.nan], np.float32)]
    slots = [np.array([3, 1, 2, 0]), np.array([3, 5, 6, 7])]
    s, l = _union_topk(scores, slots)
    assert l.tolist() == [3, 5, 2]            # best-per-slot, desc order
    np.testing.assert_allclose(s, [0.9, 0.9, 0.5])


def test_union_topk_empty_and_all_masked():
    s, l = _union_topk([], [])
    assert len(s) == 0 and len(l) == 0
    s, l = _union_topk([np.array([-np.inf, -1e30], np.float32)],
                       [np.array([0, 1])])
    assert len(s) == 0 and len(l) == 0
    assert s.dtype == np.float32 and l.dtype == np.int64


def test_union_topk_matches_dict_reference_randomized():
    rng = np.random.default_rng(21)
    for _ in range(50):
        rows = rng.integers(1, 3)
        score_rows, slot_rows = [], []
        for _ in range(rows):
            n = rng.integers(1, 12)
            sc = rng.normal(size=n).astype(np.float32)
            sc[rng.random(n) < 0.2] = -np.inf
            sc[rng.random(n) < 0.1] = -1e30
            score_rows.append(sc)
            slot_rows.append(rng.integers(0, 8, size=n))
        best = {}
        for sc, sl in zip(score_rows, slot_rows):
            for c, s_ in zip(sc, sl):
                if np.isfinite(c) and c > -1e29 and \
                        (s_ not in best or c > best[s_]):
                    best[int(s_)] = float(c)
        got_s, got_l = _union_topk(score_rows, slot_rows)
        assert dict(zip(got_l.tolist(), got_s.tolist())) == pytest.approx(best)
        assert list(got_s) == sorted(got_s, reverse=True)


def test_add_partial_overflow_evicts_oldest_without_duplicate_slots():
    """Regression: a batch insert into a PARTIALLY full db (0 < free < n)
    must land every row on a distinct slot and overwrite the oldest VALID
    entries — not re-pick already-free slots (which silently dropped rows
    and kept entries FIFO should have evicted)."""
    rng = np.random.default_rng(24)
    db = VectorDB(8, 4)
    db.add(_unit(rng, 4, 8), _unit(rng, 4, 8), np.array([100, 101, 102, 103]),
           t=0.0)
    db.evict_slots(np.array([0, 1]))             # 2 free, 2 valid (102, 103)
    db.insert_time[2] = 0.5                      # 102 older than 103
    db.insert_time[3] = 1.0
    slots = db.add(_unit(rng, 3, 8), _unit(rng, 3, 8),
                   np.array([200, 201, 202]), t=2.0)
    assert len(set(slots.tolist())) == 3         # no duplicate slots
    alive = set(db.payload_ids[db.valid].tolist())
    assert alive == {103, 200, 201, 202}         # oldest valid (102) evicted
    np.testing.assert_allclose(db.centroid(),
                               db.img_vecs[db.valid].mean(axis=0),
                               rtol=1e-5, atol=1e-7)


def test_centroid_cache_tracks_mutations():
    rng = np.random.default_rng(22)
    db = VectorDB(10, 16)
    for step in range(30):
        if rng.random() < 0.6 or db.size == 0:
            n = int(rng.integers(1, 5))
            db.add(_unit(rng, n, 10), _unit(rng, n, 10),
                   np.arange(n) + step * 100, t=float(step))
        else:
            live = np.flatnonzero(db.valid)
            db.evict_slots(rng.choice(live, size=1))
        if db.size:
            np.testing.assert_allclose(
                db.centroid(), db.img_vecs[db.valid].mean(axis=0),
                rtol=1e-5, atol=1e-7)
        else:
            np.testing.assert_array_equal(db.centroid(), np.zeros(10))


def test_centroid_invalidated_on_restore():
    rng = np.random.default_rng(23)
    db = VectorDB(6, 8)
    db.add(_unit(rng, 5, 6), _unit(rng, 5, 6), np.arange(5), 0.0)
    snap = db.snapshot()
    db2 = VectorDB.restore(6, 8, snap)
    np.testing.assert_allclose(db2.centroid(), db.centroid(),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(db2.centroid(),
                               db2.img_vecs[db2.valid].mean(axis=0),
                               rtol=1e-5, atol=1e-7)
