"""Continuous-batching serving engine: property-based parity suite,
per-stage timestamp accounting, queue-delay regression, no-JIT-at-serve
guarantee, and the bursty-trace latency win.

Parity contract (module docstring of ``repro.runtime.serving``): batch
partitioning never changes results on traces where distinct in-batch
prompts do not interact through freshly archived images.  The property
tests draw from a verified grid of (trace seed × arrival process) points
satisfying that precondition — the shim's seeded draws make the example
stream deterministic in CI; real `hypothesis`'s ``sampled_from`` stays
inside the same domain.
"""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.policy import GenerationPolicy
from repro.core.trace import (RequestTrace, TimedRequest, bursty_arrivals,
                              poisson_arrivals, trace_arrivals)
from repro.launch.serve import build_system
from repro.runtime.serving import ServingEngine


def _system():
    system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                   capacity_per_node=80, seed=0)
    return system


def _trace(n, seed):
    return list(RequestTrace(seed=seed).generate(n))


def _arrivals(reqs, kind, param, seed):
    if kind == "poisson":
        return poisson_arrivals(reqs, rate=param, seed=seed)
    return bursty_arrivals(reqs, burst_size=int(param), burst_gap=0.4)


def _route_key(r):
    return r.fast_path or r.route.value


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 30),
       rate=st.sampled_from([5.0, 50.0, 500.0]))
def test_poisson_arrivals_properties(n, seed, rate):
    reqs = _trace(n, seed=1)
    a = poisson_arrivals(reqs, rate, seed=seed)
    b = poisson_arrivals(reqs, rate, seed=seed)
    assert len(a) == n
    assert [x.prompt for x in a] == [r.prompt for r in reqs]  # order kept
    assert [x.seed for x in a] == list(range(n))
    times = [x.arrival_time for x in a]
    assert all(t2 >= t1 > 0 for t1, t2 in zip(times, times[1:])) or n == 1
    assert times[0] > 0
    assert times == [x.arrival_time for x in b]               # deterministic
    assert poisson_arrivals(reqs, rate, seed=seed + 1)[0].arrival_time \
        != times[0]


def test_poisson_arrivals_mean_rate():
    reqs = _trace(400, seed=0)
    times = [r.arrival_time for r in poisson_arrivals(reqs, 50.0, seed=3)]
    mean_gap = times[-1] / len(times)
    assert 0.5 / 50.0 < mean_gap < 2.0 / 50.0
    with pytest.raises(ValueError):
        poisson_arrivals(reqs, rate=0.0)


def test_trace_arrivals_replay_and_validation():
    reqs = _trace(4, seed=2)
    ts = [0.0, 0.5, 0.5, 3.25]
    arr = trace_arrivals(reqs, ts)
    assert [a.arrival_time for a in arr] == ts
    assert [a.quality_tier for a in arr] == [r.quality_tier for r in reqs]
    with pytest.raises(ValueError):
        trace_arrivals(reqs, [0.0, 1.0])            # length mismatch
    with pytest.raises(ValueError):
        trace_arrivals(reqs, [0.0, 2.0, 1.0, 3.0])  # not non-decreasing
    # bare prompt strings work too
    arr2 = trace_arrivals(["a", "b"], [1.0, 2.0])
    assert arr2[0].prompt == "a" and arr2[1].seed == 1


def test_bursty_arrivals_structure():
    arr = bursty_arrivals(["p"] * 7, burst_size=3, burst_gap=2.0,
                          within_burst_gap=0.01)
    times = [round(a.arrival_time, 6) for a in arr]
    assert times == [0.0, 0.01, 0.02, 2.0, 2.01, 2.02, 4.0]
    with pytest.raises(ValueError):
        bursty_arrivals(["p"], burst_size=0, burst_gap=1.0)
    with pytest.raises(ValueError):
        bursty_arrivals(["p"], burst_size=1, burst_gap=-1.0)


# ---------------------------------------------------------------------------
# parity properties: batch partitioning never changes results
# ---------------------------------------------------------------------------

# Verified grid (see module docstring): every point satisfies the
# serve_batch parity precondition, so continuous-mode partitions must
# reproduce fixed-drain results exactly.
_PARITY_SEEDS = (0, 2, 3, 4, 5, 7, 8, 9, 11)
_PARITY_ARRIVALS = (("poisson", 30.0), ("poisson", 60.0),
                    ("poisson", 120.0), ("bursty", 3), ("bursty", 7),
                    ("bursty", 12))


@settings(max_examples=6, deadline=None)
@given(tseed=st.sampled_from(_PARITY_SEEDS),
       arrival=st.sampled_from(_PARITY_ARRIVALS))
def test_continuous_is_permutation_of_fixed_drain(tseed, arrival):
    """On random Zipf traces, continuous-mode results (routes, images,
    cache state, hit/miss stats) are a permutation — in fact arrival-order
    identical — of the fixed-drain ``serve_batch`` results."""
    kind, param = arrival
    reqs = _trace(40, seed=tseed)

    s_cont = _system()
    done_cont = ServingEngine(s_cont, max_batch=8).run(
        _arrivals(reqs, kind, param, seed=tseed))

    s_fix = _system()
    eng = ServingEngine(s_fix, max_batch=8)
    for i, r in enumerate(reqs):
        eng.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    done_fix = eng.drain()

    assert len(done_cont) == len(done_fix) == len(reqs)
    # permutation of results: both disciplines preserve arrival order, so
    # the permutation is the identity — assert the stronger pairwise form
    for a, b in zip(done_cont, done_fix):
        assert a.request.prompt == b.request.prompt
        assert _route_key(a.result) == _route_key(b.result)
        assert a.result.node == b.result.node
        assert a.result.steps == b.result.steps
        np.testing.assert_array_equal(a.result.image, b.result.image)
    # hit/miss stats
    assert s_cont.stats.route_counts == s_fix.stats.route_counts
    assert s_cont.stats.cache_hits == s_fix.stats.cache_hits
    assert s_cont.stats.reference_hits == s_fix.stats.reference_hits
    assert s_cont.stats.hit_rate == pytest.approx(s_fix.stats.hit_rate)
    # cache state
    for db_a, db_b in zip(s_cont.dbs, s_fix.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)
        np.testing.assert_array_equal(db_a.access_count, db_b.access_count)
    assert len(s_cont.blob_store) == len(s_fix.blob_store)
    assert s_cont.scheduler._hist_payloads == s_fix.scheduler._hist_payloads
    assert s_cont.scheduler.history_hits == s_fix.scheduler.history_hits


@settings(max_examples=4, deadline=None)
@given(tseed=st.integers(0, 30))
def test_single_submission_continuous_is_bitwise_sequential(tseed):
    """Arrivals spaced far wider than the service time are served as
    batches of one — and a batch of one IS the sequential path, so the
    continuous engine must reproduce ``serve`` bitwise on ANY trace."""
    reqs = _trace(16, seed=tseed)

    s_seq = _system()
    r_seq = [s_seq.serve(r.prompt, seed=i, quality_tier=r.quality_tier)
             for i, r in enumerate(reqs)]

    s_cont = _system()
    spaced = trace_arrivals(reqs, [1.0 * (i + 1) for i in range(len(reqs))])
    done = ServingEngine(s_cont, max_batch=8).run(spaced)

    for a, c in zip(r_seq, done):
        assert _route_key(a) == _route_key(c.result)
        assert a.node == c.result.node
        assert a.score == pytest.approx(c.result.score)
        np.testing.assert_array_equal(a.image, c.result.image)
    assert s_seq.stats.route_counts == s_cont.stats.route_counts
    for db_a, db_b in zip(s_seq.dbs, s_cont.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)


def test_drain_mode_equals_legacy_drain():
    """``run(mode="drain")`` on an everything-already-arrived trace chunks
    the queue exactly like the legacy ``submit``+``drain`` loop."""
    reqs = _trace(20, seed=4)
    s_a = _system()
    done_a = ServingEngine(s_a, max_batch=8).run(
        trace_arrivals(reqs, [0.0] * len(reqs)), mode="drain")
    s_b = _system()
    eng = ServingEngine(s_b, max_batch=8)
    for i, r in enumerate(reqs):
        eng.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    done_b = eng.drain()
    for a, b in zip(done_a, done_b):
        assert _route_key(a.result) == _route_key(b.result)
        np.testing.assert_array_equal(a.result.image, b.result.image)
    assert s_a.stats.route_counts == s_b.stats.route_counts


def test_run_validates_mode_and_handles_empty():
    eng = ServingEngine(_system(), max_batch=4)
    assert eng.run([]) == []
    with pytest.raises(ValueError):
        eng.run([TimedRequest(0.0, "p")], mode="micro")


# ---------------------------------------------------------------------------
# per-stage timestamps and true queue delay
# ---------------------------------------------------------------------------


def test_stage_timestamps_monotone_for_every_request():
    """Every request — coalesced duplicates included — carries its own
    monotone non-decreasing stage-timestamp trail, and queue delays are
    never negative."""
    system = _system()
    reqs = _trace(24, seed=5)
    done = ServingEngine(system, max_batch=8).run(
        bursty_arrivals(reqs, burst_size=7, burst_gap=0.3))
    names = system.pipeline.stage_names
    for c in done:
        walls = c.result.stage_walls
        assert list(walls) == names                 # all stages, in order
        assert all(w >= 0.0 for w in walls.values())    # monotone trail
        assert c.queue_delay >= 0.0
        assert c.result.wall_total > 0.0
        assert c.finished_at >= c.request.submitted_at


def test_stage_timestamps_on_request_state():
    """The raw trail lives on ``RequestState.stage_ts``: admission <=
    every stage end, non-decreasing in stage order."""
    system = _system()
    states = system.pipeline.run(
        system, [r.prompt for r in _trace(6, seed=6)],
        seeds=list(range(6)), submitted_ats=[0.0] * 6)
    names = system.pipeline.stage_names
    for s in states:
        assert list(s.stage_ts) == names
        prev = s.admitted_at
        for name in names:
            assert s.stage_ts[name] >= prev
            prev = s.stage_ts[name]
        assert s.result.queue_delay == pytest.approx(s.admitted_at)


def test_stage_walls_reconcile_with_end_to_end_wall():
    """sum(stage durations) == wall_total, and queue delay + wall_total
    reconciles with the end-to-end submission->finish wall time."""
    system = _system()
    eng = ServingEngine(system, max_batch=4)
    reqs = _trace(12, seed=7)
    for i, r in enumerate(reqs):
        eng.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    done = eng.drain()
    for c in done:
        r = c.result
        assert sum(r.stage_walls.values()) == pytest.approx(r.wall_total,
                                                            rel=1e-6)
        e2e = c.finished_at - c.request.submitted_at
        # admission->finish + wait == submission->completion, up to the
        # engine's bookkeeping between serve_batch return and finished_at
        assert r.queue_delay + r.wall_total == pytest.approx(e2e, abs=0.05)
        assert r.queue_delay + r.wall_total <= e2e + 1e-9


def test_continuous_clock_reconciles():
    """Virtual-clock accounting: finished_at - arrival == queue delay +
    measured service, and the service the engine booked matches the
    pipeline's own wall_total within bookkeeping overhead."""
    system = _system()
    reqs = _trace(18, seed=8)
    done = ServingEngine(system, max_batch=8).run(
        poisson_arrivals(reqs, rate=80.0, seed=8))
    for c in done:
        e2e = c.finished_at - c.request.submitted_at
        assert e2e >= c.queue_delay >= 0.0
        service = e2e - c.queue_delay
        assert service == pytest.approx(c.result.wall_total, abs=0.05)
        assert c.result.queue_delay == c.queue_delay


def test_coalesced_duplicates_get_their_own_timestamps():
    """An in-batch near-duplicate coalesces onto the earlier member's
    generation (alias plan) — it must still carry the full timestamp
    trail and a queue delay of its own."""
    system = _system()
    # a novel prompt (nothing close in the warm cache) forces the first
    # member down the generate path, so its verbatim repeats coalesce
    prompt = "an uncatalogued shimmering polyhedron on static"
    states = system.pipeline.run(system, [prompt, prompt, prompt],
                                 seeds=[0, 1, 2],
                                 submitted_ats=[0.0, 0.0, 0.0])
    kinds = [s.plan.kind for s in states]
    assert kinds[0] == "gen" and set(kinds[1:]) == {"alias"}
    names = system.pipeline.stage_names
    for s in states:
        assert list(s.stage_ts) == names
        assert list(s.result.stage_walls) == names
        assert s.result.wall_total > 0.0
        assert s.result.queue_delay >= 0.0


def test_queue_delay_is_time_waited_not_ticks():
    """Regression for the old ``self._clock - req.submitted_at`` formula,
    which reported submission-COUNT ticks: the first-submitted request had
    the LARGEST delay (N-1 ticks) even though it is admitted first.  True
    queue delay is the opposite: the first request is admitted at drain
    start (~0 wait) while the last waits out the batches ahead of it."""
    system = _system()
    eng = ServingEngine(system, max_batch=8)
    reqs = _trace(17, seed=10)                 # 3 micro-batches: 8 + 8 + 1
    for i, r in enumerate(reqs):
        eng.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    done = eng.drain()
    delays = [c.queue_delay for c in done]
    assert all(d >= 0.0 for d in delays)
    # old formula: delays[0] == 16 ticks > delays[-1] == 0 ticks
    assert delays[0] < delays[-1]
    # within a micro-batch later submissions waited less (shared admission)
    for lo in (0, 8):
        assert all(a >= b for a, b in zip(delays[lo:lo + 8],
                                          delays[lo + 1:lo + 8]))
    # across micro-batches delays grow by the service time ahead
    assert max(delays[:8]) < min(delays[8:16]) + delays[0] + 1e-9
    assert np.mean(delays[8:16]) > np.mean(delays[:8])


# ---------------------------------------------------------------------------
# exact-crossing maintenance: sub-batch intervals keep their cadence
# ---------------------------------------------------------------------------


def _count_maintains(system):
    """Wrap ``system.maintain`` to record the request count at each sweep."""
    crossings = []
    orig = system.maintain

    def wrapped():
        crossings.append(system.stats.requests)
        return orig()

    system.maintain = wrapped
    return crossings


def test_sub_batch_maintenance_interval_is_honoured():
    """Regression for the old clamp: ``ServingEngine`` used to clamp a
    sub-batch ``maintenance_interval`` up to ``max_batch`` with a warning
    because sweeps only fired at group boundaries.  Sweeps now fire at
    EXACT request-count crossings inside the Finish stage, so the
    operator's interval is honoured as-is — no clamp, no warning."""
    system = _system()
    system.maintenance_interval = 2
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ServingEngine(system, max_batch=8)
    assert system.maintenance_interval == 2     # left alone
    crossings = _count_maintains(system)
    reqs = _trace(8, seed=3)
    system.serve_batch([r.prompt for r in reqs], seeds=list(range(8)))
    # one batch of 8 with interval 2 sweeps at requests 2, 4, 6, 8 — the
    # exact cadence the sequential loop produces
    assert crossings == [2, 4, 6, 8]


def test_group_boundary_maintenance_keeps_partition_parity():
    """Regression for the maintenance-mid-flight caveat: sweeps fire at
    exact request-count crossings, so sequential serve and the batched
    drain sweep at the SAME request counts — cache state no longer
    depends on partitioning.  (Pre-fix, mid-loop sweeps diverged: a batch
    crossing the boundary swept before its later members' archives, at a
    different point than the sequential loop.)"""
    reqs = _trace(48, seed=2)

    def build():
        system = _system()
        system.maintenance_interval = 8
        system.cache_capacity = 100          # tight: sweeps actually evict
        return system

    s_seq = build()
    for i, r in enumerate(reqs):
        s_seq.serve(r.prompt, seed=i, quality_tier=r.quality_tier)

    s_bat = build()
    done = ServingEngine(s_bat, max_batch=8).run(
        trace_arrivals(reqs, [0.0] * len(reqs)), mode="drain")
    assert len(done) == len(reqs)
    assert s_seq.stats.route_counts == s_bat.stats.route_counts
    for db_a, db_b in zip(s_seq.dbs, s_bat.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)
    # the sweeps actually ran and bound the cache
    assert s_seq.total_size <= 100 and s_bat.total_size <= 100


def test_sub_batch_interval_ragged_groups_keep_parity():
    """The previously caveated case, now passing: a maintenance interval
    SMALLER than max_batch with ragged continuous admission groups.  The
    old group-boundary sweep shifted its cadence with the partitioning
    (hence the clamp); exact-crossing sweeps + deferred archives make the
    (archive, sweep) sequence partition-independent, so sequential serve,
    fixed drain, and ragged continuous groups all converge to the same
    cache state and route mix on a verified trace."""
    reqs = _trace(40, seed=2)

    def build():
        system = _system()
        system.maintenance_interval = 4      # < max_batch = 8
        system.cache_capacity = 100          # tight: sweeps actually evict
        return system

    s_seq = build()
    for i, r in enumerate(reqs):
        s_seq.serve(r.prompt, seed=i, quality_tier=r.quality_tier)

    s_drain = build()
    ServingEngine(s_drain, max_batch=8).run(
        trace_arrivals(reqs, [0.0] * len(reqs)), mode="drain")

    s_cont = build()
    ServingEngine(s_cont, max_batch=8).run(
        poisson_arrivals(reqs, rate=60.0, seed=2))   # ragged groups

    for sys_b in (s_drain, s_cont):
        assert s_seq.stats.route_counts == sys_b.stats.route_counts
        for db_a, db_b in zip(s_seq.dbs, sys_b.dbs):
            np.testing.assert_array_equal(db_a.valid, db_b.valid)
            np.testing.assert_array_equal(db_a.payload_ids,
                                          db_b.payload_ids)
        assert sys_b.total_size <= 100


def test_batch_spanning_intervals_sweeps_at_each_crossing():
    """Regression for the old coalesced-sweep warning: a single batch
    spanning several interval multiples used to collapse them into ONE
    group-boundary sweep (and warn).  Exact-crossing maintenance fires a
    sweep at EVERY multiple the batch crosses, interleaved with result
    recording — no warning, no coalescing."""
    system = _system()
    system.maintenance_interval = 4
    crossings = _count_maintains(system)
    reqs = _trace(12, seed=6)
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        system.serve_batch([r.prompt for r in reqs],
                           seeds=list(range(len(reqs))))
    assert crossings == [4, 8, 12]
    # a batch of 6 continues on the same counter: next crossing is 16
    crossings.clear()
    system.serve_batch([r.prompt for r in reqs[:6]], seeds=list(range(6)))
    assert crossings == [16]


def test_continuous_run_with_sub_batch_interval_stays_consistent():
    """A continuous run with a sub-batch maintenance interval (the config
    the engine used to clamp away): sweeps fire at exact crossings inside
    ragged admission groups — capacity stays bounded and every history
    entry still resolves to a live blob."""
    reqs = _trace(40, seed=5)
    system = _system()
    system.maintenance_interval = 2              # honoured as-is now
    system.cache_capacity = 100
    eng = ServingEngine(system, max_batch=8)
    done = eng.run(poisson_arrivals(reqs, rate=60.0, seed=5))
    assert len(done) == len(reqs)
    assert system.total_size <= 100
    blob_ids = set(system.blob_store._blobs)
    assert all(p in blob_ids for p in system.scheduler._hist_payloads)


# ---------------------------------------------------------------------------
# tiny-DiT CPU config: no JIT at serve time + the bursty latency win
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_diffusion_backend():
    import jax
    from repro.configs import get_arch
    from repro.models.diffusion import dit as dit_mod
    from repro.models.diffusion import vae as vae_mod
    from repro.core.embeddings import ProxyClipEmbedder
    from repro.data.synthetic import render_caption
    from repro.runtime.serving import DiffusionBackend

    emb = ProxyClipEmbedder(render_caption)
    dcfg = get_arch("sd15-small").make_config(None)
    net = dit_mod.init_dit(jax.random.key(0), dcfg.net)
    vae = vae_mod.init_vae(jax.random.key(1), dcfg.vae)
    return DiffusionBackend(net, dcfg.net, vae, dcfg.vae,
                            embed_prompt=lambda p: emb.embed_text([p])[0])


def _tiny_system(backend, max_batch):
    policy = GenerationPolicy(steps_full=2, steps_ref=2)
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60, seed=0,
                                   policy=policy, backend=backend)
    # every pow2 bucket a group of size <= max_batch can pad to
    buckets, b = [], 1
    while b <= max_batch:
        buckets.append(b)
        b *= 2
    backend.precompile(step_buckets=(2,), batch_buckets=tuple(buckets))
    for bucket in buckets:
        for db in system.dbs:
            db.search_batch(np.zeros((bucket, db.dim), np.float32),
                            system.topk)
    return system


def test_precompiled_continuous_run_never_jits(tiny_diffusion_backend):
    """Serving after ``precompile()`` must not trigger JIT at serve time:
    ``DiffusionBackend._compiled`` gains no new (kind, steps, batch) keys
    during a continuous run whose group sizes stay within the precompiled
    buckets."""
    system = _tiny_system(tiny_diffusion_backend, max_batch=4)
    keys_before = set(tiny_diffusion_backend._compiled)
    reqs = _trace(12, seed=11)
    done = ServingEngine(system, max_batch=4).run(
        poisson_arrivals(reqs, rate=200.0, seed=11))
    assert len(done) == len(reqs)
    assert set(tiny_diffusion_backend._compiled) == keys_before
    # the run actually exercised the denoiser path, not just cache hits
    gen_routes = [c for c in done
                  if c.result.steps > 0 and c.result.fast_path != "history"]
    assert gen_routes


def test_bursty_trace_continuous_beats_fixed_drain_p95(
        tiny_diffusion_backend):
    """The benchmark smoke (acceptance gate): on the tiny-DiT CPU config a
    bursty arrival trace gives continuous mode a lower p95 queue delay
    than fixed-drain at equal offered load/throughput — fixed-drain
    stragglers wait out a whole burst period for their bucket to fill."""
    reqs = _trace(24, seed=12)
    arr = bursty_arrivals(reqs, burst_size=6, burst_gap=2.0)

    done_c = ServingEngine(_tiny_system(tiny_diffusion_backend, 4),
                           max_batch=4).run(arr, mode="continuous")
    done_f = ServingEngine(_tiny_system(tiny_diffusion_backend, 4),
                           max_batch=4).run(arr, mode="drain")

    assert len(done_c) == len(done_f) == len(reqs)   # equal offered load
    qc = np.array([c.queue_delay for c in done_c])
    qf = np.array([c.queue_delay for c in done_f])
    assert np.percentile(qc, 95) < np.percentile(qf, 95)
    # throughput (served/makespan on the shared virtual clock) stays equal
    # within the tail-service wiggle: both serve every burst before the
    # next one lands
    rps_c = len(done_c) / max(c.finished_at for c in done_c)
    rps_f = len(done_f) / max(c.finished_at for c in done_f)
    assert rps_c == pytest.approx(rps_f, rel=0.5)
