"""Request-trace generator properties + the train CLI's restart path."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.trace import RequestTrace


def test_trace_deterministic():
    a = [r.prompt for r in RequestTrace(seed=5).generate(50)]
    b = [r.prompt for r in RequestTrace(seed=5).generate(50)]
    assert a == b


def test_trace_zipf_concentration():
    """Zipf law: the head of the popularity distribution dominates."""
    reqs = [r.prompt for r in RequestTrace(seed=2, zipf_a=1.4,
                                           repeat_rate=0.0).generate(400)]
    from collections import Counter
    counts = Counter(reqs).most_common()
    top10 = sum(c for _, c in counts[:10])
    assert top10 > 0.35 * len(reqs)


def test_trace_repeats_marked():
    reqs = list(RequestTrace(seed=3, repeat_rate=0.5).generate(200))
    repeats = [r for r in reqs if r.is_repeat]
    assert len(repeats) > 40
    # a repeat echoes the previous prompt verbatim
    for i, r in enumerate(reqs):
        if r.is_repeat and i > 0:
            assert r.prompt == reqs[i - 1].prompt
            break


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 60), seed=st.integers(0, 20))
def test_trace_total_function(n, seed):
    reqs = list(RequestTrace(seed=seed, n_specs=50).generate(n))
    assert len(reqs) == n
    assert all(r.prompt for r in reqs)


def test_trace_drift_changes_popularity():
    """Topic drift rotates which scenes are popular across windows."""
    trace = RequestTrace(seed=7, drift_every=100, repeat_rate=0.0)
    reqs = [r.prompt for r in trace.generate(400)]
    from collections import Counter
    first = set(p for p, _ in Counter(reqs[:100]).most_common(5))
    last = set(p for p, _ in Counter(reqs[300:]).most_common(5))
    assert first != last


def test_train_cli_failure_restart(tmp_path):
    """The launch/train driver: inject a failure, restart, finish —
    the operational fault-tolerance story end-to-end."""
    import sys
    from repro.launch import train as train_cli

    ckpt = str(tmp_path / "ckpt")
    argv = sys.argv
    try:
        sys.argv = ["train", "--arch", "sd15-small", "--steps", "8",
                    "--ckpt-every", "4", "--ckpt-dir", ckpt,
                    "--fail-at", "6", "--fresh"]
        with pytest.raises(Exception):
            train_cli.main()
        # restart picks up from the step-4 checkpoint and completes
        sys.argv = ["train", "--arch", "sd15-small", "--steps", "8",
                    "--ckpt-every", "4", "--ckpt-dir", ckpt]
        assert train_cli.main() == 0
    finally:
        sys.argv = argv
