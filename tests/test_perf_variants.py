"""§Perf variants must be numerically faithful to the baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer.lm import LMConfig, init_lm, lm_loss
from repro.runtime.steps import build_cell_program
from repro.configs import get_arch, get_shape


@pytest.mark.parametrize("tie", [False, True])
@pytest.mark.parametrize("chunks", [2, 4])
def test_chunked_ce_matches_reference(tie, chunks):
    cfg = LMConfig(vocab=64, n_layers=2, d_model=32, n_heads=4,
                   n_kv_heads=2, head_dim=8, d_ff=64, tie_embeddings=tie,
                   max_seq=32)
    params = init_lm(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    ref, _ = lm_loss(params, cfg, toks[:, :-1], toks[:, 1:])
    got, _ = lm_loss(params, cfg, toks[:, :-1], toks[:, 1:],
                     vocab_chunks=chunks)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_chunked_ce_grads_match():
    cfg = LMConfig(vocab=32, n_layers=1, d_model=16, n_heads=2,
                   n_kv_heads=2, head_dim=8, d_ff=32, max_seq=16)
    params = init_lm(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab)

    g_ref = jax.grad(lambda p: lm_loss(p, cfg, toks[:, :-1],
                                       toks[:, 1:])[0])(params)
    g_chk = jax.grad(lambda p: lm_loss(p, cfg, toks[:, :-1], toks[:, 1:],
                                       vocab_chunks=4)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_chk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_options_thread_through_builder():
    arch = get_arch("qwen2-0.5b")
    cell = get_shape("lm", "train_4k")
    prog = build_cell_program(arch, cell, reduced=True,
                              options={"vocab_chunks": 2,
                                       "microbatches": 1})
    state = prog.init_fn(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1),
                                          prog.args_sds[1]["tokens"].shape,
                                          0, 32)}
    new_state, metrics = jax.jit(prog.step_fn)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert prog.meta["n_micro"] == 1
