"""Algorithm 1 routing, Eq. 7/8 models, and the end-to-end CacheGenius
orchestrator over a request trace."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.latency_model import CostModel, LatencyModel
from repro.core.policy import GenerationPolicy, Route, select_reference
from repro.core.system import CacheGenius, GenerationBackend
from repro.core.trace import RequestTrace
from repro.data.synthetic import caption_of, render_caption
from repro.launch.serve import build_system


# ---------------------------------------------------------------------------
# Algorithm 1 policy
# ---------------------------------------------------------------------------


def test_route_thresholds_exact():
    pol = GenerationPolicy(lo=0.4, hi=0.5)
    assert pol.route(0.51) is Route.HIT_RETURN
    assert pol.route(0.50) is Route.IMG2IMG     # inclusive upper band edge
    assert pol.route(0.45) is Route.IMG2IMG
    assert pol.route(0.40) is Route.IMG2IMG     # inclusive lower band edge
    assert pol.route(0.39) is Route.TXT2IMG


def test_steps_per_route():
    pol = GenerationPolicy(steps_full=30, steps_ref=20)
    assert pol.steps_for(Route.HIT_RETURN) == 0
    assert pol.steps_for(Route.IMG2IMG) == 20
    assert pol.steps_for(Route.TXT2IMG) == 30


@settings(max_examples=50, deadline=None)
@given(clip=st.floats(0, 1), pick=st.floats(0, 1))
def test_composite_score_stays_in_unit_interval(clip, pick):
    s = GenerationPolicy().composite_score(clip, pick)
    assert 0.0 <= s <= 1.0


def test_select_reference():
    assert select_reference(np.array([])) == -1
    assert select_reference(np.array([0.1, 0.9, 0.3])) == 1


# ---------------------------------------------------------------------------
# Eq. 8 latency + cost models
# ---------------------------------------------------------------------------


def test_latency_eq8_structure():
    lm = LatencyModel(t_retrieve=0.05, t_return=0.02, t_noise=0.005,
                      t_step=0.06)
    base = lm.t_embed + lm.t_schedule + lm.t_retrieve
    assert lm.latency(Route.HIT_RETURN, 0) == pytest.approx(base + 0.02)
    assert lm.latency(Route.IMG2IMG, 20) == pytest.approx(
        base + 0.005 + 20 * 0.06)
    assert lm.latency(Route.TXT2IMG, 30) == pytest.approx(base + 30 * 0.06)
    # K < N  ⇒  img2img strictly cheaper than txt2img (the paper's premise)
    assert lm.latency(Route.IMG2IMG, 20) < lm.latency(Route.TXT2IMG, 30)


def test_latency_node_speed_scaling():
    lm = LatencyModel()
    fast = lm.latency(Route.TXT2IMG, 30, node_speed=2.0)
    slow = lm.latency(Route.TXT2IMG, 30, node_speed=0.5)
    assert fast < slow


def test_cost_model_accumulates():
    cm = CostModel()
    cm.charge(0, gpu_seconds=3600.0)          # 1 GPU-hour on the 4090D
    cm.charge(3, gpu_seconds=3600.0)          # 1 GPU-hour on the 2070S
    cost = cm.total_cost()
    assert cost == pytest.approx(0.28 + 0.084, rel=1e-6)


# ---------------------------------------------------------------------------
# end-to-end orchestrator
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def system():
    sys_, _, _, _ = build_system(n_nodes=4, corpus_n=300,
                                 capacity_per_node=200)
    return sys_


def test_serve_trace_routes_and_stats(system):
    trace = RequestTrace(seed=3, n_specs=120)
    for i, req in enumerate(trace.generate(120)):
        res = system.serve(req.prompt, seed=i,
                           quality_tier=req.quality_tier)
        assert res.image is not None
        assert res.latency > 0
    st_ = system.stats
    assert st_.requests == 120
    # the corpus covers the trace: most requests must avoid full generation
    assert st_.hit_rate > 0.5
    assert len(st_.route_counts) >= 2


def test_serve_latency_beats_always_full(system):
    st_ = system.stats
    full = system.latency_model.latency(Route.TXT2IMG,
                                        system.policy.steps_full)
    assert np.mean(st_.latencies) < full


def test_node_failure_keeps_serving(system):
    system.fail_node(0)
    trace = RequestTrace(seed=9, n_specs=40)
    for i, req in enumerate(trace.generate(30)):
        res = system.serve(req.prompt, seed=i)
        assert res.node != 0 or res.fast_path == "history"


def test_maintenance_respects_capacity():
    sys_, _, _, _ = build_system(n_nodes=3, corpus_n=150,
                                 capacity_per_node=100)
    sys_.cache_capacity = 100
    evicted = sys_.maintain()
    assert sys_.total_size <= 100
    assert sum(len(v) for v in evicted.values()) == 150 - 100


def test_blob_store_sync_with_eviction():
    """Paper §IV-G: evicting a vector synchronously removes its image."""
    sys_, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                 capacity_per_node=60)
    before = len(sys_.blob_store)
    sys_.cache_capacity = 40
    sys_.maintain()
    assert len(sys_.blob_store) == before - (60 - 40)


def test_history_cache_invalidated_on_eviction():
    """Regression: a history-cache hit must never dereference a blob the
    LCU sweep deleted (found by fig19 under drift + tight capacity)."""
    sys_, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                 capacity_per_node=60)
    trace = RequestTrace(seed=21, n_specs=80, repeat_rate=0.3)
    reqs = list(trace.generate(40))
    for i, r in enumerate(reqs):
        sys_.serve(r.prompt, seed=i)
    sys_.cache_capacity = 30
    sys_.maintain()
    # replay the same prompts: history hits must still resolve
    for i, r in enumerate(reqs):
        res = sys_.serve(r.prompt, seed=100 + i)
        assert res.image is not None
