"""Docs stay truthful: internal links and referenced module paths in the
architecture/benchmark docs must resolve to real files.

This is the CI "docs check": `docs/ARCHITECTURE.md`, the top-level
`README.md`, and `benchmarks/README.md` are the repo's architecture
record — a link or module path that stops resolving means the record has
drifted from the code.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

DOCS = ("README.md", "docs/ARCHITECTURE.md", "benchmarks/README.md")

# referenced paths that are generated at run time, not checked in
_GENERATED_PREFIXES = ("experiments/", ".cache", "/tmp")


def _doc_text(doc: str) -> tuple[Path, str]:
    path = ROOT / doc
    assert path.is_file(), f"documented file {doc} is missing"
    return path, path.read_text()


@pytest.mark.parametrize("doc", DOCS)
def test_markdown_links_resolve(doc):
    path, text = _doc_text(doc)
    links = re.findall(r"\[[^\]]*\]\(([^)]+)\)", text)
    internal = [ln.split("#")[0] for ln in links
                if not ln.startswith(("http://", "https://", "#"))]
    assert internal, f"{doc} has no internal links to check"
    for link in internal:
        if not link:
            continue                      # pure-anchor link
        target = (path.parent / link).resolve()
        assert target.exists(), f"{doc}: broken link -> {link}"


@pytest.mark.parametrize("doc", DOCS)
def test_referenced_file_paths_exist(doc):
    _, text = _doc_text(doc)
    refs = re.findall(r"`([A-Za-z0-9_./-]+\.(?:py|md|ini|json))`", text)
    checked = 0
    for ref in refs:
        if ref.startswith(_GENERATED_PREFIXES):
            continue
        assert (ROOT / ref).is_file(), f"{doc}: missing file -> {ref}"
        checked += 1
    if doc != "README.md":
        assert checked, f"{doc} references no checkable file paths"


@pytest.mark.parametrize("doc", DOCS)
def test_referenced_module_paths_resolve(doc):
    """Dotted module references (`repro.launch.serve`, `benchmarks.run`)
    must map onto real source files under src/ or the repo root."""
    _, text = _doc_text(doc)
    mods = set(re.findall(r"`((?:repro|benchmarks)(?:\.\w+)+)`", text))
    for mod in mods:
        parts = mod.split(".")
        base = ROOT / "src" if parts[0] == "repro" else ROOT
        as_file = base.joinpath(*parts).with_suffix(".py")
        as_pkg = base.joinpath(*parts) / "__init__.py"
        assert as_file.is_file() or as_pkg.is_file(), \
            f"{doc}: module path does not resolve -> {mod}"


def test_architecture_doc_names_every_pipeline_stage():
    """The stage table in docs/ARCHITECTURE.md tracks the real pipeline."""
    from repro.core.pipeline import ServePipeline
    _, text = _doc_text("docs/ARCHITECTURE.md")
    for name in ServePipeline().stage_names:
        assert f"**{name}**" in text, f"stage {name} undocumented"


def test_benchmarks_readme_names_every_benchmark():
    """benchmarks/README.md documents every registered benchmark (and
    documents no phantom ones)."""
    import sys
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.paper_figures import ALL_BENCHMARKS, STACK_FREE
    finally:
        sys.path.pop(0)
    _, text = _doc_text("benchmarks/README.md")
    for name in ALL_BENCHMARKS:
        assert f"`{name}`" in text, f"benchmark {name} undocumented"
    for name in STACK_FREE:
        assert name in ALL_BENCHMARKS
