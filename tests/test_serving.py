"""Serving engine: AOT-precompiled diffusion backend, batching queue,
and the LM response cache (beyond-paper arch adaptation)."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.policy import GenerationPolicy
from repro.core.system import Route
from repro.launch.serve import build_system
from repro.models.diffusion import dit as dit_mod
from repro.models.diffusion import vae as vae_mod
from repro.runtime.serving import (DiffusionBackend, LMResponseCache,
                                   Request, ServingEngine)


@pytest.fixture(scope="module")
def tiny_backend(embedder_mod):
    dcfg = get_arch("sd15-small").make_config(None)
    net = dit_mod.init_dit(jax.random.key(0), dcfg.net)
    vae = vae_mod.init_vae(jax.random.key(1), dcfg.vae)
    return DiffusionBackend(
        net, dcfg.net, vae, dcfg.vae,
        embed_prompt=lambda p: embedder_mod.embed_text([p])[0])


@pytest.fixture(scope="module")
def embedder_mod():
    from repro.core.embeddings import ProxyClipEmbedder
    from repro.data.synthetic import render_caption
    return ProxyClipEmbedder(render_caption)


def test_backend_generates_correct_shapes(tiny_backend):
    img = tiny_backend.txt2img("a red circle", steps=3, seed=0)
    res = tiny_backend.vae_cfg.downsample * tiny_backend.net_cfg.img_res
    assert img.shape == (res, res, 3)
    ref = np.zeros((res, res, 3), np.float32)
    img2 = tiny_backend.img2img("a blue square", ref, steps=2, seed=1)
    assert img2.shape == (res, res, 3)


def test_backend_precompile_removes_cold_start(tiny_backend):
    tiny_backend.precompile(step_buckets=(2,), batch_buckets=(1,))
    keys = set(tiny_backend._compiled)
    assert ("txt2img", 2, 1) in keys and ("img2img", 2, 1) in keys
    # a precompiled call must not add a new bucket (no recompile)
    tiny_backend.txt2img("anything", steps=2, seed=0)
    assert set(tiny_backend._compiled) == keys


def test_backend_deterministic_in_seed(tiny_backend):
    a = tiny_backend.txt2img("a red circle", steps=2, seed=7)
    b = tiny_backend.txt2img("a red circle", steps=2, seed=7)
    np.testing.assert_array_equal(a, b)


def test_backend_batched_matches_sequential(tiny_backend):
    """Batched AOT calls reproduce per-request sampling: each element draws
    its initial noise from its own seed, so batching never changes an
    individual request's image (padding to the power-of-two bucket
    included — 3 requests run in the batch=4 bucket)."""
    prompts = ["a red circle", "a blue square", "a green triangle"]
    seeds = [5, 6, 7]
    seq = np.stack([tiny_backend.txt2img(p, 2, s)
                    for p, s in zip(prompts, seeds)])
    bat = tiny_backend.txt2img_batch(prompts, 2, seeds)
    assert bat.shape == seq.shape
    np.testing.assert_allclose(bat, seq, rtol=1e-5, atol=1e-5)

    refs = seq
    seq2 = np.stack([tiny_backend.img2img(p, r, 2, s)
                     for p, r, s in zip(prompts, refs, seeds)])
    bat2 = tiny_backend.img2img_batch(prompts, refs, 2, seeds)
    np.testing.assert_allclose(bat2, seq2, rtol=1e-5, atol=1e-5)


def test_backend_batched_seed_isolation(tiny_backend):
    """Distinct seeds in one batch give distinct images; the same seed in a
    different batch position gives the same image."""
    a = tiny_backend.txt2img_batch(["a red circle"] * 2, 2, [1, 2])
    assert np.abs(a[0] - a[1]).max() > 1e-6
    b = tiny_backend.txt2img_batch(["a red circle"] * 2, 2, [3, 1])
    np.testing.assert_allclose(b[1], a[0], rtol=1e-5, atol=1e-5)


def test_engine_drains_in_order():
    system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                   capacity_per_node=60)
    eng = ServingEngine(system, max_batch=4)
    prompts = [f"a {c} circle" for c in ("red", "blue", "green")] * 3
    for i, p in enumerate(prompts):
        eng.submit(p, seed=i)
    done = eng.drain()
    assert len(done) == len(prompts)
    assert [c.request.prompt for c in done] == prompts
    assert all(c.queue_delay >= 0 for c in done)


def test_engine_survives_node_failure():
    system, _, _, _ = build_system(n_nodes=3, corpus_n=90,
                                   capacity_per_node=60)
    eng = ServingEngine(system)
    eng.fail_node(1)
    for i in range(6):
        eng.submit(f"a small red circle {'x' * i}", seed=i)
    done = eng.drain()
    assert len(done) == 6


def test_fail_node_mid_trace_reroutes_batched_path():
    """Node failure in the middle of a batched drain sequence: every
    subsequent request must route off the dead node, and its VDB must
    never be touched again (no searches, no inserts, no access marks)."""
    from repro.core.trace import RequestTrace

    system, _, _, _ = build_system(n_nodes=3, corpus_n=120,
                                   capacity_per_node=120, seed=0)
    engine = ServingEngine(system, max_batch=8)
    reqs = list(RequestTrace(seed=1).generate(64))
    for i, r in enumerate(reqs[:32]):
        engine.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    engine.drain()

    dead = 1
    engine.fail_node(dead)
    db = system.dbs[dead]
    # failure recovery reassigns the dead shard to the survivors
    assert db.size == 0
    qc, ac = db.query_count, db.access_count.copy()

    for i, r in enumerate(reqs[32:]):
        engine.submit(r.prompt, seed=32 + i, quality_tier=r.quality_tier)
    done = engine.drain()
    assert len(done) == 32
    for c in done:
        assert c.result.node != dead        # history fast path reports -1
    assert db.query_count == qc             # no retrieval scans
    assert db.size == 0                     # no archives landed on it
    np.testing.assert_array_equal(db.access_count, ac)


# ---------------------------------------------------------------------------
# LM response cache
# ---------------------------------------------------------------------------


def _bow_embed(text):
    """Toy deterministic text embedding for the cache tests."""
    v = np.zeros(64, np.float32)
    for w in text.split():
        v[hash(w) % 64] += 1.0
    n = np.linalg.norm(v)
    return v / n if n else v


def test_lm_cache_hit_and_miss():
    cache = LMResponseCache(embed=_bow_embed, hit_threshold=0.99)
    assert cache.lookup("tell me about cats") is None
    cache.insert("tell me about cats", "cats are great")
    assert cache.lookup("tell me about cats") == "cats are great"
    assert cache.lookup("explain quantum computing") is None
    assert cache.hits == 1 and cache.misses == 2


def test_lm_cache_semantic_threshold():
    cache = LMResponseCache(embed=_bow_embed, hit_threshold=0.8)
    cache.insert("the red fox jumps high", "resp")
    # near-duplicate (shares most words) hits below-exact threshold
    assert cache.lookup("the red fox jumps") == "resp"


def test_lm_cache_capacity_eviction():
    cache = LMResponseCache(embed=_bow_embed, capacity=3)
    for i in range(5):
        cache.insert(f"prompt number {i} unique words {i}", f"r{i}")
    assert len(cache._responses) == 3
    assert cache._vecs.shape[0] == 3


def test_lm_cache_hit_miss_accounting_and_rate():
    cache = LMResponseCache(embed=_bow_embed, hit_threshold=0.99)
    assert cache.hit_rate == 0.0                      # no traffic yet
    assert cache.lookup("alpha beta gamma") is None   # miss on empty
    cache.insert("alpha beta gamma", "r0")
    assert cache.lookup("alpha beta gamma") == "r0"   # hit
    assert cache.lookup("delta epsilon zeta") is None  # miss below threshold
    assert (cache.hits, cache.misses) == (1, 2)
    assert cache.hit_rate == pytest.approx(1 / 3)
    # inserts never change the accounting
    cache.insert("delta epsilon zeta", "r1")
    assert (cache.hits, cache.misses) == (1, 2)


def _keyed_embed(table):
    def embed(text):
        return table.get(text, np.zeros(next(iter(table.values())).shape,
                                        np.float32))
    return embed


def test_lm_cache_threshold_boundary_is_inclusive():
    """The hit test is ``sim >= threshold``: a similarity EXACTLY at the
    threshold returns the cached response."""
    table = {"one": np.array([1.0, 0.0], np.float32),
             "two": np.array([0.0, 1.0], np.float32)}
    # orthogonal pair: cos = 0.0 == threshold -> hit
    cache = LMResponseCache(embed=_keyed_embed(table), hit_threshold=0.0)
    cache.insert("one", "r")
    assert cache.lookup("two") == "r"
    # identical pair: cos = 1.0 == threshold -> hit; below -> miss
    cache = LMResponseCache(embed=_keyed_embed(table), hit_threshold=1.0)
    cache.insert("one", "r")
    assert cache.lookup("one") == "r"
    assert cache.lookup("two") is None


def test_lm_cache_capacity_ring_keeps_newest():
    """The capacity ring drops the OLDEST entries; vectors and responses
    stay parallel so a surviving hit returns its own response."""
    dim = 8
    table = {f"p{i}": np.eye(dim, dtype=np.float32)[i] for i in range(dim)}
    cache = LMResponseCache(embed=_keyed_embed(table), capacity=3,
                            hit_threshold=0.99)
    for i in range(5):
        cache.insert(f"p{i}", f"r{i}")
    assert cache._vecs.shape[0] == 3 and len(cache._responses) == 3
    for i in (0, 1):                      # evicted: oldest two
        assert cache.lookup(f"p{i}") is None
    for i in (2, 3, 4):                   # survivors map to THEIR responses
        assert cache.lookup(f"p{i}") == f"r{i}"
