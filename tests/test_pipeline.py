"""Staged serve pipeline: stage structure, batch-first backend protocol,
vectorized composite scoring, and batch-amortised wall-latency accounting.

The batched-vs-sequential parity contract itself is pinned in
``test_batching.py``; this module covers the redesign's new surfaces.
"""
from __future__ import annotations

import sys

import numpy as np
import pytest

from repro.core.pipeline import (CallableBackend, GenerationBackend,
                                 ServePipeline)
from repro.core.policy import GenerationPolicy
from repro.core.trace import RequestTrace
from repro.launch.serve import build_system
from repro.runtime.serving import ServingEngine


# ---------------------------------------------------------------------------
# pipeline structure
# ---------------------------------------------------------------------------


def test_default_stage_names_and_order():
    assert ServePipeline().stage_names == [
        "Embed", "Schedule", "Retrieve", "Score", "Plan", "Generate",
        "Archive", "Finish"]


def test_serve_is_a_batch_of_one(monkeypatch):
    """``CacheGenius.serve`` must be a thin wrapper over ``serve_batch`` —
    no duplicated sequential routing path."""
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60, seed=0)
    seen = {}
    orig = system.serve_batch

    def spy(prompts, *, seeds=None, quality_tiers=None):
        seen["args"] = (list(prompts), seeds, quality_tiers)
        return orig(prompts, seeds=seeds, quality_tiers=quality_tiers)

    monkeypatch.setattr(system, "serve_batch", spy)
    res = system.serve("a small red circle", seed=3, quality_tier=True)
    assert seen["args"] == (["a small red circle"], [3], [True])
    assert res.image is not None


def test_request_states_carry_typed_plans():
    """Every request leaving the pipeline has a typed RequestState with a
    Plan of a known kind and a result."""
    system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                   capacity_per_node=80, seed=0)
    reqs = list(RequestTrace(seed=1).generate(16))
    states = system.pipeline.run(
        system, [r.prompt for r in reqs], seeds=list(range(16)),
        quality_tiers=[r.quality_tier for r in reqs])
    assert [s.index for s in states] == list(range(16))
    for s in states:
        assert s.pvec is not None and s.decision is not None
        assert s.plan is not None
        assert s.plan.kind in ("alias", "history", "cached", "gen")
        assert s.result is not None and s.result.image is not None
        if s.plan.kind == "alias":
            assert 0 <= s.plan.target < s.index


# ---------------------------------------------------------------------------
# vectorized composite scoring (acceptance: no per-candidate Python calls)
# ---------------------------------------------------------------------------


def _count_scalar_score_calls(system):
    calls = {"clip": 0, "pick": 0}
    emb = system.embedder
    orig_clip, orig_pick = emb.clip_score, emb.pick_score

    def clip(*a, **k):
        calls["clip"] += 1
        return orig_clip(*a, **k)

    def pick(*a, **k):
        calls["pick"] += 1
        return orig_pick(*a, **k)

    emb.clip_score, emb.pick_score = clip, pick
    return calls


def test_serve_path_issues_no_per_candidate_score_calls():
    system, _, _, _ = build_system(n_nodes=2, corpus_n=100,
                                   capacity_per_node=100, seed=0)
    calls = _count_scalar_score_calls(system)
    reqs = list(RequestTrace(seed=1).generate(32))
    for i in range(0, 32, 8):
        chunk = reqs[i:i + 8]
        system.serve_batch([r.prompt for r in chunk],
                           seeds=list(range(i, i + len(chunk))),
                           quality_tiers=[r.quality_tier for r in chunk])
    # retrieval-scored routes actually happened...
    assert system.stats.requests == 32
    assert max(system.stats.scores) > 0
    # ...yet composite scoring never dropped to scalar Python calls
    assert calls == {"clip": 0, "pick": 0}


def test_sequential_serve_also_vectorized():
    system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                   capacity_per_node=80, seed=0)
    calls = _count_scalar_score_calls(system)
    for i, r in enumerate(RequestTrace(seed=2).generate(12)):
        system.serve(r.prompt, seed=i)
    assert calls == {"clip": 0, "pick": 0}


def test_score_candidates_matches_scalar_scores(embedder, corpus):
    images, captions, _ = corpus
    ivecs = embedder.embed_image(images[:24])
    pvec = embedder.embed_text([captions[0]])[0]
    clips, picks = embedder.score_candidates(pvec, ivecs)
    for k in range(24):
        assert clips[k] == pytest.approx(
            embedder.clip_score(pvec, ivecs[k]), abs=1e-6)
        assert picks[k] == pytest.approx(
            embedder.pick_score(pvec, ivecs[k]), abs=1e-6)
    comp = GenerationPolicy().composite_scores(clips, picks)
    assert comp.shape == (24,)
    assert np.all((comp >= 0.0) & (comp <= 1.0))


def test_coalesced_requests_are_never_scored():
    """In-flight duplicates that alias onto an earlier batch member must
    not pay for candidate scoring (the Plan walk evaluates the lazy Score
    thunk only on the routes that read it).  Centroid mode — score-aware
    routing necessarily scores every request at schedule time (that IS
    its routing input); its call-count contract is pinned in
    ``tests/test_scheduling_score.py``."""
    system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                   capacity_per_node=80, seed=0,
                                   routing="centroid")
    calls = {"n": 0}
    orig = system.embedder.score_candidates

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    system.embedder.score_candidates = counting
    reqs = list(RequestTrace(seed=1).generate(40))
    states = []
    for i in range(0, 40, 8):
        chunk = reqs[i:i + 8]
        states.extend(system.pipeline.run(
            system, [r.prompt for r in chunk],
            seeds=list(range(i, i + len(chunk))),
            quality_tiers=[r.quality_tier for r in chunk]))
    scored = sum(1 for s in states
                 if s.plan.kind in ("cached", "gen") and s.plan.fast is None)
    skipped = len(states) - scored
    assert skipped > 0                  # the Zipf trace produces duplicates
    assert calls["n"] == scored         # and none of them were scored


def test_score_stage_falls_back_for_embedders_without_vectorized_entry():
    """Custom embedders lacking ``score_candidates`` still serve (per-
    candidate fallback), with identical routing."""

    class _NoVectorized:
        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, name):
            if name == "score_candidates":
                raise AttributeError(name)
            return getattr(self._inner, name)

    def run(wrap):
        system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                       capacity_per_node=80, seed=0)
        if wrap:
            system.embedder = _NoVectorized(system.embedder)
        reqs = list(RequestTrace(seed=4).generate(20))
        out = system.serve_batch([r.prompt for r in reqs],
                                 seeds=list(range(20)))
        return system, out

    s_vec, r_vec = run(False)
    s_fal, r_fal = run(True)
    for a, b in zip(r_vec, r_fal):
        assert (a.fast_path or a.route.value) == (b.fast_path or b.route.value)
        assert a.node == b.node
        assert a.score == pytest.approx(b.score, abs=1e-6)
    assert s_vec.stats.route_counts == s_fal.stats.route_counts


# ---------------------------------------------------------------------------
# batch-first GenerationBackend protocol
# ---------------------------------------------------------------------------


class _BatchOnlyBackend(GenerationBackend):
    """New-style backend: only the required batched surface implemented."""

    def txt2img_batch(self, prompts, steps, seeds):
        return np.stack([np.full((4, 4, 3), float(s), np.float32)
                         for s in seeds])

    def img2img_batch(self, prompts, references, steps, seeds):
        return np.asarray(references, np.float32) * 0.5


def test_scalar_entry_points_derive_from_batch():
    b = _BatchOnlyBackend()
    img = b.txt2img("x", 5, 3)
    assert img.shape == (4, 4, 3)
    np.testing.assert_array_equal(img, np.full((4, 4, 3), 3.0, np.float32))
    ref = np.ones((4, 4, 3), np.float32)
    np.testing.assert_array_equal(b.img2img("x", ref, 5, 0), ref * 0.5)


def test_scalar_only_subclass_batches_via_loop():
    """A migrating subclass that overrides ONLY the old scalar surface
    still serves: the batched entry points loop over it."""

    class _ScalarOnly(GenerationBackend):
        def txt2img(self, prompt, steps, seed):
            return np.full((2, 2, 3), float(seed), np.float32)

        def img2img(self, prompt, reference, steps, seed):
            return np.asarray(reference) + 1.0

    b = _ScalarOnly()
    out = b.txt2img_batch(["a", "b"], 4, [1, 2])
    assert out.shape == (2, 2, 2, 3)
    np.testing.assert_array_equal(out[1], np.full((2, 2, 3), 2.0))
    refs = np.zeros((2, 2, 2, 3), np.float32)
    np.testing.assert_array_equal(b.img2img_batch(["a", "b"], refs, 4,
                                                  [0, 0]), refs + 1.0)


def test_base_protocol_requires_batched_surface():
    with pytest.raises(NotImplementedError):
        GenerationBackend().txt2img_batch(["p"], 2, [0])
    with pytest.raises(NotImplementedError):
        GenerationBackend().img2img_batch(["p"], np.zeros((1, 2, 2, 3)), 2,
                                          [0])


def test_legacy_callable_adapter_scalar_only():
    """Pre-redesign dataclass form: scalar callables only — the adapter
    derives the batched surface as a per-request loop."""
    order = []

    def t2i(prompt, steps, seed):
        order.append(prompt)
        return np.full((2, 2, 3), float(seed), np.float32)

    def i2i(prompt, ref, steps, seed):
        return np.asarray(ref) + 1.0

    for ctor in (GenerationBackend, CallableBackend):
        order.clear()
        b = ctor(txt2img=t2i, img2img=i2i)
        out = b.txt2img_batch(["a", "b"], 4, [1, 2])
        assert out.shape == (2, 2, 2, 3) and order == ["a", "b"]
        np.testing.assert_array_equal(out[0], np.full((2, 2, 3), 1.0))
        np.testing.assert_array_equal(out[1], np.full((2, 2, 3), 2.0))
        refs = np.zeros((2, 2, 2, 3), np.float32)
        np.testing.assert_array_equal(
            b.img2img_batch(["a", "b"], refs, 4, [0, 0]), refs + 1.0)
        np.testing.assert_array_equal(b.txt2img("c", 1, 7),
                                      np.full((2, 2, 3), 7.0))
        np.testing.assert_array_equal(b.img2img("c", refs[0], 1, 7),
                                      refs[0] + 1.0)


def test_legacy_callable_adapter_prefers_batch_callables():
    def t2i(prompt, steps, seed):      # pragma: no cover - must not run
        raise AssertionError("scalar callable used on the batched path")

    def t2i_batch(prompts, steps, seeds):
        return np.zeros((len(prompts), 2, 2, 3), np.float32)

    b = GenerationBackend(txt2img=t2i, img2img=None, txt2img_batch=t2i_batch)
    assert b.txt2img_batch(["a", "b", "c"], 2, [0, 1, 2]).shape == (3, 2, 2, 3)


def test_diffusion_backend_is_a_generation_backend():
    from repro.runtime.serving import DiffusionBackend
    assert issubclass(DiffusionBackend, GenerationBackend)


# ---------------------------------------------------------------------------
# batch-amortised wall latency (ServeStats.batch_wall_latencies)
# ---------------------------------------------------------------------------


def test_wall_latency_is_batch_amortised():
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60, seed=0)
    reqs = list(RequestTrace(seed=1).generate(8))
    out = system.serve_batch([r.prompt for r in reqs], seeds=list(range(8)))
    assert len(system.stats.batch_wall_latencies) == 1
    total = system.stats.batch_wall_latencies[0]
    assert total > 0
    # every result reports the SAME amortised share, and shares sum back
    # to the batch total (old behaviour: each result reported the whole
    # batch's wall clock, inflating per-request latency by ~batch size)
    for r in out:
        assert r.wall_latency == pytest.approx(total / 8)
    assert sum(r.wall_latency for r in out) == pytest.approx(total)
    # a second micro-batch appends a second total
    system.serve_batch([reqs[0].prompt], seeds=[99])
    assert len(system.stats.batch_wall_latencies) == 2


def test_engine_drain_records_one_total_per_microbatch():
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60, seed=0)
    engine = ServingEngine(system, max_batch=4)
    for i, r in enumerate(RequestTrace(seed=2).generate(10)):
        engine.submit(r.prompt, seed=i)
    engine.drain()
    # 10 requests at max_batch=4 -> micro-batches of 4, 4, 2
    assert len(system.stats.batch_wall_latencies) == 3
    assert len(system.stats.wall_latencies) == 10
    assert sum(system.stats.wall_latencies) == pytest.approx(
        sum(system.stats.batch_wall_latencies))


# ---------------------------------------------------------------------------
# serve CLI: --max-batch / --batch flags
# ---------------------------------------------------------------------------


def test_serve_cli_max_batch_flag(capsys):
    from repro.launch import serve as serve_cli
    argv = sys.argv
    try:
        sys.argv = ["serve", "--requests", "24", "--nodes", "2",
                    "--max-batch", "1"]
        assert serve_cli.main() == 0
        seq = capsys.readouterr().out
        sys.argv = ["serve", "--requests", "24", "--nodes", "2",
                    "--batch", "6"]
        assert serve_cli.main() == 0
        bat = capsys.readouterr().out
    finally:
        sys.argv = argv
    assert "wall latency" in seq and "max_batch=1" in seq
    assert "max_batch=6" in bat

    def grab(out, key):
        line = next(ln for ln in out.splitlines() if ln.startswith(key))
        return line.split(":", 1)[1]

    # batch=1 reproduces the sequential routing numbers exactly
    assert grab(seq, "route mix") == grab(bat, "route mix")
    assert grab(seq, "hit rate") == grab(bat, "hit rate")
    assert grab(seq, "mean latency") == grab(bat, "mean latency")
