"""Cache durability journal: WAL + snapshot recovery properties.

The contract pinned here (the crash-restart half of the fault-domain
tentpole): a ``VectorDB`` with a ``CacheJournal`` attached can be
rebuilt, at ANY point in an arbitrary interleaved mutation stream, to a
state bitwise-equal (every ``snapshot()`` array, ``np.testing`` strict)
to the live db — because every mutation's RAW arguments hit the WAL
before the slab changes, and replay re-runs the REAL mutation methods.
Randomized streams cover the add / evict / mark_access interleavings the
serving pipeline actually produces (FIFO overwrite under pressure,
evictions of already-dead slots, repeated accesses), crossed with
snapshot cadences including the pure-WAL ``snapshot_every=0`` mode.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core.journal import CacheJournal
from repro.core.vdb import VectorDB


DIM = 16


def _rand_op(db: VectorDB, rng: np.random.Generator, t: float) -> None:
    """One random mutation drawn from the live-serving distribution."""
    kind = rng.choice(["add", "add", "evict", "access", "access"])
    valid = np.flatnonzero(db.valid)
    if kind != "add" and len(valid) == 0:
        kind = "add"
    if kind == "add":
        n = int(rng.integers(1, 4))
        depths = (rng.integers(-1, 6, size=n)
                  if rng.random() < 0.5 else None)
        db.add(rng.standard_normal((n, DIM)).astype(np.float32),
               rng.standard_normal((n, DIM)).astype(np.float32),
               rng.integers(0, 10_000, size=n), t, depths=depths,
               source_ids=(rng.integers(0, 10_000, size=n)
                           if rng.random() < 0.5 else None))
    elif kind == "evict":
        k = int(rng.integers(1, min(3, len(valid)) + 1))
        slots = rng.choice(valid, size=k, replace=False)
        if rng.random() < 0.2:       # evicting a dead slot must replay too
            slots = np.append(slots, rng.integers(0, db.capacity))
        db.evict_slots(slots)
    else:
        k = int(rng.integers(1, min(4, len(valid)) + 1))
        db.mark_access(rng.choice(valid, size=k, replace=False), t)


def _assert_bitwise(db: VectorDB, rebuilt: VectorDB) -> None:
    live, rest = db.snapshot(), rebuilt.snapshot()
    assert set(live) == set(rest)
    for k in live:
        np.testing.assert_array_equal(live[k], rest[k], err_msg=k)


@pytest.mark.parametrize("seed,snapshot_every",
                         [(0, 8), (1, 8), (2, 5), (3, 64), (4, 0), (5, 0),
                          (6, 1), (7, 3)])
def test_replay_bitwise_equal_through_random_stream(tmp_path, seed,
                                                    snapshot_every):
    """The tentpole property: at every probe point of a random mutation
    stream — including mid-WAL, exactly on auto-snapshot boundaries, and
    in pure-WAL mode — replay reproduces the live db bitwise."""
    rng = np.random.default_rng(seed)
    db = VectorDB(DIM, 24, name="n0")
    j = CacheJournal(str(tmp_path), snapshot_every=snapshot_every)
    db.attach_journal(j)
    probes = set(rng.integers(1, 120, size=12).tolist()) | {119}
    for i in range(120):
        _rand_op(db, rng, t=float(i))
        if i in probes:
            _assert_bitwise(db, j.replay(DIM, 24, name="n0"))
    # a second replay from the same directory is just as equal (replay
    # mutates nothing on disk)
    _assert_bitwise(db, j.replay(DIM, 24, name="n0"))


def test_pre_attach_state_is_durable_via_base_snapshot(tmp_path):
    """Content loaded BEFORE the journal attaches (corpus pre-population)
    is captured by an explicit base snapshot; the WAL then only needs to
    cover post-attach mutations."""
    rng = np.random.default_rng(9)
    db = VectorDB(DIM, 16)
    db.add(rng.standard_normal((6, DIM)).astype(np.float32),
           rng.standard_normal((6, DIM)).astype(np.float32),
           np.arange(6), 0.0)                  # pre-attach: not journaled
    j = CacheJournal(str(tmp_path), snapshot_every=0)
    db.attach_journal(j)
    j.snapshot()                               # the durability baseline
    db.mark_access([0, 2], 1.0)
    db.evict_slots([1])
    _assert_bitwise(db, j.replay(DIM, 16))


def test_snapshot_requires_bound_db(tmp_path):
    j = CacheJournal(str(tmp_path))
    with pytest.raises(RuntimeError):
        j.snapshot()
    with pytest.raises(ValueError):
        CacheJournal(str(tmp_path), snapshot_every=-1)


def test_snapshot_prunes_absorbed_wal_and_old_snapshots(tmp_path):
    rng = np.random.default_rng(3)
    db = VectorDB(DIM, 16)
    j = CacheJournal(str(tmp_path), snapshot_every=0)
    db.attach_journal(j)
    for i in range(5):
        _rand_op(db, rng, t=float(i))
    first = j.snapshot()
    assert os.path.isdir(first)
    # records <= snapshot seq are gone, the snapshot is the restart base
    assert not [n for n in os.listdir(tmp_path) if n.startswith("wal_")]
    for i in range(3):
        _rand_op(db, rng, t=float(5 + i))
    assert len([n for n in os.listdir(tmp_path)
                if n.startswith("wal_")]) == 3
    second = j.snapshot()
    assert os.path.isdir(second) and not os.path.isdir(first)  # pruned
    _assert_bitwise(db, j.replay(DIM, 16))


def test_deferred_auto_snapshot_never_loses_boundary_record(tmp_path):
    """Regression for a real WAL bug: the mutation hook runs BEFORE the
    slab applies the record, so auto-snapshotting inside that hook
    published a state MISSING the boundary record's effect while pruning
    it from the WAL.  The publish is now deferred to the next mutation's
    hook; a stream cut exactly at the cadence boundary must replay the
    boundary mutation's effect."""
    db = VectorDB(DIM, 16)
    j = CacheJournal(str(tmp_path), snapshot_every=2)
    db.attach_journal(j)
    rng = np.random.default_rng(0)
    vec = rng.standard_normal((1, DIM)).astype(np.float32)
    db.add(vec, vec, [7], 0.0)          # record 1
    db.mark_access([0], 1.0)            # record 2: cadence boundary
    _assert_bitwise(db, j.replay(DIM, 16))     # access_count must be 2
    assert j.replay(DIM, 16).access_count[0] == 2
    db.mark_access([0], 2.0)            # record 3: triggers the deferred
    #                                     snapshot covering records 1-2
    snaps = [n for n in os.listdir(tmp_path) if n.startswith("snap_")]
    assert snaps == ["snap_0000000002"]
    _assert_bitwise(db, j.replay(DIM, 16))


def test_replay_ignores_inflight_tmp_artifacts(tmp_path):
    """A crash mid-publish leaves ``*.tmp`` artifacts; replay must treat
    them as absent (the atomic-rename discipline's whole point)."""
    rng = np.random.default_rng(4)
    db = VectorDB(DIM, 16)
    j = CacheJournal(str(tmp_path), snapshot_every=0)
    db.attach_journal(j)
    for i in range(4):
        _rand_op(db, rng, t=float(i))
    # fake a crash mid-snapshot-publish and mid-WAL-append
    os.makedirs(tmp_path / "snap_0000000099.tmp")
    np.savez(tmp_path / "snap_0000000099.tmp" / "arrays.npz",
             junk=np.zeros(3))
    with open(tmp_path / "wal_0000000099.npz.tmp", "wb") as f:
        f.write(b"torn write")
    _assert_bitwise(db, j.replay(DIM, 16))
    # a fresh journal over the same directory resumes from the real seq,
    # not the torn artifacts
    assert CacheJournal(str(tmp_path), snapshot_every=0).seq == j.seq


def test_replay_rejects_unknown_record_kind(tmp_path):
    db = VectorDB(DIM, 8)
    j = CacheJournal(str(tmp_path), snapshot_every=0)
    db.attach_journal(j)
    db.mark_access(np.array([], np.int64), 0.0)
    with open(tmp_path / "wal_0000000002.npz", "wb") as f:
        np.savez(f, kind=np.array("frobnicate"))
    with pytest.raises(ValueError, match="frobnicate"):
        j.replay(DIM, 8)


def test_restored_db_keeps_journaling_after_rejoin(tmp_path):
    """The rejoin path re-attaches the journal to the replayed db: a
    second crash after more traffic still replays bitwise."""
    rng = np.random.default_rng(5)
    db = VectorDB(DIM, 16)
    j = CacheJournal(str(tmp_path), snapshot_every=4)
    db.attach_journal(j)
    for i in range(10):
        _rand_op(db, rng, t=float(i))
    db.detach_journal()                          # crash #1
    db2 = j.replay(DIM, 16)
    _assert_bitwise(db, db2)
    db2.attach_journal(j)                        # rejoin
    for i in range(10, 20):
        _rand_op(db2, rng, t=float(i))
    _assert_bitwise(db2, j.replay(DIM, 16))      # crash #2 replays too
