"""Data layer (synthetic corpus, tokenizer, embeddings) and the prompt
optimizer — unit + hypothesis properties."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.prompt_optimizer import (PromptOptimizer, phrase_importance,
                                         split_phrases)
from repro.data.synthetic import (SceneSpec, caption_of, make_corpus,
                                  parse_caption, render_caption, render_scene)
from repro.data.tokenizer import HashTokenizer
from repro.utils import stable_hash


# ---------------------------------------------------------------------------
# synthetic corpus
# ---------------------------------------------------------------------------


def test_caption_parse_roundtrip():
    spec = SceneSpec("triangle", "blue", "navy", "large", "left")
    assert parse_caption(caption_of(spec)) == spec


def test_caption_parse_survives_phrase_reorder():
    """The prompt optimizer permutes phrases; the proxy embedder must
    still recover the scene (its cross-modal alignment depends on it)."""
    spec = SceneSpec("ring", "orange", "teal", "small", "right")
    cap = caption_of(spec)
    opt = PromptOptimizer()
    assert parse_caption(opt.optimize(cap)) == spec


def test_render_deterministic_and_bounded():
    spec = SceneSpec()
    a = render_scene(spec, 32)
    b = render_scene(spec, 32)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= -1.0 and a.max() <= 1.0


def test_corpus_deterministic():
    im1, cap1, _ = make_corpus(16, res=16, seed=5)
    im2, cap2, _ = make_corpus(16, res=16, seed=5)
    np.testing.assert_array_equal(im1, im2)
    assert cap1 == cap2


def test_structural_similarity_property(embedder):
    """The paper's §IV-C premise: same layout / different semantics scores
    higher than different layout (bird vs airplane example)."""
    same_shape_a = render_scene(SceneSpec("circle", "red", "black",
                                          "large", "center"), 32)
    same_shape_b = render_scene(SceneSpec("circle", "green", "black",
                                          "large", "center"), 32)
    diff = render_scene(SceneSpec("cross", "red", "black",
                                  "small", "left"), 32)
    va, vb, vd = embedder.embed_image(
        np.stack([same_shape_a, same_shape_b, diff]))
    assert float(va @ vb) > float(va @ vd)


def test_embedder_cross_modal_alignment(embedder, corpus):
    images, captions, _ = corpus
    iv = embedder.embed_image(images[:32])
    tv = embedder.embed_text(captions[:32])
    diag = np.mean([iv[i] @ tv[i] for i in range(32)])
    off = np.mean([iv[i] @ tv[(i + 7) % 32] for i in range(32)])
    assert diag > off + 0.2     # CLIP-like: matched pairs score higher


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------


def test_tokenizer_shapes_and_reserved_ids():
    tok = HashTokenizer(vocab_size=1000)
    out = tok.encode("a small red circle", max_len=10)
    assert out.shape == (10,)
    assert out[0] == tok.BOS
    assert (out >= 0).all() and (out < 1000).all()


@settings(max_examples=30, deadline=None)
@given(text=st.text(alphabet=st.characters(whitelist_categories=("Ll", "Zs")),
                    min_size=0, max_size=60),
       max_len=st.integers(4, 32))
def test_tokenizer_total_function(text, max_len):
    """Property: any text encodes to exactly max_len valid ids,
    deterministically."""
    tok = HashTokenizer(vocab_size=512)
    a = tok.encode(text, max_len=max_len)
    b = tok.encode(text, max_len=max_len)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (max_len,)
    assert (a < 512).all()


@settings(max_examples=30, deadline=None)
@given(word=st.text(alphabet="abcdefghij", min_size=1, max_size=12),
       mod=st.integers(2, 1 << 20))
def test_stable_hash_range(word, mod):
    h = stable_hash(word, mod)
    assert 0 <= h < mod
    assert h == stable_hash(word, mod)


# ---------------------------------------------------------------------------
# prompt optimizer (§IV-D)
# ---------------------------------------------------------------------------


def test_split_phrases():
    parts = split_phrases("a car, parked, the street, the rain")
    assert parts == ["a car", "parked", "the street", "the rain"]


def test_optimizer_preserves_content():
    opt = PromptOptimizer()
    prompt = "the street, the rain, a car, parked"
    out = opt.optimize(prompt)
    assert sorted(split_phrases(out)) == sorted(split_phrases(prompt))


def test_optimizer_orders_by_importance():
    opt = PromptOptimizer(attention_fn=lambda ph: np.arange(len(ph))[::-1])
    out = opt.optimize("first, second, third")
    assert out == "first, second, third"
    opt2 = PromptOptimizer(attention_fn=lambda ph: np.arange(len(ph)))
    assert opt2.optimize("first, second, third") == "third, second, first"


def test_stopwords_rank_low():
    assert phrase_importance("of the") < phrase_importance("crimson dragon")


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(["red circle", "blue square", "the park",
                                 "a storm", "golden ring"]),
                min_size=1, max_size=5, unique=True))
def test_optimizer_is_permutation(phrases):
    """Property: optimize() is a permutation of the input phrases."""
    opt = PromptOptimizer()
    prompt = ", ".join(phrases)
    out_parts = split_phrases(opt.optimize(prompt))
    assert sorted(out_parts) == sorted(split_phrases(prompt))
