"""HLO analyzers: collective parsing, loop-trip weighting, and the
instruction-level flop/byte model — validated on synthetic HLO and on a
real lowered program with known flop counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch import roofline

SYNTH_HLO = """
HloModule test

%cond.1 (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,64]{1,0} parameter(1)
  %ar = f32[128,64]{1,0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[]) tuple(%ni)
}

ENTRY %main (a: f32[16,32], b: f32[32,8]) -> f32[16,8] {
  %a = f32[16,32]{1,0} parameter(0)
  %b = f32[32,8]{1,0} parameter(1)
  %init = (s32[]) tuple(%zero)
  %w = (s32[]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[16,128]{1,0} all-gather(%a), replica_groups=[2,4]<=[8], dimensions={1}
  ROOT %d = f32[16,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_collective_stats_loop_weighting():
    st = roofline.collective_stats(SYNTH_HLO)
    # all-reduce inside the 10-trip loop: 128*64*4 bytes × 10
    # all-gather in entry: result 16*128*4, operand = result / group(4)
    ar = 128 * 64 * 4 * 10
    ag = 16 * 128 * 4 / 4
    assert st.by_op["all-reduce"] == pytest.approx(ar)
    assert st.by_op["all-gather"] == pytest.approx(ag)
    assert st.count == 11


def test_hlo_cost_dot_flops():
    cost = roofline.hlo_cost(SYNTH_HLO)
    # dot: 2 * 16*8 * 32
    assert cost.dot_flops == pytest.approx(2 * 16 * 8 * 32)


def test_shape_bytes_tuple_and_scalars():
    assert roofline._shape_bytes("f32[4,4]{1,0}") == 64
    assert roofline._shape_bytes("(f32[2], bf16[3,3])") == 8 + 18
    assert roofline._shape_bytes("pred[]") == 1
    assert roofline._shape_bytes("token[]") == 0


def test_hlo_cost_matches_known_matmul():
    """Real lowering: flops of a jitted matmul chain must match analytic."""
    def f(a, b, c):
        return (a @ b) @ c

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    c = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    hlo = jax.jit(f).lower(a, b, c).compile().as_text()
    cost = roofline.hlo_cost(hlo)
    want = 2 * 64 * 256 * 128 + 2 * 64 * 32 * 256
    assert cost.dot_flops == pytest.approx(want, rel=1e-6)


def test_hlo_cost_weights_scan_loops():
    """A lax.scan of K matmuls must report K × the single-iteration flops
    (this is exactly what XLA's own cost_analysis gets wrong)."""
    K = 7

    def f(x, ws):
        def body(h, w):
            return h @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((K, 32, 32), jnp.float32)
    hlo = jax.jit(f).lower(x, ws).compile().as_text()
    cost = roofline.hlo_cost(hlo)
    want = K * 2 * 32 * 32 * 32
    assert cost.dot_flops == pytest.approx(want, rel=0.01)


def test_hlo_cost_convolution():
    def f(x, k):
        return jax.lax.conv_general_dilated(
            x, k, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    x = jax.ShapeDtypeStruct((2, 16, 16, 8), jnp.float32)
    k = jax.ShapeDtypeStruct((3, 3, 8, 4), jnp.float32)
    hlo = jax.jit(f).lower(x, k).compile().as_text()
    cost = roofline.hlo_cost(hlo)
    want = 2 * (2 * 16 * 16 * 4) * (3 * 3 * 8)
    # CPU may rewrite convs; accept either the conv counter or dot rewrite
    got = cost.conv_flops + cost.dot_flops
    assert got == pytest.approx(want, rel=0.35)


def test_roofline_terms_dominance():
    cost = {"flops": 197e12, "bytes accessed": 1.0}
    coll = roofline.CollectiveStats()
    t = roofline.roofline_terms(cost, coll, chips=256, model_flops=197e12)
    assert t.dominant == "compute"
    assert t.compute_s == pytest.approx(1.0)
    cost2 = {"flops": 1.0, "bytes accessed": 819e9 * 2}
    t2 = roofline.roofline_terms(cost2, coll, chips=256, model_flops=1.0)
    assert t2.dominant == "memory"
    assert t2.memory_s == pytest.approx(2.0)


def test_group_size_parsing():
    assert roofline._group_size("replica_groups=[16,16]<=[256]") == 16
    assert roofline._group_size("replica_groups={{0,1,2,3}}") == 4
