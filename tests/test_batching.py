"""Batched end-to-end serving path vs the sequential loop.

Parity contract (documented on ``CacheGenius.serve_batch``): scheduling and
retrieval see the cache state at micro-batch entry, in-batch near-duplicate
prompts coalesce onto one generation, and archives land in submission
order — so on a fixed trace the batched drain must produce the same routes,
images, stats, and cache state as request-at-a-time ``serve``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.scheduler import NodeInfo, RequestScheduler
from repro.core.trace import RequestTrace
from repro.core.vdb import VectorDB
from repro.launch.serve import build_system
from repro.runtime.serving import ServingEngine


def _unit(rng, n, d):
    v = rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# VectorDB.search_batch vs per-query search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True])
def test_search_batch_matches_search(use_pallas):
    """Same slots, same scores as per-query `search` — including slots
    invalidated by eviction (masked) between inserts."""
    rng = np.random.default_rng(0)
    db = VectorDB(dim=16, capacity=64, use_pallas=use_pallas)
    img = _unit(rng, 20, 16)
    txt = _unit(rng, 20, 16)
    slots = db.add(img, txt, np.arange(20), t=0.0)
    db.evict_slots(slots[5:9])          # masked/invalid slots in the slab
    queries = _unit(rng, 5, 16)
    rows = db.search_batch(queries, 6)
    assert len(rows) == 5
    for q, (s_b, sl_b) in zip(queries, rows):
        s_1, sl_1 = db.search(q, 6)
        np.testing.assert_array_equal(sl_b, sl_1)
        np.testing.assert_allclose(s_b, s_1, rtol=1e-5, atol=1e-6)
        assert db.valid[sl_b].all()     # never returns an invalid slot
        assert list(s_b) == sorted(s_b, reverse=True)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_search_batch_empty_db(use_pallas):
    rng = np.random.default_rng(1)
    db = VectorDB(dim=16, capacity=32, use_pallas=use_pallas)
    rows = db.search_batch(_unit(rng, 3, 16), 4)
    assert all(len(s) == 0 and len(sl) == 0 for s, sl in rows)
    # per-query search agrees (the Pallas sentinel must not leak as a hit)
    s, sl = db.search(_unit(rng, 1, 16)[0], 4)
    assert len(s) == 0 and len(sl) == 0


def test_search_batch_single_index_and_query_count():
    rng = np.random.default_rng(2)
    db = VectorDB(dim=8, capacity=16)
    v = _unit(rng, 6, 8)
    w = _unit(rng, 6, 8)
    db.add(v, w, np.arange(6), t=0.0)
    before = db.query_count
    queries = _unit(rng, 4, 8)
    for index in ("img", "txt", "both"):
        rows = db.search_batch(queries, 3, index=index)
        for q, (s_b, sl_b) in zip(queries, rows):
            s_1, sl_1 = db.search(q, 3, index=index)
            np.testing.assert_array_equal(sl_b, sl_1)
            np.testing.assert_allclose(s_b, s_1, rtol=1e-5, atol=1e-6)
    # batched scans count one query per request, like the sequential path
    assert db.query_count == before + 3 * 4 + 3 * 4


# ---------------------------------------------------------------------------
# RequestScheduler.schedule_batch vs sequential schedule
# ---------------------------------------------------------------------------


def test_schedule_batch_matches_sequential(fleet):
    dbs, _, _, img_vecs, _, _ = fleet

    def fresh():
        s = RequestScheduler(nodes=[NodeInfo(i, speed=sp) for i, sp in
                                    enumerate([1.0, 2.0, 0.5, 1.0])])
        s.record_result(img_vecs[0], payload_id=777)   # committed history
        return s

    vecs = np.stack([img_vecs[0],        # history hit
                     img_vecs[3],        # normal routing
                     img_vecs[4],        # quality repeat -> priority
                     img_vecs[5]])
    tiers = [False, False, True, False]
    keys = [11, 22, 33, 44]

    seq = fresh()
    seq._prompt_counts[33] = 1           # "33" already seen once
    expected = []
    for v, t, k in zip(vecs, tiers, keys):
        d = seq.schedule(v, dbs, quality_tier=t, prompt_key=k)
        seq.complete(d.node)
        expected.append(d)

    bat = fresh()
    bat._prompt_counts[33] = 1
    got = bat.schedule_batch(vecs, dbs, quality_tiers=tiers, prompt_keys=keys)

    for e, g in zip(expected, got):
        assert (e.node, e.fast_path, e.history_payload) == \
            (g.node, g.fast_path, g.history_payload)
    assert got[0].fast_path == "history" and got[2].fast_path == "priority"
    assert bat._prompt_counts == seq._prompt_counts
    assert bat.history_hits == seq.history_hits
    # batch is scheduled-and-completed atomically: no residual queue depth
    assert all(n.queue_depth == 0 for n in bat.nodes)


# ---------------------------------------------------------------------------
# CacheGenius.serve_batch vs sequential serve on a fixed Zipf trace
# ---------------------------------------------------------------------------


def _build_system():
    system, _, _, _ = build_system(n_nodes=3, corpus_n=120,
                                   capacity_per_node=120, seed=0)
    return system


def _run_sequential(reqs):
    system = _build_system()
    results = [system.serve(r.prompt, seed=i, quality_tier=r.quality_tier)
               for i, r in enumerate(reqs)]
    return system, results


def _run_batched(reqs, batch_size):
    system = _build_system()
    results = []
    for i in range(0, len(reqs), batch_size):
        chunk = reqs[i:i + batch_size]
        results.extend(system.serve_batch(
            [r.prompt for r in chunk],
            seeds=list(range(i, i + len(chunk))),
            quality_tiers=[r.quality_tier for r in chunk]))
    return system, results


def _trace(n):
    return list(RequestTrace(seed=1).generate(n))


def test_serve_batch_parity_with_sequential():
    """The acceptance gate: batched results (routes, hit counts, images,
    evicted/archived cache state) match the sequential serve loop."""
    reqs = _trace(64)
    s_seq, r_seq = _run_sequential(reqs)
    s_bat, r_bat = _run_batched(reqs, batch_size=8)

    for a, b in zip(r_seq, r_bat):
        assert (a.fast_path or a.route.value) == (b.fast_path or b.route.value)
        assert a.node == b.node
        assert a.steps == b.steps
        np.testing.assert_array_equal(a.image, b.image)

    assert s_seq.stats.route_counts == s_bat.stats.route_counts
    assert s_seq.stats.cache_hits == s_bat.stats.cache_hits
    assert s_seq.stats.reference_hits == s_bat.stats.reference_hits
    assert s_seq.stats.hit_rate == pytest.approx(s_bat.stats.hit_rate)

    for db_a, db_b in zip(s_seq.dbs, s_bat.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)
        np.testing.assert_array_equal(db_a.insert_time, db_b.insert_time)
        np.testing.assert_array_equal(db_a.access_count, db_b.access_count)
        np.testing.assert_array_equal(db_a.last_access, db_b.last_access)

    assert len(s_seq.blob_store) == len(s_bat.blob_store)
    assert s_seq.scheduler._hist_payloads == s_bat.scheduler._hist_payloads
    assert s_seq.scheduler._prompt_counts == s_bat.scheduler._prompt_counts
    assert s_seq.scheduler.history_hits == s_bat.scheduler.history_hits


def test_serve_batch_of_one_equals_serve():
    reqs = _trace(12)
    s_seq, r_seq = _run_sequential(reqs)
    s_bat, r_bat = _run_batched(reqs, batch_size=1)
    for a, b in zip(r_seq, r_bat):
        assert (a.fast_path or a.route.value) == (b.fast_path or b.route.value)
        assert a.score == pytest.approx(b.score)
        np.testing.assert_array_equal(a.image, b.image)
    assert s_seq.stats.route_counts == s_bat.stats.route_counts


def test_serve_batch_without_scheduler():
    """Round-robin node assignment must survive batching.  Without the
    scheduler there is no history cache to coalesce in-batch duplicates
    through (sequential duplicates hit via *retrieval* of the fresh
    archive), so this mode's parity holds for distinct prompts — use a
    de-duplicated trace."""
    def build():
        system, _, _, _ = build_system(n_nodes=2, corpus_n=80,
                                       capacity_per_node=80,
                                       use_scheduler=False, seed=0)
        return system

    reqs, seen = [], set()
    for r in RequestTrace(seed=1, repeat_rate=0.0).generate(400):
        if r.prompt not in seen:
            seen.add(r.prompt)
            reqs.append(r)
        if len(reqs) == 20:
            break
    seq = build()
    r_seq = [seq.serve(r.prompt, seed=i) for i, r in enumerate(reqs)]
    bat = build()
    r_bat = []
    for i in range(0, 20, 5):
        chunk = reqs[i:i + 5]
        r_bat.extend(bat.serve_batch([r.prompt for r in chunk],
                                     seeds=list(range(i, i + len(chunk)))))
    for a, b in zip(r_seq, r_bat):
        assert a.node == b.node
        assert (a.fast_path or a.route.value) == (b.fast_path or b.route.value)
    assert seq.stats.route_counts == bat.stats.route_counts


def test_serve_batch_empty():
    assert _build_system().serve_batch([]) == []


def test_engine_batched_drain_matches_sequential_loop():
    """ServingEngine.drain (micro-batched) == the request-at-a-time loop."""
    reqs = _trace(32)
    s_seq, r_seq = _run_sequential(reqs)

    system = _build_system()
    engine = ServingEngine(system, max_batch=8)
    for i, r in enumerate(reqs):
        engine.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    done = engine.drain()

    assert [c.request.prompt for c in done] == [r.prompt for r in reqs]
    for a, c in zip(r_seq, done):
        assert (a.fast_path or a.route.value) == \
            (c.result.fast_path or c.result.route.value)
        np.testing.assert_array_equal(a.image, c.result.image)
    assert s_seq.stats.route_counts == system.stats.route_counts


def test_serve_batch_maintenance_and_history_consistency():
    """When maintenance fires inside a batched drain, evicted blobs must
    disappear from the history cache too — a later duplicate prompt must
    not dereference a deleted image."""
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60, seed=0)
    system.cache_capacity = 70          # force evictions
    system.maintenance_interval = 16
    reqs = _trace(48)
    for i in range(0, len(reqs), 8):
        chunk = reqs[i:i + 8]
        system.serve_batch([r.prompt for r in chunk],
                           seeds=list(range(i, i + len(chunk))))
    assert system.total_size <= system.cache_capacity
    blob_ids = set(system.blob_store._blobs)
    assert all(p in blob_ids for p in system.scheduler._hist_payloads)
    # replay every prompt once more — history hits must all resolve
    for i in range(0, len(reqs), 8):
        chunk = reqs[i:i + 8]
        out = system.serve_batch([r.prompt for r in chunk],
                                 seeds=list(range(i, i + len(chunk))))
        assert len(out) == len(chunk)
