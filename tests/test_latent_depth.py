"""Latent-depth reference caching (PR 6) + the accounting/eviction bugfix
sweep that rides along.

Tentpole coverage: the depth schedule's band boundaries, depth metadata on
the VDB slabs (fused-scan parity included), per-depth eviction utility
under one C_max, the k=0 resume parity invariant on both backends, and the
end-to-end strictly-fewer-steps win on the band-mutation workload.

Bugfix sweep coverage: scheduler strict schedule/complete pairing (no
silent clamp), fresh-entry access_count=1 under LFU, CostModel rate
validation for non-default fleets, and the resumed-path Eq. 8 latency
accounting (t_latent replaces t_noise).
"""
from __future__ import annotations

import warnings

import numpy as np
import pytest

from repro.core.latency_model import CostModel, LatencyModel
from repro.core.lcu import LCUPolicy, LFUPolicy
from repro.core.policy import GenerationPolicy, Route
from repro.core.scheduler import NodeInfo, RequestScheduler
from repro.core.trace import band_mutation_trace
from repro.core.vdb import VectorDB
from repro.launch.serve import NullBackend, build_system


# ---------------------------------------------------------------------------
# depth schedule (policy layer)
# ---------------------------------------------------------------------------


def test_default_latent_depths_quartiles():
    pol = GenerationPolicy(steps_ref=20)
    assert pol.default_latent_depths() == (5, 10, 15)
    # tiny chains: quartiles that collapse to 0 are dropped, dupes merged
    assert GenerationPolicy(steps_ref=2).default_latent_depths() == (1,)
    assert GenerationPolicy(steps_ref=4).default_latent_depths() == (1, 2, 3)


def test_resume_depth_band_boundaries():
    """[lo, hi] splits into len(depths)+1 equal sub-bands over the levels
    (0,) + latent_depths; an exact sub-band edge belongs to the DEEPER
    side, and scores outside the band clamp to the extremes.  Edge
    semantics are pinned on a [0, 1] band where the sub-band boundaries
    (0.25, 0.5, 0.75) are exactly representable floats."""
    unit = GenerationPolicy(lo=0.0, hi=1.0, steps_ref=20,
                            latent_depths=(5, 10, 15))
    assert unit.resume_depth(0.0) == 0       # band floor
    assert unit.resume_depth(0.249) == 0     # just inside first sub-band
    assert unit.resume_depth(0.25) == 5      # exact edge -> deeper side
    assert unit.resume_depth(0.5) == 10
    assert unit.resume_depth(0.75) == 15
    assert unit.resume_depth(1.0) == 15      # band ceiling
    # paper-default band: clamping + interior sub-band membership
    pol = GenerationPolicy(lo=0.4, hi=0.5, steps_ref=20,
                           latent_depths=(5, 10, 15))
    assert pol.resume_depth(0.30) == 0       # below band: shallowest
    assert pol.resume_depth(0.41) == 0
    assert pol.resume_depth(0.46) == 10
    assert pol.resume_depth(0.49) == 15
    assert pol.resume_depth(0.90) == 15      # above band: deepest
    # no schedule configured -> always a full-chain reference
    assert GenerationPolicy().resume_depth(0.45) == 0


def test_steps_for_resume_never_negative():
    pol = GenerationPolicy(steps_ref=20)
    assert pol.steps_for_resume(0) == 20
    assert pol.steps_for_resume(5) == 15
    assert pol.steps_for_resume(20) == 0
    assert pol.steps_for_resume(25) == 0


def test_latent_depths_validation_at_build():
    with pytest.raises(ValueError):
        build_system(n_nodes=2, corpus_n=16, latent_depths=(0,))
    with pytest.raises(ValueError):
        build_system(n_nodes=2, corpus_n=16, latent_depths=(5, 20))
    system, *_ = build_system(n_nodes=2, corpus_n=16, latent_depths=True)
    assert system.latent_depths == (5, 10, 15)
    assert system.policy.latent_depths == (5, 10, 15)
    system, *_ = build_system(n_nodes=2, corpus_n=16,
                              latent_depths=[15, 5, 5])
    assert system.latent_depths == (5, 15)   # sorted, deduped


# ---------------------------------------------------------------------------
# VDB depth metadata
# ---------------------------------------------------------------------------


def _vecs(n, dim=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, dim)).astype(np.float32)


def test_vdb_depth_metadata_defaults_and_roundtrip():
    db = VectorDB(dim=8, capacity=16)
    v = _vecs(3)
    db.add(v, v, np.array([10, 11, 12]), t=1.0)
    slots = np.flatnonzero(db.valid)
    # default: every entry is a finished image that is its own source
    assert (db.depth[slots] == -1).all()
    assert set(db.source_id[slots]) == {10, 11, 12}

    w = _vecs(2, seed=1)
    s2 = db.add(w, w, np.array([20, 21]), t=2.0,
                depths=np.array([5, 10]), source_ids=np.array([10, 10]))
    assert list(db.depth[s2]) == [5, 10]
    assert list(db.source_id[s2]) == [10, 10]

    restored = VectorDB.restore(db.dim, db.capacity, db.snapshot())
    np.testing.assert_array_equal(restored.depth, db.depth)
    np.testing.assert_array_equal(restored.source_id, db.source_id)

    # eviction resets the metadata so freed slots can't alias stale depths
    db.evict_slots(s2)
    assert (db.depth[s2] == -1).all()
    assert (db.source_id[s2] == -1).all()


def test_vdb_fresh_entry_access_count_is_one():
    """Regression: fresh entries used to start at access_count 0 and tied
    as most-evictable under LFU, so a sweep right after insertion evicted
    the newest rows first."""
    db = VectorDB(dim=8, capacity=8)
    v = _vecs(2)
    slots = db.add(v, v, np.array([1, 2]), t=0.0)
    assert (db.access_count[slots] == 1).all()


def test_fused_scan_parity_with_depth_rows():
    """search_batch over a db holding mixed finished/latent rows must be
    bit-identical to a standalone restore of the same snapshot — the depth
    and source_id columns are host-side metadata the fused scan never
    consumes."""
    system, emb, _, _ = build_system(n_nodes=2, corpus_n=32,
                                     capacity_per_node=600, seed=0,
                                     latent_depths=True)
    for i, r in enumerate(band_mutation_trace(40, band_fraction=0.5, seed=0)):
        system.serve(r.prompt, seed=i)
    assert any((db.depth[db.valid] >= 0).any() for db in system.dbs)
    q = emb.embed_text(["a medium red circle at the center on a black "
                        "background", "a small blue square at the left on "
                        "a gray background"])
    for db in system.dbs:
        solo = VectorDB.restore(db.dim, db.capacity, db.snapshot())
        got = db.search_batch(q, 4)
        want = solo.search_batch(q, 4)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# per-depth eviction under one C_max
# ---------------------------------------------------------------------------


def test_per_depth_eviction_protects_deep_latents_on_ties():
    """Identical vectors make every LCU distance tie; the per-depth
    discount must then evict finished images before deep latents (deep
    resumes save the most denoising steps per cached row)."""
    db = VectorDB(dim=8, capacity=16)
    v = np.ones((6, 8), np.float32)
    db.add(v, v, np.arange(100, 106), t=1.0,
           depths=np.array([-1, -1, -1, 5, 10, 15]),
           source_ids=np.array([100, 101, 102, 100, 100, 100]))
    evicted = LCUPolicy().maintain([db], c_max=3)
    gone = set(evicted[0])
    assert gone == {100, 101, 102}           # all finished images
    keep = np.flatnonzero(db.valid)
    assert sorted(db.depth[keep]) == [5, 10, 15]


def test_depth_discount_noop_without_latents():
    """With no latent rows anywhere the depthed scores are bit-identical
    to the raw policy sort."""
    db = VectorDB(dim=8, capacity=16)
    v = _vecs(4, seed=3)
    db.add(v, v, np.arange(4), t=1.0)
    pol = LCUPolicy()
    np.testing.assert_array_equal(pol.depth_scores(db, -1), pol.scores(db))


def test_lfu_recency_tiebreak_evicts_older_insert():
    """Equal access counts break toward evicting the OLDER insert; the
    bounded recency term must never flip a genuine count ordering."""
    db = VectorDB(dim=8, capacity=16)
    v = _vecs(2, seed=4)
    old = db.add(v[:1], v[:1], np.array([1]), t=0.5)[0]
    new = db.add(v[1:], v[1:], np.array([2]), t=5.0)[0]
    s = LFUPolicy().scores(db)
    assert s[old] > s[new]                   # higher score = evicted first
    # a single extra use dominates any recency difference
    db.mark_access(np.array([old]), t=6.0)
    s = LFUPolicy().scores(db)
    assert s[new] > s[old]


# ---------------------------------------------------------------------------
# scheduler strict pairing (bugfix: no silent max(0, ...) clamp)
# ---------------------------------------------------------------------------


def _sched_fixture():
    sched = RequestScheduler(nodes=[NodeInfo(0, speed=1.0),
                                    NodeInfo(1, speed=2.0)])
    dbs = []
    for i in range(2):
        db = VectorDB(dim=512, capacity=8)
        v = _vecs(4, dim=512, seed=5 + i)
        db.add(v, v, np.arange(4), t=0.0)
        dbs.append(db)
    return sched, dbs


def test_scheduler_complete_pairs_normal_path():
    sched, dbs = _sched_fixture()
    q = _vecs(1, dim=512, seed=6)[0]
    d = sched.schedule(q, dbs)
    assert d.fast_path is None
    assert sched.nodes[d.node].queue_depth == 1
    sched.complete(d.node)
    assert sched.nodes[d.node].queue_depth == 0
    # a second release has no matching schedule(): warn, stay at 0
    with pytest.warns(RuntimeWarning, match="queue-depth underflow"):
        sched.complete(d.node)
    assert sched.nodes[d.node].queue_depth == 0


def test_scheduler_complete_pairs_priority_path():
    sched, dbs = _sched_fixture()
    q = _vecs(1, dim=512, seed=7)[0]
    d1 = sched.schedule(q, dbs, quality_tier=True, prompt_key=42)
    sched.complete(d1.node)
    d2 = sched.schedule(q * 0.99, dbs, quality_tier=True, prompt_key=42)
    assert d2.fast_path == "priority"
    assert d2.node == 1                      # fastest node
    assert sched.nodes[1].queue_depth == 1
    sched.complete(d2.node)
    assert sched.nodes[1].queue_depth == 0


def test_scheduler_complete_history_is_noop():
    sched, dbs = _sched_fixture()
    q = _vecs(1, dim=512, seed=8)[0]
    sched.record_result(q, payload_id=7)
    d = sched.schedule(q, dbs)
    assert d.fast_path == "history" and d.node == -1
    depths = [n.queue_depth for n in sched.nodes]
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # no underflow warning either
        sched.complete(d.node)
    assert [n.queue_depth for n in sched.nodes] == depths


# ---------------------------------------------------------------------------
# cost/latency accounting bugfixes
# ---------------------------------------------------------------------------


def test_cost_model_default_rates_wrap_modulo():
    cm = CostModel()
    cm.charge(4, 10.0)                       # node 4 -> rate of node 0
    cm.charge(0, 10.0)
    assert cm.total_cost() == pytest.approx(2 * 10.0 * 0.28 / 3600.0)


def test_cost_model_custom_rates_must_cover_fleet():
    cm = CostModel(gpu_rates=(0.30, 0.20))
    cm.charge(1, 5.0)                        # in range: fine
    with pytest.raises(ValueError, match="no rate in gpu_rates"):
        cm.charge(2, 5.0)
    ok = CostModel(gpu_rates=(0.30, 0.20, 0.10))
    ok.charge(2, 5.0)
    assert ok.total_cost() == pytest.approx(5.0 * 0.10 / 3600.0)


def test_latency_resumed_swaps_noise_for_latent_fetch():
    lm = LatencyModel()
    base = lm.t_embed + lm.t_schedule + lm.t_retrieve
    k, steps = 5, 15
    classic = lm.latency(Route.IMG2IMG, 20)
    resumed = lm.latency(Route.IMG2IMG, steps, resumed=True)
    assert classic == pytest.approx(base + lm.t_noise + 20 * lm.t_step)
    assert resumed == pytest.approx(base + lm.t_latent + steps * lm.t_step)
    assert resumed < classic                 # L_k = t_r + t_latent + (K-k)t_s


# ---------------------------------------------------------------------------
# k=0 resume parity + the end-to-end win
# ---------------------------------------------------------------------------


def test_null_backend_resume_k0_equals_img2img():
    be = NullBackend(res=32)
    prompts = ["a medium red circle at the center on a black background",
               "a large blue square at the left on a gray background"]
    refs = np.stack([np.full((32, 32, 3), 0.3, np.float32),
                     np.full((32, 32, 3), 0.7, np.float32)])
    lat = be.archive_latents_batch(refs, [0, 1], (5, 10), steps_total=20)
    assert lat.shape[0] == 2                 # one slab per depth
    np.testing.assert_array_equal(lat[0], refs)
    out = be.resume_batch(prompts, lat[0], 20, 0, [0, 1])
    np.testing.assert_array_equal(out, be.img2img_batch(prompts, refs,
                                                        20, [0, 1]))


def test_latent_arm_beats_baseline_at_equal_hit_rate():
    """The acceptance property: on the band-mutation workload the latent
    arm serves the SAME routes at the SAME hit rate but strictly fewer
    mean denoising steps — every saved step is a depth resume."""
    reqs = band_mutation_trace(120, band_fraction=0.5, seed=0)
    stats = {}
    for depths in (None, True):
        system, *_ = build_system(n_nodes=2, corpus_n=32,
                                  capacity_per_node=600, seed=0,
                                  latent_depths=depths)
        for i, r in enumerate(reqs):
            system.serve(r.prompt, seed=i)
        stats[bool(depths)] = system.stats
    base, lat = stats[False], stats[True]
    assert lat.route_counts == base.route_counts
    assert lat.hit_rate == pytest.approx(base.hit_rate)
    assert lat.latent_resumes > 0
    assert lat.total_steps < base.total_steps
    saved = base.total_steps - lat.total_steps
    assert saved >= lat.latent_resumes       # every resume skips >= 1 step


def test_latent_resume_latency_accounted_per_depth():
    """Resumed requests must be charged the per-depth Eq. 8 latency, which
    is strictly below the classic img2img latency at the same node speed."""
    system, *_ = build_system(n_nodes=2, corpus_n=32,
                              capacity_per_node=600, seed=0,
                              latent_depths=True)
    lm, pol = system.latency_model, system.policy
    classic = lm.latency(Route.IMG2IMG, pol.steps_ref)
    resumed = [lm.latency(Route.IMG2IMG, pol.steps_for_resume(k),
                          resumed=True) for k in system.latent_depths]
    assert all(r < classic for r in resumed)
    assert sorted(resumed, reverse=True) == resumed   # deeper = faster


def test_diffusion_backend_resume_k0_parity():
    """Real-backend pin of the parity invariant: archiving the depth-0
    latent and resuming from it reproduces the full SDEdit img2img output
    for the same (image, seed) — the latent path is the same chain, just
    split at archive time."""
    import jax
    from repro.configs import get_arch
    from repro.core.embeddings import ProxyClipEmbedder
    from repro.data.synthetic import render_caption
    from repro.models.diffusion import dit as dit_mod
    from repro.models.diffusion import vae as vae_mod
    from repro.runtime.serving import DiffusionBackend

    emb = ProxyClipEmbedder(render_caption)
    dcfg = get_arch("sd15-small").make_config(None)
    net = dit_mod.init_dit(jax.random.key(0), dcfg.net)
    vae = vae_mod.init_vae(jax.random.key(1), dcfg.vae)
    be = DiffusionBackend(net, dcfg.net, vae, dcfg.vae,
                          embed_prompt=lambda p: emb.embed_text([p])[0])
    assert be.supports_latent_resume

    res = dcfg.vae.downsample * dcfg.net.img_res
    prompts = ["a medium red circle at the center on a black background",
               "a small blue square at the left on a gray background"]
    refs = np.stack([render_caption(p, res=res) for p in prompts])
    seeds, steps = [3, 4], 2

    lat = be.archive_latents_batch(refs, seeds, (0, 1), steps_total=steps)
    assert lat.shape[:2] == (2, 2)           # (depths, batch, ...)
    classic = be.img2img_batch(prompts, refs, steps, seeds)
    via_k0 = be.resume_batch(prompts, lat[0], steps, 0, seeds)
    np.testing.assert_allclose(via_k0, classic, atol=1e-5)
    # deeper resume runs fewer steps but stays finite and image-shaped
    via_k1 = be.resume_batch(prompts, lat[1], steps, 1, seeds)
    assert via_k1.shape == classic.shape
    assert np.isfinite(via_k1).all()
