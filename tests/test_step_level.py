"""Step-level continuous batching: randomized ragged-admission property
suite for the persistent slot-based sampler engine.

The step-level engine (``ServingEngine.run(step_level=True)``) admits
requests into a fixed-capacity slot buffer and advances ALL in-flight
chains one denoising step per compiled launch, so mixed step-count
requests (K-step txt2img misses, truncated img2img band hits, resume@k
latent-depth hits) enter and retire at ANY step boundary.  The contract
pinned here: ragged slot admission NEVER changes results — every
(routes, bitwise images, cache state, hit stats, maintenance sweeps)
observable matches both group-continuous mode and the sequential
``serve`` loop on the verified parity grid.

Parity methodology matches ``test_serving_continuous``: batch
partitioning is invisible only on traces where distinct in-batch
prompts do not interact through freshly archived images, so the
property tests draw from empirically verified (trace seed x arrival
process x slot capacity) grids.  Bursty arrivals are partition-
deterministic on the virtual clock; the latent-depth/mixed-hit grids
below were each verified stable over repeated runs.
"""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.policy import GenerationPolicy
from repro.core.trace import (RequestTrace, TimedRequest,
                              band_mutation_trace, bursty_arrivals,
                              mixed_hit_trace, poisson_arrivals,
                              trace_arrivals)
from repro.launch.serve import build_system
from repro.runtime.serving import EmulatedSlotEngine, ServingEngine


def _system(n_nodes=2, corpus_n=80, latent_depths=None):
    system, _, _, _ = build_system(n_nodes=n_nodes, corpus_n=corpus_n,
                                   capacity_per_node=80, seed=0,
                                   latent_depths=latent_depths)
    return system


def _trace(n, seed):
    return list(RequestTrace(seed=seed).generate(n))


def _arrivals(reqs, kind, param, seed):
    if kind == "poisson":
        return poisson_arrivals(reqs, rate=param, seed=seed)
    return bursty_arrivals(reqs, burst_size=int(param), burst_gap=0.4)


def _route_key(r):
    return r.fast_path or r.route.value


def _assert_same_results(done_a, done_b):
    assert len(done_a) == len(done_b)
    for a, b in zip(done_a, done_b):
        assert a.request.prompt == b.request.prompt
        assert _route_key(a.result) == _route_key(b.result)
        assert a.result.node == b.result.node
        assert a.result.steps == b.result.steps
        np.testing.assert_array_equal(a.result.image, b.result.image)


def _assert_same_state(s_a, s_b):
    assert s_a.stats.route_counts == s_b.stats.route_counts
    assert s_a.stats.cache_hits == s_b.stats.cache_hits
    assert s_a.stats.reference_hits == s_b.stats.reference_hits
    assert s_a.stats.latent_resumes == s_b.stats.latent_resumes
    for db_a, db_b in zip(s_a.dbs, s_b.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)
        np.testing.assert_array_equal(db_a.access_count, db_b.access_count)
    assert len(s_a.blob_store) == len(s_b.blob_store)
    assert s_a.scheduler._hist_payloads == s_b.scheduler._hist_payloads
    assert s_a.scheduler.history_hits == s_b.scheduler.history_hits


# ---------------------------------------------------------------------------
# ragged-admission parity: step-level == group-continuous == sequential
# ---------------------------------------------------------------------------

# Verified grid (see module docstring); seeds/arrivals shared with the
# group-continuous suite, crossed with slot capacities that exercise
# capacity-limited admission (4 < burst sizes), the max_batch-aligned
# default (8) and an odd oversized buffer (13).
_PARITY_SEEDS = (0, 2, 3, 4, 5, 7, 8, 9, 11)
_PARITY_ARRIVALS = (("poisson", 30.0), ("poisson", 60.0),
                    ("poisson", 120.0), ("bursty", 3), ("bursty", 7),
                    ("bursty", 12))
_CAPACITIES = (4, 8, 13)


@settings(max_examples=6, deadline=None)
@given(tseed=st.sampled_from(_PARITY_SEEDS),
       arrival=st.sampled_from(_PARITY_ARRIVALS),
       cap=st.sampled_from(_CAPACITIES))
def test_step_level_matches_group_and_sequential(tseed, arrival, cap):
    """The tentpole property: on random Zipf traces, step-level slot
    admission reproduces group-continuous mode AND the sequential serve
    loop — routes, nodes, steps, bitwise images, cache state, hit stats
    — for any slot capacity."""
    kind, param = arrival
    reqs = _trace(40, seed=tseed)
    arr = _arrivals(reqs, kind, param, seed=tseed)

    s_seq = _system()
    r_seq = [s_seq.serve(r.prompt, seed=i, quality_tier=r.quality_tier)
             for i, r in enumerate(reqs)]

    s_grp = _system()
    done_grp = ServingEngine(s_grp, max_batch=8).run(arr)

    s_stp = _system()
    done_stp = ServingEngine(s_stp, max_batch=8).run(
        arr, step_level=True, slot_capacity=cap)

    _assert_same_results(done_stp, done_grp)
    _assert_same_state(s_stp, s_grp)
    # and against the no-batching ground truth
    for a, c in zip(r_seq, done_stp):
        assert _route_key(a) == _route_key(c.result)
        assert a.node == c.result.node
        np.testing.assert_array_equal(a.image, c.result.image)
    _assert_same_state(s_stp, s_seq)


# Latent-depth / hit-rate-mix grids: (trace kind, trace seed, burst size)
# points where band-mutation archives do not feed back into the same
# admission group (verified stable over repeated runs, with resume@k
# latent-depth hits present across the grid).
_BAND_GRID = (("band", 7, 3), ("band", 14, 3), ("band", 14, 7))
_MIXED_GRID = (("mixed", 1, 3), ("mixed", 3, 3), ("mixed", 4, 3),
               ("mixed", 4, 7), ("mixed", 4, 12), ("mixed", 6, 3),
               ("mixed", 7, 3), ("mixed", 8, 3), ("mixed", 9, 3),
               ("mixed", 10, 3), ("mixed", 10, 7), ("mixed", 11, 3),
               ("mixed", 14, 3), ("mixed", 14, 7), ("mixed", 15, 3))


def _hit_mix_trace(kind, seed, n=40):
    if kind == "band":
        return band_mutation_trace(n, seed=seed)
    return mixed_hit_trace(n, seed=seed)


@settings(max_examples=6, deadline=None)
@given(point=st.sampled_from(_BAND_GRID + _MIXED_GRID),
       cap=st.sampled_from(_CAPACITIES))
def test_step_level_parity_with_latent_depth_resumes(point, cap):
    """Hit-rate-mix parity: traces mixing txt2img misses, img2img band
    hits, resume@k latent-depth hits and verbatim repeats retire at
    ragged step boundaries — results and cache state (including
    ``latent_resumes``) still match group mode exactly."""
    kind, tseed, burst = point
    reqs = _hit_mix_trace(kind, tseed)
    arr = bursty_arrivals(reqs, burst_size=burst, burst_gap=0.4)

    s_grp = _system(corpus_n=40, latent_depths=True)
    done_grp = ServingEngine(s_grp, max_batch=8).run(arr)

    s_stp = _system(corpus_n=40, latent_depths=True)
    done_stp = ServingEngine(s_stp, max_batch=8).run(
        arr, step_level=True, slot_capacity=cap)

    _assert_same_results(done_stp, done_grp)
    _assert_same_state(s_stp, s_grp)


def test_step_level_grid_covers_latent_resumes():
    """Coverage guard for the grid above: the band workload actually
    exercises resume@k slots (an engine change that silently stopped
    admitting latent-resume plans would otherwise pass parity)."""
    reqs = _hit_mix_trace("band", 7)
    s = _system(corpus_n=40, latent_depths=True)
    done = ServingEngine(s, max_batch=8).run(
        bursty_arrivals(reqs, burst_size=3, burst_gap=0.4),
        step_level=True, slot_capacity=8)
    assert len(done) == len(reqs)
    assert s.stats.latent_resumes > 0
    assert s.stats.reference_hits > 0


# ---------------------------------------------------------------------------
# maintenance sweeps fire at exact interval crossings under slot retirement
# ---------------------------------------------------------------------------


def _count_maintains(system):
    crossings = []
    orig = system.maintain

    def wrapped():
        crossings.append(system.stats.requests)
        return orig()

    system.maintain = wrapped
    return crossings


def test_step_level_maintenance_crossings_match_group():
    """Finish runs per retired slot in submission order, so eviction
    sweeps land at EVERY exact multiple of ``maintenance_interval`` —
    the same crossings group-continuous mode produces."""
    reqs = _trace(40, seed=0)
    arr = bursty_arrivals(reqs, burst_size=7, burst_gap=0.4)

    s_grp = _system()
    s_grp.maintenance_interval = 4
    cross_grp = _count_maintains(s_grp)
    ServingEngine(s_grp, max_batch=8).run(arr)

    s_stp = _system()
    s_stp.maintenance_interval = 4
    cross_stp = _count_maintains(s_stp)
    ServingEngine(s_stp, max_batch=8).run(arr, step_level=True,
                                          slot_capacity=4)

    assert cross_stp == cross_grp
    assert cross_stp == [m for m in range(4, 41, 4)]


# ---------------------------------------------------------------------------
# slot-engine invariants: monotone step indices, bounded occupancy
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(tseed=st.sampled_from(_PARITY_SEEDS),
       cap=st.sampled_from(_CAPACITIES))
def test_slot_step_indices_strictly_monotone(tseed, cap):
    """Every admitted slot's recorded step-index trail counts 0,1,2,...
    with no skips, stalls or rewinds, and occupancy never exceeds the
    slot capacity."""
    reqs = _trace(30, seed=tseed)
    eng = ServingEngine(_system(), max_batch=8)
    done = eng.run(bursty_arrivals(reqs, burst_size=7, burst_gap=0.4),
                   step_level=True, slot_capacity=cap)
    assert len(done) == len(reqs)
    slots = eng.last_slot_engine
    assert isinstance(slots, EmulatedSlotEngine)   # generic-backend path
    assert slots.progress                          # gen work happened
    for trail in slots.progress.values():
        assert trail == list(range(len(trail)))    # strictly +1 from 0
        assert len(trail) >= 2                     # at least one advance
    assert eng.slot_occupancy
    assert len(eng.slot_occupancy) == slots.step_calls
    assert all(1 <= o <= cap for o in eng.slot_occupancy)


def test_step_level_validation_and_empty():
    eng = ServingEngine(_system(), max_batch=4)
    assert eng.run([], step_level=True) == []
    with pytest.raises(ValueError):
        eng.run([TimedRequest(0.0, "p")], mode="drain", step_level=True)
    with pytest.raises(ValueError):
        eng.run([TimedRequest(0.0, "p")], slot_capacity=4)
    # on_step is valid in BOTH modes now (group mode calls it per group
    # — the chaos harness's injection point); it must actually fire
    seen = []
    done = eng.run([TimedRequest(0.0, "p")], on_step=seen.append)
    assert len(done) == 1 and seen == [0]


# ---------------------------------------------------------------------------
# fault injection: node leaves mid-flight
# ---------------------------------------------------------------------------


def test_node_leave_mid_flight_zero_accepted_job_loss():
    """A node dying while slots are in flight loses nothing: every
    admitted request completes with an image, in-flight chains finish
    and their archives reroute to survivors, and the dead node's
    VectorDB is left exactly as it was at the instant of death."""
    system = _system(n_nodes=3)
    eng = ServingEngine(system, max_batch=8)
    reqs = _trace(30, seed=0)
    snap = {}

    def on_step(step_no):
        if step_no == 2:                      # mid-flight, slots occupied
            assert eng.last_slot_engine.active_count() > 0
            eng.fail_node(1)
            db = system.dbs[1]
            snap["valid"] = db.valid.copy()
            snap["payload_ids"] = db.payload_ids.copy()
            snap["access_count"] = db.access_count.copy()

    done = eng.run(bursty_arrivals(reqs, burst_size=7, burst_gap=0.4),
                   step_level=True, slot_capacity=4, on_step=on_step)
    assert snap, "failure injection never fired"
    assert len(done) == len(reqs)                      # zero loss
    assert all(c.result.image is not None for c in done)
    assert not system.scheduler.nodes[1].alive
    # the dead node's VectorDB is untouched after the failure instant
    db = system.dbs[1]
    np.testing.assert_array_equal(db.valid, snap["valid"])
    np.testing.assert_array_equal(db.payload_ids, snap["payload_ids"])
    np.testing.assert_array_equal(db.access_count, snap["access_count"])
    # post-failure generations actually rerouted somewhere alive
    gen_nodes = {c.result.node for c in done
                 if c.result.steps > 0 and c.result.node >= 0}
    assert gen_nodes & {0, 2}


def test_node_leave_before_any_admission_routes_around():
    """Degenerate fault timing: the node is already dead at first
    admission — Schedule never picks it, and the run completes."""
    system = _system(n_nodes=3)
    eng = ServingEngine(system, max_batch=8)
    eng.fail_node(1)
    reqs = _trace(16, seed=2)
    done = eng.run(bursty_arrivals(reqs, burst_size=4, burst_gap=0.4),
                   step_level=True, slot_capacity=4)
    assert len(done) == len(reqs)
    assert all(c.result.node != 1 for c in done if c.result.steps > 0)


# ---------------------------------------------------------------------------
# per-slot timestamp accounting under ragged retirement
# ---------------------------------------------------------------------------


def test_step_level_per_slot_timestamps_reconcile():
    """Regression: ``queue_delay`` / ``stage_walls`` / ``wall_total`` are
    stamped from each slot's OWN trail at retirement, never smeared
    across an admission group — every request reconciles individually."""
    system = _system()
    reqs = _trace(24, seed=5)
    done = ServingEngine(system, max_batch=8).run(
        bursty_arrivals(reqs, burst_size=7, burst_gap=0.3),
        step_level=True, slot_capacity=4)
    names = system.pipeline.stage_names
    for c in done:
        r = c.result
        assert list(r.stage_walls) == names          # all stages, in order
        assert all(w >= 0.0 for w in r.stage_walls.values())
        assert sum(r.stage_walls.values()) == pytest.approx(r.wall_total,
                                                            rel=1e-6)
        assert c.queue_delay >= 0.0
        assert r.queue_delay == c.queue_delay
        assert r.wall_total > 0.0
        assert c.finished_at >= c.request.submitted_at + c.queue_delay


def test_step_level_queue_delay_is_admission_minus_arrival():
    """Widely spaced arrivals are admitted the instant they arrive, so
    queue delays collapse to ~0 even though each request then spends
    many engine steps in its slot."""
    reqs = _trace(8, seed=3)
    spaced = trace_arrivals(reqs, [1.0 * (i + 1) for i in range(len(reqs))])
    done = ServingEngine(_system(), max_batch=8).run(
        spaced, step_level=True, slot_capacity=4)
    assert len(done) == len(reqs)
    for c in done:
        assert 0.0 <= c.queue_delay < 0.5


# ---------------------------------------------------------------------------
# tiny-DiT CPU config: one compiled executable, no serve-time JIT,
# bitwise parity through the real slot engine, and the bursty p95 win
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_diffusion_backend():
    import jax
    from repro.configs import get_arch
    from repro.models.diffusion import dit as dit_mod
    from repro.models.diffusion import vae as vae_mod
    from repro.core.embeddings import ProxyClipEmbedder
    from repro.data.synthetic import render_caption
    from repro.runtime.serving import DiffusionBackend

    emb = ProxyClipEmbedder(render_caption)
    dcfg = get_arch("sd15-small").make_config(None)
    net = dit_mod.init_dit(jax.random.key(0), dcfg.net)
    vae = vae_mod.init_vae(jax.random.key(1), dcfg.vae)
    return DiffusionBackend(net, dcfg.net, vae, dcfg.vae,
                            embed_prompt=lambda p: emb.embed_text([p])[0])


def _tiny_system(backend, max_batch):
    policy = GenerationPolicy(steps_full=2, steps_ref=2)
    system, _, _, _ = build_system(n_nodes=2, corpus_n=60,
                                   capacity_per_node=60, seed=0,
                                   policy=policy, backend=backend)
    buckets, b = [], 1
    while b <= max_batch:
        buckets.append(b)
        b *= 2
    backend.precompile(step_buckets=(2,), batch_buckets=tuple(buckets))
    for bucket in buckets:
        for db in system.dbs:
            db.search_batch(np.zeros((bucket, db.dim), np.float32),
                            system.topk)
    return system


def test_step_level_never_jits_single_executable(tiny_diffusion_backend):
    """After ``precompile_step_level()`` a step-level run adds NO new
    ``_compiled`` keys, and exactly ONE ``step_slots`` executable exists
    per slot capacity — the whole ragged schedule reuses it."""
    system = _tiny_system(tiny_diffusion_backend, max_batch=4)
    tiny_diffusion_backend.precompile_step_level(4)
    keys_before = set(tiny_diffusion_backend._compiled)
    assert ("step_slots", 0, 4) in keys_before

    eng = ServingEngine(system, max_batch=4)
    reqs = _trace(12, seed=11)
    done = eng.run(bursty_arrivals(reqs, burst_size=4, burst_gap=2.0),
                   step_level=True, slot_capacity=4)
    assert len(done) == len(reqs)
    assert set(tiny_diffusion_backend._compiled) == keys_before
    step_keys = [k for k in tiny_diffusion_backend._compiled
                 if k[0] == "step_slots"]
    assert step_keys == [("step_slots", 0, 4)]   # one per capacity bucket
    slots = eng.last_slot_engine
    assert slots.step_calls == len(eng.slot_occupancy) > 0
    # the run exercised the denoiser, not just cache fast paths
    assert any(c.result.steps > 0 and c.result.fast_path != "history"
               for c in done)


def test_step_level_bitwise_matches_sequential_tiny_dit(
        tiny_diffusion_backend):
    """Acceptance gate: through the REAL slot engine (persistent latents,
    per-slot timesteps, separate decode program) every image is bitwise
    identical to the sequential ``serve`` loop on the parity trace."""
    reqs = _trace(12, seed=11)

    s_seq = _tiny_system(tiny_diffusion_backend, max_batch=4)
    r_seq = [s_seq.serve(r.prompt, seed=i, quality_tier=r.quality_tier)
             for i, r in enumerate(reqs)]

    s_stp = _tiny_system(tiny_diffusion_backend, max_batch=4)
    tiny_diffusion_backend.precompile_step_level(4)
    done = ServingEngine(s_stp, max_batch=4).run(
        bursty_arrivals(reqs, burst_size=3, burst_gap=2.0),
        step_level=True, slot_capacity=4)

    assert len(done) == len(reqs)
    for a, c in zip(r_seq, done):
        assert _route_key(a) == _route_key(c.result)
        np.testing.assert_array_equal(a.image, c.result.image)
    assert s_seq.stats.route_counts == s_stp.stats.route_counts
    for db_a, db_b in zip(s_seq.dbs, s_stp.dbs):
        np.testing.assert_array_equal(db_a.valid, db_b.valid)
        np.testing.assert_array_equal(db_a.payload_ids, db_b.payload_ids)


def test_step_level_bursty_p95_beats_group_continuous(
        tiny_diffusion_backend):
    """The latency acceptance gate: on bursty arrivals, step-level
    admission (join a half-finished batch NOW) gives a strictly lower
    p95 queue delay than group-continuous (wait for the current step
    group to drain) at equal offered load and throughput."""
    reqs = _trace(24, seed=12)
    arr = bursty_arrivals(reqs, burst_size=6, burst_gap=2.0)

    done_g = ServingEngine(_tiny_system(tiny_diffusion_backend, 4),
                           max_batch=4).run(arr, mode="continuous")
    s_stp = _tiny_system(tiny_diffusion_backend, 4)
    tiny_diffusion_backend.precompile_step_level(4)
    done_s = ServingEngine(s_stp, max_batch=4).run(
        arr, step_level=True, slot_capacity=4)

    assert len(done_g) == len(done_s) == len(reqs)   # equal offered load
    qg = np.array([c.queue_delay for c in done_g])
    qs = np.array([c.queue_delay for c in done_s])
    assert np.percentile(qs, 95) < np.percentile(qg, 95)
    rps_g = len(done_g) / max(c.finished_at for c in done_g)
    rps_s = len(done_s) / max(c.finished_at for c in done_s)
    assert rps_s == pytest.approx(rps_g, rel=0.5)
