"""The paper's cache layer: VDB, storage classifier, scheduler, LCU —
unit behaviour + hypothesis property tests on the invariants."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.kmeans import cluster_sizes, kmeans_assign, kmeans_fit
from repro.core.lcu import (FIFOPolicy, LCUPolicy, LFUPolicy, LRUPolicy,
                            POLICIES)
from repro.core.scheduler import NodeInfo, RequestScheduler
from repro.core.storage_classifier import StorageClassifier
from repro.core.vdb import BlobStore, VectorDB

import jax.numpy as jnp


def _unit(rng, n, d):
    v = rng.normal(size=(n, d)).astype(np.float32)
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# VectorDB
# ---------------------------------------------------------------------------


def test_vdb_add_search_roundtrip():
    rng = np.random.default_rng(0)
    db = VectorDB(dim=16, capacity=32)
    vecs = _unit(rng, 10, 16)
    slots = db.add(vecs, vecs, np.arange(10), t=0.0)
    assert db.size == 10 and len(slots) == 10
    scores, got = db.search(vecs[3], k=1, index="img")
    assert got[0] == slots[3]
    assert scores[0] > 0.999


def test_vdb_dual_index_union():
    rng = np.random.default_rng(1)
    db = VectorDB(dim=8, capacity=16)
    img = _unit(rng, 6, 8)
    txt = _unit(rng, 6, 8)
    db.add(img, txt, np.arange(6), t=0.0)
    scores, slots = db.search(txt[2], k=3, index="both")
    assert len(slots) == len(set(slots.tolist()))  # de-duplicated union
    assert len(slots) <= 6


def test_vdb_overwrite_oldest_when_full():
    rng = np.random.default_rng(2)
    db = VectorDB(dim=8, capacity=4)
    a = _unit(rng, 4, 8)
    db.add(a, a, np.arange(4), t=0.0)
    b = _unit(rng, 2, 8)
    db.add(b, b, np.array([100, 101]), t=1.0)
    assert db.size == 4
    assert set([100, 101]).issubset(set(db.payload_ids[db.valid].tolist()))


def test_vdb_overwrite_targets_exactly_the_oldest():
    """FIFO pressure valve: when full, inserts overwrite the entries with
    the OLDEST insert_time, never newer ones."""
    rng = np.random.default_rng(12)
    db = VectorDB(dim=8, capacity=4)
    for i in range(4):                       # distinct insert times 0..3
        v = _unit(rng, 1, 8)
        db.add(v, v, np.array([i]), t=float(i))
    nv = _unit(rng, 2, 8)
    db.add(nv, nv, np.array([100, 101]), t=10.0)
    alive = set(db.payload_ids[db.valid].tolist())
    assert alive == {2, 3, 100, 101}         # payloads 0 and 1 (oldest) gone
    assert db.size == 4


def test_vdb_add_batch_larger_than_capacity():
    """A single insert bigger than the slab keeps size == capacity and the
    newest entries win the collided slots."""
    rng = np.random.default_rng(13)
    db = VectorDB(dim=8, capacity=4)
    v = _unit(rng, 6, 8)
    db.add(v, v, np.arange(6), t=0.0)
    assert db.size == 4
    assert set(db.payload_ids[db.valid].tolist()) == {2, 3, 4, 5}


def test_vdb_evict_returns_payloads():
    rng = np.random.default_rng(3)
    db = VectorDB(dim=8, capacity=8)
    v = _unit(rng, 5, 8)
    slots = db.add(v, v, np.arange(50, 55), t=0.0)
    payloads = db.evict_slots(slots[:2])
    assert sorted(payloads.tolist()) == [50, 51]
    assert db.size == 3


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 30), k=st.integers(1, 8), seed=st.integers(0, 99))
def test_vdb_search_scores_sorted_and_valid(n, k, seed):
    """Property: scores descend; returned slots are valid; k caps results."""
    rng = np.random.default_rng(seed)
    db = VectorDB(dim=8, capacity=64)
    v = _unit(rng, n, 8)
    db.add(v, v, np.arange(n), t=0.0)
    q = _unit(rng, 1, 8)[0]
    scores, slots = db.search(q, k=k)
    assert list(scores) == sorted(scores, reverse=True)
    assert db.valid[slots].all()
    assert len(slots) <= 2 * k


# ---------------------------------------------------------------------------
# K-means / storage classifier
# ---------------------------------------------------------------------------


def test_kmeans_separates_clear_clusters():
    rng = np.random.default_rng(4)
    a = rng.normal(0, 0.05, (40, 4)) + np.array([1, 0, 0, 0])
    b = rng.normal(0, 0.05, (40, 4)) + np.array([-1, 0, 0, 0])
    x = np.concatenate([a, b]).astype(np.float32)
    state = kmeans_fit(jnp.asarray(x), k=2, iters=10)
    asg = np.asarray(state.assignment)
    assert len(set(asg[:40])) == 1 and len(set(asg[40:])) == 1
    assert asg[0] != asg[40]
    sizes = np.asarray(cluster_sizes(state.assignment, 2))
    assert sizes.sum() == 80


def test_kmeans_inertia_decreases_with_iters():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(100, 8)).astype(np.float32))
    i1 = float(kmeans_fit(x, k=4, iters=1).inertia)
    i10 = float(kmeans_fit(x, k=4, iters=10).inertia)
    assert i10 <= i1 + 1e-5


def test_storage_classifier_builds_consistent_fleet(fleet, corpus, embedder):
    dbs, blob, cls, img_vecs, _, _ = fleet
    assert sum(db.size for db in dbs) == len(img_vecs)
    # every stored vector is nearest to its own node's centroid
    asg = cls.assign(img_vecs)
    for ni, db in enumerate(dbs):
        if db.size:
            stored = db.img_vecs[db.valid]
            a, _ = kmeans_assign(jnp.asarray(stored),
                                 jnp.asarray(cls.centroids))
            assert (np.asarray(a) == ni).mean() > 0.99
    assert cls.modal_consistency is not None
    assert cls.modal_consistency > 0.5  # paper Fig. 6b: high cross-modal agreement


def test_failed_node_reassignment(fleet):
    dbs, blob, cls, img_vecs, _, _ = fleet
    total_before = sum(db.size for db in dbs)
    moved = dbs[1].size
    cls.reassign_failed_node(dbs, failed=1, t=9.0)
    assert dbs[1].size == 0
    assert sum(db.size for db in dbs) == total_before
    del moved


# ---------------------------------------------------------------------------
# request scheduler (Eq. 6 + fast paths)
# ---------------------------------------------------------------------------


def test_scheduler_routes_to_most_similar_node(fleet):
    dbs, _, cls, img_vecs, _, _ = fleet
    sched = RequestScheduler(nodes=[NodeInfo(i) for i in range(4)],
                             balance_weight=0.0)
    # a query ON a node centroid must route to that node
    for ni in range(4):
        if dbs[ni].size == 0:
            continue
        q = dbs[ni].centroid()
        d = sched.schedule(q, dbs)
        assert d.node == ni
        sched.complete(d.node)


def test_scheduler_history_fast_path(fleet):
    dbs, _, _, img_vecs, _, _ = fleet
    sched = RequestScheduler(nodes=[NodeInfo(i) for i in range(4)])
    q = img_vecs[0]
    sched.record_result(q, payload_id=777)
    d = sched.schedule(q, dbs)
    assert d.fast_path == "history" and d.history_payload == 777


def test_scheduler_priority_fast_path(fleet):
    dbs, _, _, img_vecs, _, _ = fleet
    nodes = [NodeInfo(0, speed=1.0), NodeInfo(1, speed=2.0),
             NodeInfo(2, speed=0.5), NodeInfo(3, speed=1.0)]
    sched = RequestScheduler(nodes=nodes)
    q = img_vecs[1]
    d1 = sched.schedule(q, dbs, quality_tier=True, prompt_key=42)
    assert d1.fast_path is None          # first occurrence: normal path
    d2 = sched.schedule(q + 0.31, dbs, quality_tier=True, prompt_key=42)
    assert d2.fast_path == "priority"
    assert d2.node == 1                  # fastest node


def test_scheduler_skips_failed_nodes(fleet):
    dbs, _, _, img_vecs, _, _ = fleet
    sched = RequestScheduler(nodes=[NodeInfo(i) for i in range(4)])
    sched.mark_failed(2)
    for i in range(8):
        d = sched.schedule(img_vecs[i], dbs)
        assert d.node != 2
        sched.complete(d.node)


def test_scheduler_load_balances():
    rng = np.random.default_rng(6)
    dbs = []
    for i in range(2):
        db = VectorDB(8, 16)
        v = _unit(rng, 4, 8)
        db.add(v, v, np.arange(4), t=0)
        dbs.append(db)
    sched = RequestScheduler(nodes=[NodeInfo(0), NodeInfo(1)],
                             balance_weight=10.0)  # heavy penalty
    q = dbs[0].centroid()
    first = sched.schedule(q, dbs)       # goes to node 0, queue grows
    second = sched.schedule(q, dbs)      # penalty pushes to node 1
    assert {first.node, second.node} == {0, 1}


# ---------------------------------------------------------------------------
# eviction policies (Algorithm 2 + baselines, Fig. 19)
# ---------------------------------------------------------------------------


def _db_with(rng, n=12, d=8):
    db = VectorDB(d, 32)
    v = _unit(rng, n, d)
    db.add(v, v, np.arange(n), t=0.0)
    return db


def test_lcu_evicts_farthest_from_centroid():
    rng = np.random.default_rng(7)
    db = VectorDB(4, 16)
    tight = rng.normal(0, 0.01, (8, 4)) + np.array([1.0, 0, 0, 0])
    outlier = np.array([[-1.0, 0, 0, 0]])
    vecs = np.concatenate([tight, outlier]).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=-1, keepdims=True)
    db.add(vecs, vecs, np.arange(9), t=0.0)
    evicted = LCUPolicy().maintain([db], c_max=8)
    assert evicted[0].tolist() == [8]    # the outlier goes first


def test_lru_lfu_fifo_orderings():
    rng = np.random.default_rng(8)
    db = _db_with(rng, n=4)
    db.mark_access(np.array([0, 1]), t=5.0)     # 2,3 least recently used
    db.mark_access(np.array([0]), t=6.0)        # 0 most frequent
    ev_lru = LRUPolicy().maintain([_copy_db(db)], c_max=3)
    assert ev_lru[0][0] in (2, 3)
    ev_lfu = LFUPolicy().maintain([_copy_db(db)], c_max=3)
    assert ev_lfu[0][0] in (1, 2, 3)            # not the frequent slot 0
    db2 = _copy_db(db)
    db2.insert_time[:4] = [3.0, 2.0, 1.0, 0.0]
    ev_fifo = FIFOPolicy().maintain([db2], c_max=3)
    assert ev_fifo[0][0] == 3                   # oldest insert


def _copy_db(db):
    new = VectorDB(db.dim, db.capacity)
    for attr in ("img_vecs", "txt_vecs", "valid", "insert_time",
                 "last_access", "access_count", "payload_ids"):
        setattr(new, attr, getattr(db, attr).copy())
    return new


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50), cmax=st.integers(0, 30),
       policy=st.sampled_from(sorted(POLICIES)))
def test_policies_always_reach_capacity(seed, cmax, policy):
    """Property (Algorithm 2 line 10): after maintain, Σ|D_k| ≤ C_max, and
    nothing is evicted when already within capacity."""
    rng = np.random.default_rng(seed)
    dbs = [_db_with(rng, n=rng.integers(1, 12)) for _ in range(3)]
    before = sum(db.size for db in dbs)
    evicted = POLICIES[policy].maintain(dbs, c_max=cmax)
    after = sum(db.size for db in dbs)
    if before <= cmax:
        assert evicted == {} and after == before
    else:
        assert after == cmax
        n_evicted = sum(len(v) for v in evicted.values())
        assert n_evicted == before - cmax


def test_scheduler_invalidate_payloads_drops_history_entries():
    rng = np.random.default_rng(14)
    sched = RequestScheduler(nodes=[NodeInfo(0)])
    vecs = _unit(rng, 3, 512)
    for i, v in enumerate(vecs):
        sched.record_result(v, payload_id=100 + i)
    sched.invalidate_payloads([101])
    assert sched._hist_payloads == [100, 102]
    assert sched._hist_vecs.shape[0] == 2
    # the evicted entry no longer fast-paths; the survivors still do
    assert sched._history_lookup(vecs[1]) is None
    assert sched._history_lookup(vecs[0]) == 100
    assert sched._history_lookup(vecs[2]) == 102


def test_maintain_keeps_history_cache_consistent():
    """CacheGenius.maintain (Algorithm 2 + §IV-G sync deletion): after an
    eviction sweep, every surviving history entry must still resolve in the
    blob store, and evicted payloads must be gone from the history cache —
    otherwise a later near-duplicate prompt would dereference a deleted
    image."""
    from repro.launch.serve import build_system
    from repro.core.trace import RequestTrace

    system, _, _, _ = build_system(n_nodes=2, corpus_n=40,
                                   capacity_per_node=40, seed=0)
    system.maintenance_interval = 10 ** 9          # manual maintain only
    reqs = list(RequestTrace(seed=4).generate(40))
    for i, r in enumerate(reqs):
        system.serve(r.prompt, seed=i)
    assert len(system.scheduler._hist_payloads) > 0
    system.cache_capacity = system.total_size - 10  # force eviction
    evicted = system.maintain()
    assert sum(len(v) for v in evicted.values()) >= 10
    blob_ids = set(system.blob_store._blobs)
    evicted_ids = {int(p) for v in evicted.values() for p in v}
    assert not (set(system.scheduler._hist_payloads) & evicted_ids)
    assert all(p in blob_ids for p in system.scheduler._hist_payloads)
    # replaying the whole trace must not dereference a deleted blob
    for i, r in enumerate(reqs):
        system.serve(r.prompt, seed=1000 + i)


def test_blob_store_consistency():
    blob = BlobStore()
    a = blob.put(np.ones((2, 2)))
    b = blob.put(np.zeros((2, 2)))
    assert len(blob) == 2
    blob.delete(a)
    assert len(blob) == 1
    assert blob.get(b).sum() == 0
