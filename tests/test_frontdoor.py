"""Front-door gateway: fairness/quota/escalation properties on the
deterministic queue core, result-store roundtrips, graceful node
leave/join with zero accepted-job loss, and bitwise parity between the
gateway path and a direct ``ServingEngine.run`` over the merged trace.

The queue takes an explicit ``now`` everywhere, so the property tests
replay admission and dequeue policy on a synthetic clock with no threads
and no sleeps; only the integration tests at the bottom spin up the real
worker-thread dispatcher against a real CacheGenius fleet.
"""
from __future__ import annotations

import asyncio
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.trace import (RequestTrace, bursty_arrivals, merge_arrivals,
                              poisson_arrivals, trace_arrivals)
from repro.data.synthetic import all_specs, caption_of
from repro.frontdoor import (BackpressureError, DEFAULT_TIERS, Dispatcher,
                             FileResultStore, FrontDoorQueue, Gateway,
                             GatewayClosedError, Job, MemoryResultStore,
                             QuotaExceededError, ResultHandle, TierSpec,
                             TokenBucket)
from repro.launch.frontdoor import jain_fairness
from repro.launch.serve import build_system
from repro.runtime.serving import (Request, ServingEngine,
                                   tenant_tier_stats)


def _q(**kw) -> FrontDoorQueue:
    return FrontDoorQueue(**kw)


def _job(tenant="t0", tier="standard", prompt="p", seed=0, **kw) -> Job:
    return Job(tenant=tenant, tier=tier, prompt=prompt, seed=seed, **kw)


# ---------------------------------------------------------------------------
# tiers, escalation, typed rejections (unit)
# ---------------------------------------------------------------------------


def test_tier_priority_and_mixed_batches():
    q = _q()
    q.submit(_job(tier="batch", prompt="b"), now=0.0)
    q.submit(_job(tier="standard", prompt="s"), now=0.0)
    q.submit(_job(tier="premium", prompt="p"), now=0.0)
    got = q.next_batch(3, now=0.0)
    # strict priority order, and one batch may mix tiers
    assert [j.tier for j in got] == ["premium", "standard", "batch"]
    assert len(q) == 0


def test_deadline_escalation_promotes_overdue():
    q = _q()  # DEFAULT_TIERS: batch escalates after 30s, standard after 4s
    q.submit(_job(tier="batch", prompt="old"), now=0.0)
    q.submit(_job(tier="batch", prompt="young"), now=25.0)
    q.submit(_job(tier="standard", prompt="mid"), now=29.0)
    got = q.next_batch(3, now=31.0)
    # the 31s-old batch job escalated: it joins the TAIL of standard (so
    # behind "mid", which was already there) but now outranks every
    # batch-tier job
    assert [j.prompt for j in got] == ["mid", "old", "young"]
    assert got[1].effective_tier == "standard" and got[1].escalations == 1
    assert got[1].tier == "batch"            # original tier preserved
    assert q.stats.escalations == 1
    # premium (level 0) can never escalate; math.inf disables it
    assert not math.isfinite(DEFAULT_TIERS[0].escalation_wait)


def test_escalation_can_cascade_to_premium():
    q = _q()
    q.submit(_job(tier="batch"), now=0.0)
    q.next_batch(0, now=100.0)    # two escalation passes, no dequeue
    q.next_batch(0, now=200.0)
    [j] = q.next_batch(1, now=200.0)
    assert j.effective_tier == "premium" and j.escalations == 2


def test_typed_backpressure_and_quota_errors():
    q = _q(max_depth=2, quotas={"t0": TokenBucket(rate=1.0, burst=2)})
    q.submit(_job(), now=0.0)
    q.submit(_job(), now=0.0)
    # depth bound first: the queue is full regardless of tenant
    with pytest.raises(BackpressureError) as ei:
        q.submit(_job(tenant="other"), now=0.0)
    assert not isinstance(ei.value, QuotaExceededError)
    assert ei.value.depth == 2 and ei.value.bound == 2
    assert ei.value.tenant == "other"
    # drain, then exhaust t0's bucket: burst=2 already spent at now=0
    q.next_batch(2, now=0.0)
    with pytest.raises(QuotaExceededError) as ei:
        q.submit(_job(), now=0.0)
    assert ei.value.retry_after == pytest.approx(1.0)
    assert isinstance(ei.value, BackpressureError)   # subtype relation
    # after one refill interval the tenant is admitted again
    q.submit(_job(), now=1.0)
    with pytest.raises(ValueError):
        q.submit(_job(tier="nope"), now=0.0)
    s = q.stats
    assert (s.accepted, s.rejected_backpressure, s.rejected_quota) \
        == (3, 1, 1)
    assert s.rejected_by_tenant == {"other": 1, "t0": 1}


def test_tier_validation():
    with pytest.raises(ValueError):
        FrontDoorQueue(tiers=(TierSpec("a", 0, 1.0), TierSpec("b", 2, 1.0)))
    with pytest.raises(ValueError):
        FrontDoorQueue(max_depth=0)
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1.0)


# ---------------------------------------------------------------------------
# property (a): no tenant starves under overload
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(hogs=st.integers(1, 4), flood=st.integers(20, 80),
       batch=st.sampled_from([1, 4, 8]))
def test_quiet_tenant_never_starves(hogs, flood, batch):
    """A tenant with one queued job is served within its first fair-share
    turn no matter how many jobs the flooding tenants piled up first."""
    q = _q(max_depth=10_000)
    for h in range(hogs):
        for i in range(flood):
            q.submit(_job(tenant=f"hog{h}", prompt=f"h{h}.{i}"), now=0.0)
    q.submit(_job(tenant="quiet", prompt="q0"), now=1.0)
    served = []
    while len(q):
        served.extend(j.tenant for j in q.next_batch(batch, now=2.0))
    # fair share: the quiet tenant's job lands in the first round-robin
    # turn across tenants, not behind `hogs * flood` flooded jobs
    assert "quiet" in served[:hogs + 1]
    assert len(served) == hogs * flood + 1       # nothing lost, no dups


@settings(max_examples=6, deadline=None)
@given(wq=st.sampled_from([1.0, 2.0, 4.0]))
def test_weighted_fair_share_ratio(wq):
    """With weights (wq, 1) and saturated backlogs, the share of dequeues
    the weighted tenant wins tracks wq/(wq+1)."""
    q = _q(max_depth=10_000, tenant_weights={"a": wq, "b": 1.0})
    for i in range(400):
        q.submit(_job(tenant="a", prompt=f"a{i}"), now=0.0)
        q.submit(_job(tenant="b", prompt=f"b{i}"), now=0.0)
    first = [j.tenant for j in q.next_batch(200, now=0.0)]
    share = first.count("a") / len(first)
    assert abs(share - wq / (wq + 1.0)) < 0.05
    # fairness over full service is perfect once both backlogs drain
    while len(q):
        q.next_batch(64, now=0.0)
    assert q.stats.dispatched == 800


def test_fifo_mode_ignores_fair_share():
    q = _q(fair=False)
    q.submit(_job(tenant="a", prompt="a0"), now=0.0)
    q.submit(_job(tenant="a", prompt="a1"), now=1.0)
    q.submit(_job(tenant="b", prompt="b0"), now=0.5)
    assert [j.prompt for j in q.next_batch(3, now=2.0)] \
        == ["a0", "b0", "a1"]


# ---------------------------------------------------------------------------
# property (b): token-bucket quotas enforced within one refill window
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(rate=st.sampled_from([1.0, 5.0, 20.0]),
       burst=st.sampled_from([1, 3, 10]),
       attempts_per_s=st.sampled_from([10, 50, 200]))
def test_quota_enforced_within_refill_window(rate, burst, attempts_per_s):
    """Over any window [0, W] the accepted count never exceeds
    ``burst + rate * W`` (the token-bucket invariant), and the bucket
    admits again within one refill interval of a rejection."""
    q = _q(max_depth=100_000,
           quotas={"t0": TokenBucket(rate=rate, burst=float(burst))})
    window = 2.0
    accepted_times = []
    n = int(window * attempts_per_s)
    for i in range(n):
        now = i / attempts_per_s
        try:
            q.submit(_job(), now=now)
            accepted_times.append(now)
        except QuotaExceededError as e:
            assert e.retry_after <= 1.0 / rate + 1e-9
    for w_end in (0.25, 0.5, 1.0, 2.0):
        in_window = sum(1 for t in accepted_times if t <= w_end)
        assert in_window <= burst + rate * w_end + 1e-9
    # the bucket is a rate limit, not a ban: something was accepted, and
    # if the offered rate exceeds the quota something was rejected too
    assert accepted_times
    if attempts_per_s > rate * 2 and n > burst:
        assert q.stats.rejected_quota > 0


def test_quota_is_per_tenant():
    q = _q(quotas={"metered": TokenBucket(rate=1.0, burst=1)})
    q.submit(_job(tenant="metered"), now=0.0)
    with pytest.raises(QuotaExceededError):
        q.submit(_job(tenant="metered"), now=0.0)
    for _ in range(5):          # unmetered tenants are unaffected
        q.submit(_job(tenant="free"), now=0.0)


# ---------------------------------------------------------------------------
# merge_arrivals (satellite)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 20),
       rate=st.sampled_from([5.0, 50.0]))
def test_merge_arrivals_properties(n, seed, rate):
    reqs = list(RequestTrace(seed=seed).generate(n))
    a = poisson_arrivals(reqs, rate, seed=seed, tenant="a", tier="premium")
    b = bursty_arrivals(reqs, burst_size=4, burst_gap=0.2, seed_base=n,
                        tenant="b", tier="batch")
    m = merge_arrivals(a, b)
    assert len(m) == 2 * n
    times = [r.arrival_time for r in m]
    assert times == sorted(times)                         # merged timeline
    assert merge_arrivals(a, b) == m                      # deterministic
    assert merge_arrivals(a) == list(a)                   # identity
    # per-tenant order is preserved and tags travel with the requests
    assert [r.seed for r in m if r.tenant == "a"] == [r.seed for r in a]
    assert [r.seed for r in m if r.tenant == "b"] == [r.seed for r in b]
    assert {r.tier for r in m} == {"premium", "batch"}
    # distinct seed_bases keep generation seeds unique across the merge
    assert len({(r.tenant, r.seed) for r in m}) == 2 * n


def test_merge_arrivals_stable_tie_break():
    reqs = ["p0", "p1"]
    a = trace_arrivals(reqs, [0.0, 1.0], tenant="a")
    b = trace_arrivals(reqs, [0.0, 1.0], tenant="b", seed_base=2)
    m = merge_arrivals(a, b)
    # equal timestamps: argument order wins, then within-process order
    assert [(r.tenant, r.seed) for r in m] \
        == [("a", 0), ("b", 2), ("a", 1), ("b", 3)]
    assert merge_arrivals(b, a)[0].tenant == "b"


# ---------------------------------------------------------------------------
# result stores + handles (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["memory", "file"])
def test_result_store_roundtrip(kind, tmp_path):
    store = MemoryResultStore() if kind == "memory" \
        else FileResultStore(str(tmp_path))
    img = np.arange(12, dtype=np.float32).reshape(2, 2, 3)
    ref = store.put(7, img, {"tenant": "t0", "tier": "premium"})
    assert len(store) == 1
    assert np.array_equal(store.get(ref), img)
    assert store.meta(ref)["tier"] == "premium"
    ref2 = store.put(8, img * 2)                  # no metadata
    assert store.meta(ref2) == {}
    assert np.array_equal(store.get(ref2), img * 2)
    if kind == "file":
        assert ref.endswith("7.npy")              # survives the process
    else:
        assert ref == "mem:7"


def test_result_handle_sync_async_and_failure():
    store = MemoryResultStore()
    h = ResultHandle(1, store)
    assert not h.done() and h.ref is None
    ref = store.put(1, np.zeros((2, 2, 3), np.float32), {"k": "v"})
    h._resolve(ref, {"k": "v"})
    assert h.done() and h.wait(0.1) == ref and h.meta == {"k": "v"}
    assert h.image().shape == (2, 2, 3)
    assert asyncio.run(h.wait_async()) == ref     # stdlib asyncio bridge
    h2 = ResultHandle(2, store)
    h2._fail(GatewayClosedError("closed"))
    with pytest.raises(GatewayClosedError):
        h2.wait(0.1)


def test_jain_fairness_index():
    assert jain_fairness([5, 5, 5, 5]) == pytest.approx(1.0)
    assert jain_fairness([10, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_fairness([]) == 1.0 and jain_fairness([0, 0]) == 1.0


def test_tenant_tier_stats_keys_and_untagged():
    assert tenant_tier_stats([]) == {}


# ---------------------------------------------------------------------------
# integration: real dispatcher + real fleet
# ---------------------------------------------------------------------------


def _system(n_nodes=2):
    system, _, _, _ = build_system(n_nodes=n_nodes, corpus_n=60,
                                   capacity_per_node=80, seed=0)
    return system


# distinct scene captions (arbitrary free text all collapses onto one
# history-cache key under the proxy embedder, which would short-circuit
# routing entirely)
_PROMPTS = [caption_of(s) for s in all_specs()]


def _submit_wave(gw, n, *, tenant="t0", tier="standard", base_seed=0):
    return [gw.submit(_PROMPTS[(base_seed + i) % len(_PROMPTS)],
                      tenant=tenant, tier=tier, seed=base_seed + i)
            for i in range(n)]


def test_node_leave_mid_run_zero_accepted_job_loss():
    """Property (c): draining a node between waves loses nothing — every
    accepted handle resolves, and post-leave work routes to survivors."""
    system = _system(n_nodes=3)
    gw = Gateway(ServingEngine(system, max_batch=4))
    with gw:
        first = _submit_wave(gw, 8)
        for h in first:
            h.wait(timeout=120)
        gw.leave_node(1)
        second = _submit_wave(gw, 12, base_seed=100)
        for h in second:
            h.wait(timeout=120)
    st = gw.stats()
    assert st["accepted"] == st["jobs_served"] == 20   # zero loss
    assert all(h.done() for h in first + second)
    # everything admitted after the boundary rerouted off node 1
    # (node -1 = cache-hit fast path, which touches no node at all)
    assert all(h.meta["node"] != 1 for h in second)
    assert {h.meta["node"] for h in second} <= {0, 2, -1}
    assert any(h.meta["node"] in (0, 2) for h in second)
    assert all(h.image() is not None for h in second)


def test_node_join_mid_run_grows_fleet_and_routes():
    system = _system(n_nodes=2)
    engine = ServingEngine(system, max_batch=4)
    gw = Gateway(engine)
    with gw:
        first = _submit_wave(gw, 4)
        for h in first:
            h.wait(timeout=120)
        gw.join_node(speed=50.0)     # much faster than the incumbents
        second = _submit_wave(gw, 8, tenant="t1", base_seed=100)
        for h in second:
            h.wait(timeout=120)
    assert len(system.dbs) == 3
    assert system.scheduler.nodes[2].speed == 50.0
    assert system.cluster_index.n_nodes == 3           # index rebuilt
    st = gw.stats()
    assert st["accepted"] == st["jobs_served"] == 12   # zero loss


def test_engine_join_node_direct():
    system = _system(n_nodes=2)
    engine = ServingEngine(system, max_batch=4)
    cap_before = system.cache_capacity
    idx = engine.join_node(speed=50.0)
    assert idx == 2 and len(system.dbs) == 3
    assert system.cache_capacity == cap_before + system.dbs[0].capacity
    # the joiner serves work: a quality-tier repeat whose history entry
    # was evicted (cache maintenance removes image files synchronously)
    # pins to the fastest alive node via the priority fast path — now
    # the joiner
    engine.serve_group([Request(_PROMPTS[7], 0, quality_tier=True)])
    sched = system.scheduler
    sched.invalidate_payloads(list(sched._hist_payloads))
    [done] = engine.serve_group([Request(_PROMPTS[7], 1,
                                         quality_tier=True)])
    assert done.result.fast_path == "priority"
    assert done.result.node == 2
    # a join clones node 0's VDB config; an empty fleet has none to clone
    system.dbs.clear()
    with pytest.raises(RuntimeError):
        system.join_node()


def test_gateway_backpressure_and_no_drain_close():
    system = _system(n_nodes=2)
    gw = Gateway(ServingEngine(system, max_batch=4), max_depth=3)
    # not started: jobs queue up, fourth submit hits the depth bound
    handles = _submit_wave(gw, 3)
    with pytest.raises(BackpressureError):
        gw.submit("overflow", tenant="t0")
    # close without drain fails still-queued handles typed
    gw.start()
    gw.close(drain=False)
    for h in handles:
        if not h.done():
            continue
    failed = 0
    for h in handles:
        try:
            h.wait(timeout=5)
        except GatewayClosedError:
            failed += 1
    assert failed + gw.stats()["jobs_served"] == 3


def test_gateway_parity_with_direct_run():
    """Property (d): the gateway path (queue -> dispatcher -> serve_group
    -> result store) returns bitwise the images a direct
    ``ServingEngine.run`` produces over the same merged trace.

    Uses a verified parity trace seed (see test_serving_continuous) so
    batch partitioning cannot change results, and FIFO dequeue so group
    order matches submission order.
    """
    n, tseed = 16, 3
    reqs = list(RequestTrace(seed=tseed).generate(n))
    zeros = [0.0] * (n // 2)
    merged = merge_arrivals(
        trace_arrivals(reqs[:n // 2], zeros, tenant="a", tier="standard"),
        trace_arrivals(reqs[n // 2:], zeros, tenant="b", tier="standard",
                       seed_base=n // 2))

    direct = ServingEngine(_system(), max_batch=4)
    direct_done = direct.run(merged)
    assert len(direct_done) == n

    gw = Gateway(ServingEngine(_system(), max_batch=4), fair=False)
    handles = [gw.submit(r.prompt, tenant=r.tenant, tier=r.tier,
                         seed=r.seed, quality_tier=r.quality_tier)
               for r in merged]                     # queued before start
    with gw:
        for h in handles:
            h.wait(timeout=240)

    for h, comp in zip(handles, direct_done):
        assert np.array_equal(h.image(), comp.result.image), \
            f"gateway image diverged for job {h.job_id}"
        assert h.meta["route"] == (comp.result.fast_path
                                   or comp.result.route.value)
        assert h.meta["node"] == comp.result.node
    # both paths carry the tenant/tier tags into the same stats keys
    for eng in (direct, gw.engine):
        tagged = tenant_tier_stats(eng.completed)
        assert set(tagged) == {("a", "standard"), ("b", "standard")}
        assert all(s["n"] == n // 2 for s in tagged.values())


def test_premium_tier_maps_to_priority_fast_path():
    """The dispatcher derives ``quality_tier`` from the tier (premium =
    level 0 ⇒ True), so a premium repeat whose history entry was evicted
    rides the scheduler's priority pin path."""
    system = _system(n_nodes=2)
    gw = Gateway(ServingEngine(system, max_batch=2))
    with gw:
        gw.submit(_PROMPTS[3], tenant="t0", tier="premium",
                  seed=0).wait(timeout=120)
        # cache maintenance dropped the archived image (worker is idle
        # here, so poking the scheduler between groups is race-free)
        sched = system.scheduler
        sched.invalidate_payloads(list(sched._hist_payloads))
        repeat = gw.submit(_PROMPTS[3], tenant="t0", tier="premium",
                           seed=1)
        # a standard-tier job is NOT quality traffic: same repeat, no pin
        plain = gw.submit(_PROMPTS[3], tenant="t0", tier="standard",
                          seed=2)
        repeat.wait(timeout=120)
        plain.wait(timeout=120)
    assert repeat.meta["route"] == "priority"
    assert plain.meta["route"] != "priority"


# ---------------------------------------------------------------------------
# dispatcher robustness: hung-shutdown detection, transient group retry
# ---------------------------------------------------------------------------


class _GatedBackend:
    """Delegating backend whose generation calls block on an event —
    lets a test hold the dispatcher worker mid-group deterministically."""

    def __init__(self, inner):
        import threading
        self._inner = inner
        self.gate = threading.Event()
        self.entered = threading.Event()

    def _wait(self):
        self.entered.set()
        assert self.gate.wait(timeout=120)

    def txt2img_batch(self, *a, **kw):
        self._wait()
        return self._inner.txt2img_batch(*a, **kw)

    def img2img_batch(self, *a, **kw):
        self._wait()
        return self._inner.img2img_batch(*a, **kw)

    def resume_batch(self, *a, **kw):
        self._wait()
        return self._inner.resume_batch(*a, **kw)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_stop_timeout_warns_and_keeps_thread_handle():
    """Satellite regression: a ``stop(timeout=...)`` that expires with
    the worker still alive must WARN and keep the thread handle (so
    ``running`` stays truthful and a later ``stop`` can re-join) instead
    of silently dropping it."""
    system = _system(n_nodes=2)
    backend = _GatedBackend(system.backend)
    system.backend = backend
    gw = Gateway(ServingEngine(system, max_batch=2))
    gw.start()
    h = gw.submit("a never-cached prompt to force generation", seed=0)
    assert backend.entered.wait(timeout=120)     # worker is mid-group
    with pytest.warns(RuntimeWarning, match="did not stop"):
        gw.close(timeout=0.05)
    assert gw.dispatcher.running                 # handle kept, truthful
    backend.gate.set()                           # un-wedge the worker
    gw.close(timeout=120)                        # re-join succeeds
    assert not gw.dispatcher.running
    assert gw.dispatcher._thread is None
    assert h.done()


def test_transient_group_failure_retries_then_serves():
    """A group that dies of a transient backend fault is retried with
    backoff at the dispatcher level (on top of the Generate stage's
    in-call budget) — the handles still resolve, nothing is failed."""
    from repro.core.pipeline import TransientBackendError
    from repro.faults import FlakyBackend

    system = _system(n_nodes=2)
    system.transient_retries = 0       # defeat the in-call retry budget
    system.backend = FlakyBackend(system.backend)
    system.backend.arm(1)
    gw = Gateway(ServingEngine(system, max_batch=2))
    gw.dispatcher.retry_backoff = 0.001
    with gw:
        h = gw.submit("transient-retry probe prompt", seed=0)
        assert h.image() is not None             # group retried, served
    assert system.backend.faults_injected == 1
    assert gw.stats()["jobs_served"] == 1


def test_transient_group_failure_beyond_budget_fails_handles():
    from repro.core.pipeline import TransientBackendError
    from repro.faults import FlakyBackend

    system = _system(n_nodes=2)
    system.transient_retries = 0
    system.backend = FlakyBackend(system.backend)
    gw = Gateway(ServingEngine(system, max_batch=2))
    gw.dispatcher.max_group_retries = 2
    gw.dispatcher.retry_backoff = 0.001
    system.backend.arm(10**6)                    # never recovers
    with gw:
        h = gw.submit("doomed prompt", seed=0)
        with pytest.raises(TransientBackendError):
            h.wait(timeout=120)
    # exactly initial attempt + max_group_retries in-call failures
    assert system.backend.faults_injected == 3
