"""Partitioning rules, moment-spec derivation, ZeRO/FSDP extension, and
the logical-axis constraint machinery."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim.adafactor import adafactor_init
from repro.optim.adamw import adamw_init
from repro.runtime import partition
from repro.runtime.pspec import (decode_rules, logical_constraint,
                                 logical_rules, resolve_spec, train_rules)
from repro.runtime.steps import _MeshShim


def test_lm_rules_hit_expected_paths():
    assert partition.spec_for("embed", (1000, 64),
                              partition.LM_RULES) == P("model", None)
    assert partition.spec_for("group0/attn/wq/w", (64, 128),
                              partition.LM_RULES) == P(None, "model")
    # stacked (scan) params get the leading None automatically
    assert partition.spec_for("group0/attn/wq/w", (24, 64, 128),
                              partition.LM_RULES) == P(None, None, "model")
    assert partition.spec_for("group0/moe/w_gate", (24, 8, 64, 128),
                              partition.LM_RULES) == P(None, "model", None, None)
    assert partition.spec_for("final_norm/scale", (64,),
                              partition.LM_RULES) == P(None)


def test_tree_specs_on_sds():
    sds = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((4, 32, 64),
                                                     jnp.float32)}},
           "other": jax.ShapeDtypeStruct((7,), jnp.float32)}
    specs = partition.tree_specs(sds, partition.LM_RULES)
    assert specs["attn"]["wq"]["w"] == P(None, None, "model")
    assert specs["other"] == P(None)


def test_zero_extend_spec():
    mesh = _MeshShim({"data": 4, "model": 2})
    spec = partition.zero_extend_spec(P(None, "model"), (8, 16), mesh)
    assert spec == P("data", "model")
    # indivisible dims stay unsharded
    spec2 = partition.zero_extend_spec(P(None, "model"), (3, 16), mesh)
    assert spec2 == P(None, "model")


def test_fsdp_specs_shard_every_large_param():
    mesh = _MeshShim({"data": 4, "model": 2})
    sds = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    specs = {"w": P(None, "model")}
    out = partition.fsdp_specs(specs, sds, mesh)
    assert out["w"] == P("data", "model")


def test_derive_state_specs_adamw():
    params = {"layer": {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}}
    p_specs = {"layer": {"w": P("data", "model"), "b": P(None)}}
    opt_sds = jax.eval_shape(adamw_init, params)
    mesh = _MeshShim({"data": 4, "model": 2})
    specs = partition.derive_state_specs(opt_sds, p_specs, params, mesh=mesh)
    assert specs.m["layer"]["w"] == P("data", "model")
    assert specs.v["layer"]["w"] == P("data", "model")
    assert specs.count == P()


def test_derive_state_specs_adafactor_factored():
    params = {"w": jnp.zeros((256, 512))}
    p_specs = {"w": P("data", "model")}
    opt_sds = jax.eval_shape(adafactor_init, params)
    mesh = _MeshShim({"data": 4, "model": 2})
    specs = partition.derive_state_specs(opt_sds, p_specs, params, mesh=mesh)
    # row drops the last axis, col drops the second-to-last
    assert specs.v["w"].row == P("data")
    assert specs.v["w"].col == P("model")


def test_logical_constraint_is_noop_without_rules():
    x = jnp.ones((4, 4))
    y = logical_constraint(x, "batch", "model")
    assert (y == x).all()


def test_resolve_spec_under_rules():
    with logical_rules(train_rules(multi_pod=True)):
        spec = resolve_spec("batch", None, "model")
        assert spec == P(("pod", "data"), None, "model")


def test_decode_rules_variants():
    r = decode_rules(False, shard_kv=None)
    assert r["batch"] == ("data",) and r["kv_seq"] is None
    r = decode_rules(False, shard_kv="model")
    assert r["kv_seq"] == "model"
    r = decode_rules(True, shard_kv="data_model")
    assert r["batch"] is None
    assert r["kv_seq"] == ("pod", "data", "model")


def test_count_sharded_bytes():
    mesh = _MeshShim({"data": 4, "model": 2})
    tree = {"w": jnp.zeros((8, 16), jnp.float32)}
    specs = {"w": P("data", "model")}
    n = partition.count_sharded_bytes(tree, specs, mesh)
    assert n == 8 * 16 * 4 // 8
