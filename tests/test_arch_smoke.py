"""Per-arch smoke tests: every assigned architecture instantiates at a
REDUCED same-family config and runs one step per shape-kind on CPU —
output shapes + finiteness.  (Full configs are exercised only by the
dry-run via ShapeDtypeStructs.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, get_shape, list_archs
from repro.runtime.steps import build_cell_program
from repro.utils import param_count

ALL_ARCHS = list(list_archs())


def _materialize(sds_tree, key):
    def mk(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            if s.ndim == 0:
                return jnp.zeros(s.shape, s.dtype)
            return jax.random.randint(key, s.shape, 0, 8).astype(s.dtype)
        return (jax.random.normal(key, s.shape) * 0.05).astype(s.dtype)
    return jax.tree_util.tree_map(
        mk, sds_tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _run_cell(arch_name, shape_name):
    arch = get_arch(arch_name)
    cell = get_shape(arch.family_group, shape_name)
    prog = build_cell_program(arch, cell, reduced=True)
    state = prog.init_fn(jax.random.key(0))
    args = [state] + [_materialize(a, jax.random.key(i + 1))
                      for i, a in enumerate(prog.args_sds[1:])]
    out = jax.jit(prog.step_fn)(*args)
    for leaf in jax.tree_util.tree_leaves(out):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf))), \
                f"{arch_name}/{shape_name}: non-finite output"
    return prog, out


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_train_smoke(arch_name):
    arch = get_arch(arch_name)
    shape = {"lm": "train_4k", "diffusion": "train_256",
             "vision": "cls_224"}[arch.family_group]
    prog, out = _run_cell(arch_name, shape)
    state, metrics = out
    assert "loss" in metrics
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch_name",
                         [a for a in ALL_ARCHS
                          if get_arch(a).family_group == "lm"])
def test_lm_prefill_and_decode_smoke(arch_name):
    prog, out = _run_cell(arch_name, "prefill_32k")
    logits, caches = out
    assert logits.shape[1] == 1
    prog, out = _run_cell(arch_name, "decode_32k")
    logits, new_caches = out
    assert logits.shape[1] == 1
    prog, out = _run_cell(arch_name, "long_500k")
    logits, _ = out
    assert logits.shape[0] == 2  # reduced decode batch


@pytest.mark.parametrize("arch_name",
                         [a for a in ALL_ARCHS
                          if get_arch(a).family_group == "diffusion"])
def test_diffusion_gen_smoke(arch_name):
    prog, out = _run_cell(arch_name, "gen_1024")
    # one denoising step keeps the latent shape
    assert out.shape == prog.args_sds[1].shape
    _run_cell(arch_name, "gen_fast")


@pytest.mark.parametrize("arch_name",
                         [a for a in ALL_ARCHS
                          if get_arch(a).family_group == "vision"])
def test_vision_infer_smoke(arch_name):
    prog, out = _run_cell(arch_name, "serve_b1")
    assert out.ndim == 2          # (B, n_classes)
    prog, out = _run_cell(arch_name, "serve_b128")
    assert out.shape[0] == 2      # reduced batch


def test_full_configs_param_counts():
    """Audit the headline parameter counts of the full (non-reduced)
    configs via eval_shape — no allocation."""
    from repro.models.transformer.lm import init_lm

    expected = {
        "llama4-maverick-400b-a17b": (3.5e11, 4.5e11),
        "qwen3-14b": (1.3e13 / 1e3, 1.6e10),   # 13–16 B
        "qwen2-0.5b": (4.0e8, 6.0e8),
    }
    for name, (lo, hi) in expected.items():
        arch = get_arch(name)
        cell = get_shape("lm", "train_4k")
        cfg = arch.make_config(cell)
        sds = jax.eval_shape(lambda k, c=cfg: init_lm(k, c),
                             jax.random.key(0))
        n = param_count(sds)
        assert lo <= n <= hi, f"{name}: {n:.3g} params outside [{lo:.3g},{hi:.3g}]"


def test_all_40_cells_enumerate():
    from repro.configs import all_cells
    cells = list(all_cells())
    assert len(cells) == 40
    kinds = {c.kind for _, c in cells}
    assert kinds == {"train", "prefill", "decode", "gen", "infer"}
