"""Fault-domain hardening: deterministic chaos harness, degraded-mode
serving, and crash-restart recovery properties.

Three layers are pinned here:

* the harness itself — ``FaultSchedule`` validation and bit-for-bit
  replayability (same schedule + same trace → same injector log, same
  images), the saturating ``FlakyBackend`` arm contract;
* degraded-mode serving — per-node EWMA health + circuit-breaker state
  machine on the scheduler, transient-fault retry budgets end-to-end,
  and the checksum-verify path: a corrupted archived reference NEVER
  reaches a client — the hit degrades to the full txt2img miss path and
  produces exactly the image a fresh miss would have;
* crash-restart recovery — a crashed node journal-replays to a bitwise
  copy of its pre-crash cache, rejoins through the join_node machinery,
  and an interrupted trace finishes identical to an uninterrupted twin.

Chaos acceptance (both serving modes): zero accepted-job loss under the
full scripted ``chaos`` preset (crash + rejoin + corruption + transient
backend faults + slow-node stall).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.scheduler import UnknownNodeError
from repro.core.pipeline import TransientBackendError
from repro.core.trace import RequestTrace, bursty_arrivals
from repro.faults import (FaultEvent, FaultInjector, FaultSchedule,
                          FlakyBackend, attach_journals)
from repro.launch.serve import build_system
from repro.runtime.serving import ServingEngine


def _system(n_nodes=3, corpus_n=80):
    system, _, _, captions = build_system(
        n_nodes=n_nodes, corpus_n=corpus_n, capacity_per_node=80, seed=0)
    return system, captions


def _trace(n, seed=0):
    return list(RequestTrace(seed=seed).generate(n))


# ---------------------------------------------------------------------------
# schedule: validation + determinism
# ---------------------------------------------------------------------------


def test_fault_event_and_preset_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=0, kind="meteor")
    with pytest.raises(ValueError, match="step must be"):
        FaultEvent(step=-1, kind="crash")
    with pytest.raises(ValueError, match="unknown preset"):
        FaultSchedule.preset("nope", nodes=2, horizon=20)
    with pytest.raises(ValueError, match="nodes >= 2"):
        FaultSchedule.preset("crash", nodes=1, horizon=20)
    s = FaultSchedule.preset("chaos", nodes=3, horizon=40, seed=7)
    kinds = {e.kind for e in s.events}
    assert kinds == {"crash", "corrupt", "transient", "stall"}
    assert s.horizon <= 40
    assert all(s.at(e.step) for e in s.events)


def test_schedule_rng_is_a_pure_function_of_seed_and_step():
    a = FaultSchedule(events=(), seed=3)
    b = FaultSchedule(events=(), seed=3)
    for step in (0, 7, 31):
        np.testing.assert_array_equal(a.rng(step).integers(0, 1000, 8),
                                      b.rng(step).integers(0, 1000, 8))
    assert not np.array_equal(a.rng(0).integers(0, 1000, 8),
                              a.rng(1).integers(0, 1000, 8))
    g1 = FaultSchedule.generate(nodes=3, horizon=200, seed=5)
    g2 = FaultSchedule.generate(nodes=3, horizon=200, seed=5)
    assert g1.events == g2.events
    assert g1.events != FaultSchedule.generate(nodes=3, horizon=200,
                                               seed=6).events


def test_flaky_backend_arm_is_saturating():
    """Two transient events with no backend call between them expose at
    most ``max(count)`` consecutive faults — the property that keeps any
    scripted schedule inside the serving stack's retry budget."""
    class Inner:
        def txt2img_batch(self, p, s, seeds):
            return "ok"

    fb = FlakyBackend(Inner())
    fb.arm(2)
    fb.arm(1)                    # saturates at 2, does NOT stack to 3
    assert fb._armed == 2
    for _ in range(2):
        with pytest.raises(TransientBackendError):
            fb.txt2img_batch([], 0, [])
    assert fb.txt2img_batch([], 0, []) == "ok"
    assert fb.faults_injected == 2


# ---------------------------------------------------------------------------
# fail_node edges (satellite: safe under repeated / invalid calls)
# ---------------------------------------------------------------------------


def test_fail_node_invalid_repeated_and_last_alive():
    system, _ = _system(n_nodes=3)
    eng = ServingEngine(system, max_batch=4)
    for bad in (-1, 3, 99):
        with pytest.raises(UnknownNodeError):
            eng.fail_node(bad)
    eng.fail_node(1)
    assert not system.scheduler.nodes[1].alive
    state = system.dbs[1].snapshot()
    eng.fail_node(1)                         # repeated: an exact no-op
    for k, v in system.dbs[1].snapshot().items():
        np.testing.assert_array_equal(v, state[k])
    eng.fail_node(0)
    with pytest.raises(RuntimeError, match="last alive"):
        eng.fail_node(2)                     # the fleet never goes dark
    assert system.scheduler.nodes[2].alive
    with pytest.raises(UnknownNodeError):
        system.crash_node(5)
    with pytest.raises(RuntimeError, match="last alive"):
        system.crash_node(2)


def test_rejoin_validation():
    system, _ = _system(n_nodes=3)
    with pytest.raises(RuntimeError, match="alive"):
        system.rejoin_node(0)                # can't rejoin a live node
    system.fail_node(0)
    from repro.core.vdb import VectorDB
    with pytest.raises(ValueError, match="shape"):
        system.rejoin_node(0, VectorDB(system.dbs[0].dim + 1,
                                       system.dbs[0].capacity))
    system.rejoin_node(0)
    assert system.scheduler.nodes[0].alive
    assert system.cluster_index.n_nodes == len(system.dbs)


# ---------------------------------------------------------------------------
# health EWMA + circuit breaker state machine
# ---------------------------------------------------------------------------


def test_breaker_open_half_open_closed_cycle():
    system, _ = _system(n_nodes=3)
    sched = system.scheduler
    h = sched.nodes[0].health
    assert h.ewma == 1.0 and h.state == "closed"
    for _ in range(sched.breaker_threshold - 1):
        sched.observe_fault(0)
    assert h.state == "closed" and h.ewma < 1.0
    sched.observe_fault(0)                       # threshold reached
    assert h.state == "open" and h.cooldown == sched.breaker_cooldown
    assert 0 not in {n.index for n in sched._routable_nodes()}
    for _ in range(sched.breaker_cooldown):
        sched._breaker_tick()
    assert h.state == "half_open"                # probe-back window
    assert 0 in {n.index for n in sched._routable_nodes()}
    sched.observe_fault(0)                       # probe fails: reopen
    assert h.state == "open"
    for _ in range(sched.breaker_cooldown):
        sched._breaker_tick()
    sched.observe_ok(0)                          # probe succeeds
    assert h.state == "closed"
    for _ in range(200):
        sched.observe_ok(0)
    assert h.ewma == pytest.approx(1.0)
    # fault-free nodes keep ewma EXACTLY 1.0 (the no-penalty guard that
    # preserves bitwise fault-free routing parity)
    assert sched.nodes[1].health.ewma == 1.0
    sched.observe_ok(1)
    assert sched.nodes[1].health.ewma == 1.0


def test_open_breaker_routes_around_until_probe_back():
    system, _ = _system(n_nodes=3)
    sched = system.scheduler
    for _ in range(sched.breaker_threshold):
        sched.observe_fault(1)
    routable = {n.index for n in sched._routable_nodes()}
    assert routable == {0, 2}
    # breaker-open is NOT node death: with every breaker open the
    # fallback routes to all alive nodes rather than nowhere
    for node in (0, 2):
        for _ in range(sched.breaker_threshold):
            sched.observe_fault(node)
    assert {n.index for n in sched._routable_nodes()} == {0, 1, 2}


# ---------------------------------------------------------------------------
# corrupted reference → degraded miss-path serve (never a bad image)
# ---------------------------------------------------------------------------


def test_corrupt_hit_degrades_to_exact_miss_path_image():
    system, captions = _system()
    prompt = captions[0]
    warm = system.serve(prompt, seed=0)
    assert not warm.degraded
    for bid in list(system.blob_store._blobs):
        system.blob_store.corrupt(bid)
    bids_before = set(system.blob_store._blobs)
    res = system.serve(prompt, seed=1)
    # the corrupted hit fell back to the FULL generation path and the
    # image is exactly what a pure miss would have produced
    assert res.degraded and res.route.value == "txt2img"
    assert res.steps == system.policy.steps_full
    expected = system.backend.txt2img_batch(
        [prompt], system.policy.steps_full, [1])[0]
    np.testing.assert_array_equal(res.image, expected)
    assert system.stats.corrupt_hits >= 1
    assert system.stats.degraded_serves >= 1
    # the matched reference was quarantined: its blob is deleted (the
    # degraded serve then archives a FRESH image, so compare id sets,
    # not counts)
    assert bids_before - set(system.blob_store._blobs)
    # the quarantined slots are gone from every node's index
    for db in system.dbs:
        assert not np.any(db.payload_ids[db.valid] < 0)


def test_corrupt_quarantine_attributes_fault_to_owner_node():
    system, captions = _system()
    prompt = captions[3]
    system.serve(prompt, seed=0)
    for bid in list(system.blob_store._blobs):
        system.blob_store.corrupt(bid)
    system.serve(prompt, seed=1)
    assert any(n.health.ewma < 1.0 for n in system.scheduler.nodes)


# ---------------------------------------------------------------------------
# transient backend faults: retry budget end-to-end
# ---------------------------------------------------------------------------


def test_transient_faults_absorbed_within_retry_budget():
    system, _ = _system()
    system.backend = FlakyBackend(system.backend)
    system.backend.arm(system.transient_retries)     # exactly absorbable
    res = system.serve("a prompt no cache has seen", seed=42)
    assert res.image is not None and not res.degraded
    assert system.stats.transient_retries == system.transient_retries
    assert system.backend.faults_injected == system.transient_retries
    node = res.node
    assert node >= 0 and system.scheduler.nodes[node].health.ewma < 1.0
    assert system.scheduler.nodes[node].health.consecutive_faults == 0


def test_transient_faults_beyond_budget_reraise():
    system, _ = _system()
    system.transient_retries = 0
    system.backend = FlakyBackend(system.backend)
    system.backend.arm(1)
    with pytest.raises(TransientBackendError):
        system.serve("another never-cached prompt", seed=0)


# ---------------------------------------------------------------------------
# chaos acceptance: zero accepted-job loss in BOTH serving modes
# ---------------------------------------------------------------------------


def _chaos_run(step_level, journal_root=None):
    system, _ = _system()
    reqs = _trace(36)
    arr = bursty_arrivals(reqs, burst_size=7, burst_gap=0.4)
    journals = (attach_journals(system, str(journal_root),
                                snapshot_every=16)
                if journal_root is not None else None)
    # injection boundaries ≈ denoising steps (step-level) vs admission
    # groups (~one per burst) — scale the preset to what the run sees
    horizon = 120 if step_level else 10
    sched = FaultSchedule.preset("chaos", nodes=3, horizon=horizon, seed=1)
    inj = FaultInjector(system, sched, journals=journals)
    eng = ServingEngine(system, max_batch=8)
    kw = dict(step_level=True, slot_capacity=4) if step_level else {}
    done = eng.run(arr, on_step=inj.on_step, **kw)
    inj.finish()
    return system, done, reqs, inj.report()


def test_chaos_group_mode_zero_loss():
    system, done, reqs, rep = _chaos_run(step_level=False)
    assert len(done) == len(reqs)
    assert all(c.result.image is not None for c in done)
    assert rep["actions"]["crash"] == 1
    assert rep["actions"]["rejoin-cold"] == 1    # no journal attached
    assert rep["actions"]["unstall"] == 1
    assert rep["faults_injected"] > 0            # transients really fired
    assert rep["corrupt_hits"] > 0               # corruption really bit
    assert all(system.scheduler.nodes[i].alive for i in range(3))


def test_chaos_step_level_zero_loss_with_journaled_rejoin(tmp_path):
    system, done, reqs, rep = _chaos_run(step_level=True,
                                         journal_root=tmp_path)
    assert len(done) == len(reqs)
    assert all(c.result.image is not None for c in done)
    assert rep["actions"]["crash"] == 1
    assert rep["actions"]["rejoin-journaled"] == 1
    assert rep["transient_retries"] > 0
    assert system.dbs[2].size > 0                # rejoined WITH its cache


def test_chaos_replay_is_bit_for_bit():
    """Same schedule + same trace twice → identical injector log,
    identical route mix, bitwise-identical images."""
    sys_a, done_a, _, rep_a = _chaos_run(step_level=False)
    sys_b, done_b, _, rep_b = _chaos_run(step_level=False)
    assert rep_a["log"] == rep_b["log"]
    assert sys_a.stats.route_counts == sys_b.stats.route_counts
    for a, b in zip(done_a, done_b):
        np.testing.assert_array_equal(a.result.image, b.result.image)


# ---------------------------------------------------------------------------
# crash-restart recovery: bitwise journal replay + interrupted-run parity
# ---------------------------------------------------------------------------


def test_crash_replay_bitwise_and_interrupted_run_parity(tmp_path):
    """The satellite property: serve half the trace, hard-crash the
    busiest node, journal-replay it (bitwise-equal to the instant of the
    crash), rejoin, finish the trace — every post-rejoin result is
    identical to an uninterrupted twin's."""
    reqs = _trace(40, seed=2)
    cut = 20

    twin, _ = _system()
    attach_journals(twin, str(tmp_path / "twin"), snapshot_every=16)
    twin_res = [twin.serve(r.prompt, seed=i) for i, r in enumerate(reqs)]

    system, _ = _system()
    journals = attach_journals(system, str(tmp_path / "crashed"),
                               snapshot_every=16)
    res = [system.serve(r.prompt, seed=i)
           for i, r in enumerate(reqs[:cut])]
    victim = max(range(3), key=lambda n: system.dbs[n].size)
    old = system.crash_node(victim)
    assert system.dbs[victim].size == 0          # cache really lost
    j = journals[victim]
    db = j.replay(old.dim, old.capacity, name=old.name,
                  use_pallas=old.use_pallas, interpret=old.interpret)
    live, rest = old.snapshot(), db.snapshot()   # bitwise-equal BEFORE
    assert set(live) == set(rest)                # the node rejoins
    for k in live:
        np.testing.assert_array_equal(live[k], rest[k], err_msg=k)
    db.attach_journal(j)
    system.rejoin_node(victim, db)
    res += [system.serve(r.prompt, seed=cut + i)
            for i, r in enumerate(reqs[cut:])]

    for a, b in zip(twin_res, res):
        assert (a.fast_path or a.route.value) == (b.fast_path
                                                  or b.route.value)
        assert a.node == b.node and a.steps == b.steps
        np.testing.assert_array_equal(a.image, b.image)
    assert twin.stats.route_counts == system.stats.route_counts
    for db_a, db_b in zip(twin.dbs, system.dbs):
        for k, v in db_a.snapshot().items():
            np.testing.assert_array_equal(v, db_b.snapshot()[k],
                                          err_msg=k)
