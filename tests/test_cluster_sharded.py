"""Mesh-sharded cluster retrieval == single-device, BITWISE.

The sharded scans (``ClusterIndex(mesh_nodes > 1)`` running the per-node
kernels inside ``shard_map`` over a 1-D "nodes" device mesh) are only
shippable if every public result is bit-identical to the single-device
path: same scores, same slots, same tie-breaks, same routing.  This
suite pins that across

* randomized node mixes (empty / partial / full / overfull /
  non-uniform capacities) and node counts around the mesh size
  (1, mesh-1, mesh, mesh+3, 2*mesh+1 — exercising the masked-invalid
  node padding), on all three scan modes and both kernel paths
  (jnp ref oracles and the Pallas kernels);
* incremental add/evict/overwrite streams: the sharded index's donated
  row updates must land on the owning shard and leave device state equal
  to a fresh ``from_dbs`` re-stack, with ZERO steady-state slab uploads;
* equal-score candidates straddling a shard boundary: the cross-shard
  merge must reproduce the single-device (score desc, global-slot asc)
  tie-break, not all-gather arrival order;
* an end-to-end serve run: identical routes, images, and cache state at
  ``mesh_nodes=2`` vs ``mesh_nodes=1``.

Runs under the conftest-forced 8 host CPU devices; skips cleanly when
the backend initialised before the force could land.
"""
from __future__ import annotations

import numpy as np
import pytest

try:  # pragma: no cover - prefer the real engine when available
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # offline container: seeded-random shim
    from _hypothesis_shim import given, settings, strategies as st

import jax

from repro.core.cluster_index import ClusterIndex
from repro.core.vdb import VectorDB
from repro.utils import l2n

N_DEV = len(jax.devices())
MESH = 4
pytestmark = pytest.mark.skipif(
    N_DEV < MESH,
    reason=f"sharded parity suite needs >={MESH} XLA host devices, "
    f"got {N_DEV} (backend initialised before conftest forced them)")

DIM = 16
# node counts the issue calls out: 1, mesh-1, mesh, mesh+3, 2*mesh+1
NODE_COUNTS = (1, MESH - 1, MESH, MESH + 3, 2 * MESH + 1)


def _mixed_fleet(seed: int, n_nodes: int, dim: int = DIM):
    """Fleet with empty/partial/full/overfull nodes and non-uniform
    capacities, deterministically from ``seed``."""
    rng = np.random.default_rng(seed)
    caps = [int(rng.choice([8, 12, 16, 24])) for _ in range(n_nodes)]
    # fill styles cycle so every mix appears at every node count
    fills = []
    for ni, cap in enumerate(caps):
        style = (ni + seed) % 4
        fills.append({0: 0,                       # empty
                      1: max(1, cap // 2),        # partial
                      2: cap,                     # full
                      3: cap + cap // 2}[style])  # overfull (FIFO wraps)
    dbs, t = [], 0.0
    for cap, fill in zip(caps, fills):
        db = VectorDB(dim, cap)
        for j in range(fill):
            v = l2n(rng.standard_normal(dim).astype(np.float32))[None]
            tx = l2n(rng.standard_normal(dim).astype(np.float32))[None]
            db.add(v, tx, np.array([j], np.int64), t)
            t += 1.0
        dbs.append(db)
    return dbs, rng


def _pair(seed: int, n_nodes: int, *, use_pallas: bool, mesh_nodes: int):
    """Two identical fleets -> (single-device index, sharded index)."""
    dbs1, _ = _mixed_fleet(seed, n_nodes)
    dbs2, rng = _mixed_fleet(seed, n_nodes)
    ci1 = ClusterIndex.from_dbs(dbs1, use_pallas=use_pallas)
    cim = ClusterIndex.from_dbs(dbs2, use_pallas=use_pallas,
                                mesh_nodes=mesh_nodes)
    return ci1, cim, dbs1, dbs2, rng


def _assert_results_equal(r1, r2):
    assert len(r1) == len(r2)
    for (s1, i1), (s2, i2) in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# randomized scan parity
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_nodes=st.sampled_from(NODE_COUNTS),
       use_pallas=st.sampled_from([False, True]),
       qn=st.integers(1, 9),
       k=st.integers(1, 12))
def test_search_cluster_parity(seed, n_nodes, use_pallas, qn, k):
    """Global flat mode: sharded == single-device bitwise."""
    ci1, cim, _, _, rng = _pair(seed, n_nodes, use_pallas=use_pallas,
                                mesh_nodes=MESH)
    Q = rng.standard_normal((qn, DIM)).astype(np.float32)
    _assert_results_equal(ci1.search_cluster(Q, k), cim.search_cluster(Q, k))
    assert cim.stats["allgather_bytes"] > 0


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_nodes=st.sampled_from(NODE_COUNTS),
       use_pallas=st.sampled_from([False, True]),
       qn=st.integers(1, 9),
       k=st.integers(1, 12))
def test_search_cluster_nodes_parity(seed, n_nodes, use_pallas, qn, k):
    """Per-node mode (the schedule+retrieve fusion): sharded ==
    single-device bitwise for EVERY (query, node) pair."""
    ci1, cim, _, _, rng = _pair(seed, n_nodes, use_pallas=use_pallas,
                                mesh_nodes=MESH)
    Q = rng.standard_normal((qn, DIM)).astype(np.float32)
    r1 = ci1.search_cluster_nodes(Q, k)
    rm = cim.search_cluster_nodes(Q, k)
    assert len(r1) == len(rm)
    for per1, perm in zip(r1, rm):
        _assert_results_equal(per1, perm)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_nodes=st.sampled_from(NODE_COUNTS),
       use_pallas=st.sampled_from([False, True]),
       qn=st.integers(1, 9))
def test_search_batch_parity(seed, n_nodes, use_pallas, qn):
    """Masked (query->node) mode: sharded == single-device bitwise."""
    ci1, cim, _, _, rng = _pair(seed, n_nodes, use_pallas=use_pallas,
                                mesh_nodes=MESH)
    Q = rng.standard_normal((qn, DIM)).astype(np.float32)
    nids = rng.integers(0, n_nodes, qn)
    _assert_results_equal(
        ci1.search_batch(Q, nids, 5, count_queries=False),
        cim.search_batch(Q, nids, 5, count_queries=False))


def test_padding_rule():
    """Node counts not divisible by the mesh pad with masked-invalid
    nodes; divisible counts don't pad."""
    for n_nodes in NODE_COUNTS:
        dbs, _ = _mixed_fleet(0, n_nodes)
        ci = ClusterIndex.from_dbs(dbs, mesh_nodes=MESH)
        assert ci.padded_nodes % MESH == 0
        assert ci.padded_nodes >= n_nodes
        assert ci.padded_nodes - n_nodes < MESH
        # pad nodes are invalid forever -> they can never surface a hit
        full_valid = np.asarray(ci._valid)
        assert not full_valid[n_nodes:].any()
        # public device_state strips them
        slabs, valid = ci.device_state()
        assert slabs.shape[1] == n_nodes and valid.shape[0] == n_nodes


# ---------------------------------------------------------------------------
# incremental add/evict/overwrite streams
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_nodes=st.sampled_from(NODE_COUNTS),
       steps=st.integers(10, 60))
def test_incremental_stream_matches_restack(seed, n_nodes, steps):
    """A random add/evict/overwrite stream through the sharded index's
    donated row updates leaves device state identical to rebuilding from
    the numpy source of truth — with ZERO steady-state slab uploads and
    scan results still bitwise equal to the single-device index."""
    ci1, cim, dbs1, dbs2, rng = _pair(seed, n_nodes, use_pallas=False,
                                      mesh_nodes=MESH)
    uploads0 = cim.stats["slab_uploads"]
    t = 1_000.0
    for step in range(steps):
        node = int(rng.integers(0, n_nodes))
        a, b = dbs1[node], dbs2[node]
        if rng.random() < 0.25 and a.size > 0:
            slot = int(rng.integers(0, a.capacity))
            a.evict_slots(np.array([slot]))
            b.evict_slots(np.array([slot]))
        else:  # add (FIFO-overwrites once full)
            n_rows = int(rng.integers(1, 4))
            v = l2n(rng.standard_normal((n_rows, DIM)).astype(np.float32))
            tx = l2n(rng.standard_normal((n_rows, DIM)).astype(np.float32))
            ids = np.arange(n_rows, dtype=np.int64) + 10_000 + step * 10
            a.add(v, tx, ids, t)
            b.add(v, tx, ids, t)
            t += 1.0
    assert cim.stats["slab_uploads"] == uploads0          # rows only
    assert cim.stats["row_updates"] > 0
    # sharded incremental state == rebuilt from_dbs
    dev, val = cim.device_state()
    ref, rval = cim.rebuild_reference()
    np.testing.assert_array_equal(dev, ref)
    np.testing.assert_array_equal(val, rval)
    # and the scans still agree bitwise after the stream
    Q = rng.standard_normal((5, DIM)).astype(np.float32)
    _assert_results_equal(ci1.search_cluster(Q, 6), cim.search_cluster(Q, 6))
    nids = rng.integers(0, n_nodes, 5)
    _assert_results_equal(
        ci1.search_batch(Q, nids, 4, count_queries=False),
        cim.search_batch(Q, nids, 4, count_queries=False))


# ---------------------------------------------------------------------------
# tie-break regression: equal scores straddling a shard boundary
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True])
def test_tiebreak_across_shard_boundary(use_pallas, mesh_devices):
    """The classic all-gather reordering bug: plant the SAME vector in
    nodes owned by different shards (equal scores to the query) and
    require the sharded merge to rank them exactly as the single-device
    scan does — (score desc, global slot id asc) — on BOTH scan modes."""
    n_nodes, cap = 2 * MESH, 8     # nodes i and i+MESH live on one shard
    dim = DIM

    def build():
        rng = np.random.default_rng(42)
        dup = l2n(rng.standard_normal(dim).astype(np.float32))
        dbs, t = [], 0.0
        for ni in range(n_nodes):
            db = VectorDB(dim, cap)
            # every node holds the duplicate (ties across ALL shard
            # boundaries) plus one unique filler row
            filler = l2n(rng.standard_normal(dim).astype(np.float32))
            db.add(dup[None], dup[None], np.array([ni], np.int64), t)
            db.add(filler[None], filler[None],
                   np.array([100 + ni], np.int64), t + 0.5)
            dbs.append(db)
            t += 1.0
        return dbs, dup

    dbs1, dup = build()
    dbs2, _ = build()

    ci1 = ClusterIndex.from_dbs(dbs1, use_pallas=use_pallas)
    cim = ClusterIndex.from_dbs(dbs2, use_pallas=use_pallas,
                                mesh_nodes=MESH)
    Q = dup[None]  # exact match -> every node's copy scores identically
    k = n_nodes + 2

    r1 = ci1.search_cluster(Q, k)
    rm = cim.search_cluster(Q, k)
    _assert_results_equal(r1, rm)
    # the tie really happened and resolved by ascending global slot id:
    # slot 0 of node 0, then slot 0 of node 1, ...
    scores, slots = r1[0]
    n_dup = int((scores >= scores[0] - 1e-7).sum())
    assert n_dup == n_nodes
    np.testing.assert_array_equal(slots[:n_nodes],
                                  np.arange(n_nodes) * cap)

    # per-node mode: each node's own list must agree too
    r1n = ci1.search_cluster_nodes(Q, 3)
    rmn = cim.search_cluster_nodes(Q, 3)
    for per1, perm in zip(r1n, rmn):
        _assert_results_equal(per1, perm)


# ---------------------------------------------------------------------------
# per-device bytes + end-to-end serve parity
# ---------------------------------------------------------------------------


def test_per_device_bytes_shrink(mesh_devices):
    """Sharding exists to shrink per-device cache state: at mesh size M
    each device holds ~1/M of the slab bytes."""
    dbs1, _ = _mixed_fleet(3, 2 * MESH)
    dbs2, _ = _mixed_fleet(3, 2 * MESH)
    ci1 = ClusterIndex.from_dbs(dbs1)
    cim = ClusterIndex.from_dbs(dbs2, mesh_nodes=MESH)
    single = ci1.per_device_slab_bytes()
    sharded = cim.per_device_slab_bytes()
    assert sharded < single
    # padding may round the node axis up, but never past one extra
    # shard's worth relative to the ideal 1/M split
    assert sharded <= (single // MESH) * 2


def test_end_to_end_serve_parity():
    """Full request path at mesh_nodes=2 vs mesh_nodes=1: identical
    routes, node choices, images, and final cache state."""
    from repro.core.trace import RequestTrace
    from repro.launch.serve import build_system
    from repro.runtime.serving import ServingEngine

    def run(mesh_nodes):
        system, _, _, _ = build_system(
            n_nodes=4, corpus_n=120, capacity_per_node=80,
            mesh_nodes=mesh_nodes, seed=0)
        engine = ServingEngine(system, max_batch=8)
        trace = RequestTrace(seed=1)
        for i, r in enumerate(trace.generate(48)):
            engine.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
        done = engine.drain()
        return ([c.result.route.name for c in done],
                [c.result.node for c in done],
                [None if c.result.image is None
                 else np.asarray(c.result.image) for c in done],
                [(db.valid.copy(), db.img_vecs.copy()) for db in system.dbs],
                system)

    routes1, nodes1, imgs1, state1, _ = run(1)
    routes2, nodes2, imgs2, state2, sys2 = run(2)
    assert routes1 == routes2
    assert nodes1 == nodes2
    for a, b in zip(imgs1, imgs2):
        assert (a is None) == (b is None)
        if a is not None:
            np.testing.assert_array_equal(a, b)
    for (v1, g1), (v2, g2) in zip(state1, state2):
        np.testing.assert_array_equal(v1, v2)
        np.testing.assert_array_equal(g1, g2)
    # the sharded run really ran sharded
    assert sys2.cluster_index.mesh_nodes == 2
    assert sys2.cluster_index.stats["allgather_bytes"] > 0


# ---------------------------------------------------------------------------
# harness self-test
# ---------------------------------------------------------------------------


def test_forced_subprocess_harness(forced_subprocess):
    """The tiny subprocess runner really forces host devices in a fresh
    interpreter (the escape hatch when this process's backend is stuck
    on one device)."""
    proc = forced_subprocess(
        "import jax; print(len(jax.devices()))", n_devices=4)
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == "4"
