"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.vdb_topk import vdb_topk


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,sq,sk,h,d", [
    (1, 8, 8, 1, 8),
    (2, 16, 16, 2, 16),
    (1, 33, 47, 2, 8),      # non-multiple lengths exercise padding
    (2, 64, 128, 4, 32),
    (1, 128, 64, 2, 16),    # kv shorter than q
])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, sk, h, d, causal, dtype):
    if causal and sq > sk:
        pytest.skip("causal with sq > sk is undefined for this layout")
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (b, sq, h, d), dtype)
    k = jax.random.normal(k2, (b, sk, h, d), dtype)
    v = jax.random.normal(k3, (b, sk, h, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("n,d,k,block", [
    (32, 16, 4, 16),
    (100, 32, 8, 32),       # non-multiple db size
    (512, 64, 16, 128),
    (64, 8, 32, 64),        # k large relative to blocks
])
def test_vdb_topk_matches_ref(n, d, k, block):
    key = jax.random.key(1)
    kq, kd, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (3, d))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    db = jax.random.normal(kd, (n, d))
    valid = jax.random.bernoulli(kv, 0.8, (n,))
    s, i = vdb_topk(q, db, valid, k, block_n=block, interpret=True)
    s_ref, i_ref = ref.vdb_topk_ref(q, db, valid, k)
    # scores must match exactly (same arithmetic); indices may tie-break
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-5)
    # and every returned index must actually achieve its score
    for row in range(3):
        for col in range(k):
            if np.isfinite(s[row, col]):
                got = float(db[i[row, col]] @ q[row])
                assert abs(got - float(s[row, col])) < 1e-4


@pytest.mark.parametrize("shape,groups", [
    ((2, 8, 8, 16), 4),
    ((1, 16, 16, 32), 32),
    ((3, 4, 4, 24), 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_groupnorm_silu_matches_ref(shape, groups, dtype):
    key = jax.random.key(2)
    x = jax.random.normal(key, shape, dtype)
    c = shape[-1]
    scale = jnp.linspace(0.5, 1.5, c)
    bias = jnp.linspace(-0.2, 0.2, c)
    out = ops.groupnorm_silu(x, scale, bias, groups=groups)
    want = ref.groupnorm_silu_ref(x, scale, bias, groups=groups)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,t,d", [(2, 16, 32), (1, 100, 64), (4, 7, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adaln_matches_ref(b, t, d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(3), 3)
    x = jax.random.normal(k1, (b, t, d), dtype)
    shift = jax.random.normal(k2, (b, d), dtype)
    scale = jax.random.normal(k3, (b, d), dtype)
    out = ops.adaln_modulate(x, shift, scale)
    want = ref.adaln_modulate_ref(x, shift, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_grad_path():
    """The kernel is forward-only; the model dispatches to it only outside
    grad contexts — but the jnp fallback must be differentiable."""
    from repro.models.common.attention import sdpa
    key = jax.random.key(4)
    q = jax.random.normal(key, (1, 8, 2, 8))

    def loss(q):
        return jnp.sum(sdpa(q, q, q, causal=True))

    g = jax.grad(loss)(q)
    assert bool(jnp.all(jnp.isfinite(g)))
