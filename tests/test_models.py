"""Model-family behaviour: prefill/decode parity, MoE invariants,
diffusion backbones, samplers, VAE, chunked attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common.attention import _chunked_sdpa, sdpa
from repro.models.diffusion import dit as dit_mod
from repro.models.diffusion import mmdit as mmdit_mod
from repro.models.diffusion import unet as unet_mod
from repro.models.diffusion import vae as vae_mod
from repro.models.diffusion.sampler import (ddim_sample, ddpm_loss, rf_edit,
                                            rf_loss, rf_sample, sdedit_sample)
from repro.models.diffusion.schedule import DiffusionSchedule
from repro.models.transformer.lm import (LMConfig, apply_lm, apply_lm_decode,
                                         init_kv_cache, init_lm, lm_loss)
from repro.models.transformer.moe import MoEConfig, init_moe, moe_ffn


def tiny_lm(pattern=("dense",), **kw):
    # capacity_factor high enough that prefill never drops tokens (decode
    # uses a no-drop capacity), so prefill/decode parity is exact
    moe = MoEConfig(n_experts=4, top_k=2, d_ff=32,
                    capacity_factor=4.0) if "moe" in pattern else None
    defaults = dict(vocab=97, n_layers=2 * len(pattern), d_model=32,
                    n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                    pattern=pattern, moe=moe, max_seq=64)
    defaults.update(kw)
    return LMConfig(**defaults)


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern", [("dense",), ("moe",), ("dense", "moe")])
def test_prefill_decode_parity(pattern):
    """Decoding token-by-token must reproduce the full-forward logits."""
    cfg = tiny_lm(pattern)
    params = init_lm(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab)

    full_logits, _aux = apply_lm(params, cfg, toks)

    caches = init_kv_cache(cfg, 2, 16, dtype=jnp.float32)
    got = []
    for t in range(10):
        logits, caches = apply_lm_decode(params, cfg, toks[:, t: t + 1],
                                         caches, jnp.int32(t))
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_lm_loss_finite_and_improvable():
    cfg = tiny_lm(("dense", "moe"))
    params = init_lm(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (4, 12), 0, cfg.vocab)

    def loss(p):
        return lm_loss(p, cfg, toks[:, :-1], toks[:, 1:])[0]

    l0, g = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    p1 = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)
    assert float(loss(p1)) < float(l0)


def test_qk_norm_and_bias_variants():
    for kw in (dict(qk_norm=True), dict(qkv_bias=True),
               dict(tie_embeddings=True)):
        cfg = tiny_lm(**kw)
        params = init_lm(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab)
        logits, _ = apply_lm(params, cfg, toks)
        assert logits.shape == (1, 8, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def test_moe_output_is_gated_combination():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16)
    p = init_moe(jax.random.key(0), 8, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 6, 8))
    y, aux = moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    assert float(aux["lb_loss"]) > 0.0
    assert 0.0 <= float(aux["dropped_frac"]) <= 1.0


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff=8, capacity_factor=1.0)
    p = init_moe(jax.random.key(0), 4, cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, 4))
    # capacity=1 forces drops whenever routing is imbalanced
    y, aux = moe_ffn(p, cfg, x, capacity=1)
    assert float(aux["dropped_frac"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_respects_expert_permutation():
    """Permuting experts (and gathering router rows) permutes nothing
    observable: output must be identical."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16)
    p = init_moe(jax.random.key(0), 8, cfg)
    x = jax.random.normal(jax.random.key(1), (1, 5, 8))
    y0, _ = moe_ffn(p, cfg, x)
    perm = jnp.array([2, 0, 3, 1])
    p2 = dict(p)
    p2["router"] = {"w": p["router"]["w"][:, perm]}
    p2["w_gate"] = p["w_gate"][perm]
    p2["w_up"] = p["w_up"][perm]
    p2["w_down"] = p["w_down"][perm]
    y1, _ = moe_ffn(p2, cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal", [False, True])
def test_chunked_sdpa_matches_naive(causal):
    key = jax.random.key(7)
    q = jax.random.normal(key, (2, 64, 2, 16))
    out_chunked = _chunked_sdpa(q, q, q, causal=causal, block_k=16)
    # force the naive path (seq < threshold)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, q).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((64, 64), bool))
        logits = jnp.where(mask, logits, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd",
                      jax.nn.softmax(logits, -1).astype(q.dtype), q)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# diffusion
# ---------------------------------------------------------------------------


def test_dit_shapes_and_grad():
    cfg = dit_mod.DiTConfig(img_res=8, in_ch=4, patch=2, n_layers=2,
                            d_model=32, n_heads=4, ctx_dim=16)
    p = dit_mod.init_dit(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 8, 4))
    t = jnp.array([3.0, 7.0])
    ctx = jax.random.normal(jax.random.key(2), (2, 16))
    eps = dit_mod.apply_dit(p, cfg, x, t, ctx)
    assert eps.shape == x.shape

    def loss(p):
        return jnp.mean(jnp.square(dit_mod.apply_dit(p, cfg, x, t, ctx)))

    g = jax.grad(loss)(p)
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree_util.tree_leaves(g))


def test_unet_shapes():
    cfg = unet_mod.UNetConfig(in_ch=4, ch=16, ch_mult=(1, 2), n_res=1,
                              attn_factors=(2,), n_heads=2, ctx_dim=16,
                              groups=8)
    p = unet_mod.init_unet(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, 16, 4))
    ctx = jax.random.normal(jax.random.key(2), (1, 5, 16))
    out = unet_mod.apply_unet(p, cfg, x, jnp.array([5.0]), ctx)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_mmdit_shapes():
    cfg = mmdit_mod.MMDiTConfig(img_res=8, in_ch=4, patch=2, n_double=1,
                                n_single=1, d_model=32, n_heads=4,
                                txt_len=6, txt_dim=16, vec_dim=8)
    p = mmdit_mod.init_mmdit(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 8, 4))
    ctx = {"txt": jax.random.normal(jax.random.key(2), (2, 6, 16)),
           "vec": jax.random.normal(jax.random.key(3), (2, 8))}
    v = mmdit_mod.apply_mmdit(p, cfg, x, jnp.array([0.3, 0.9]), ctx)
    assert v.shape == x.shape


def test_vae_roundtrip_shapes():
    cfg = vae_mod.VAEConfig(in_ch=3, base_ch=8, ch_mult=(1, 2), z_ch=4)
    p = vae_mod.init_vae(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, 16, 3))
    mean, logvar = vae_mod.encode(p, cfg, x)
    assert mean.shape == (2, 4, 4, 4)
    out = vae_mod.decode(p, cfg, mean)
    assert out.shape == x.shape


# ---------------------------------------------------------------------------
# samplers — the paper's Figure 1 mechanism
# ---------------------------------------------------------------------------


def _identity_eps(x, t, ctx):
    """eps_fn that predicts zero noise — DDIM then contracts toward x0."""
    return jnp.zeros_like(x)


def test_ddim_and_sdedit_shapes():
    sched = DiffusionSchedule.linear(100)
    ctx = jnp.zeros((2, 4))
    out = ddim_sample(_identity_eps, sched, (2, 8, 8, 3), ctx,
                      jax.random.key(0), steps=5)
    assert out.shape == (2, 8, 8, 3)
    ref = jnp.ones((2, 8, 8, 3)) * 0.5
    out2 = sdedit_sample(_identity_eps, sched, ref, ctx, jax.random.key(1),
                         steps=4, strength=0.5)
    assert out2.shape == ref.shape


def test_sdedit_preserves_reference_structure():
    """Low strength keeps the output close to the reference — the paper's
    reason img2img needs fewer steps (Fig. 1)."""
    sched = DiffusionSchedule.linear(100)
    ctx = jnp.zeros((1, 4))
    ref = jnp.ones((1, 8, 8, 3)) * 0.8
    weak = sdedit_sample(_identity_eps, sched, ref, ctx, jax.random.key(2),
                         steps=5, strength=0.2)
    strong = sdedit_sample(_identity_eps, sched, ref, ctx, jax.random.key(2),
                           steps=5, strength=0.95)
    d_weak = float(jnp.mean(jnp.abs(weak - ref)))
    d_strong = float(jnp.mean(jnp.abs(strong - ref)))
    assert d_weak < d_strong


def test_rf_sampler_and_edit():
    def v_fn(x, t, ctx):
        return -x  # flow toward zero

    out = rf_sample(v_fn, (1, 4, 4, 2), None, jax.random.key(0), steps=8)
    assert out.shape == (1, 4, 4, 2)
    ref = jnp.ones((1, 4, 4, 2))
    out2 = rf_edit(v_fn, ref, None, jax.random.key(1), steps=4, strength=0.5)
    assert out2.shape == ref.shape


def test_losses_finite():
    sched = DiffusionSchedule.cosine(50)
    x0 = jax.random.normal(jax.random.key(0), (2, 8, 8, 3))
    l1 = ddpm_loss(_identity_eps, sched, x0, None, jax.random.key(1))
    l2 = rf_loss(lambda x, t, c: jnp.zeros_like(x), x0, None, jax.random.key(2))
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
