"""Fault-tolerance contract: atomic checkpoints, bitwise resume, NaN
rollback, failure injection + restart, elastic resharding."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, get_shape
from repro.data.pipeline import ShardedDataLoader
from repro.launch.train import make_diffusion_loader
from repro.runtime.elastic import parse_spec, reshard_checkpoint
from repro.runtime.steps import build_cell_program
from repro.runtime.train_loop import (LoopConfig, SimulatedFailure,
                                      run_training)


@pytest.fixture()
def prog():
    arch = get_arch("sd15-small")
    cell = get_shape("diffusion", "train_256")
    return build_cell_program(arch, cell, reduced=True)


def _flat(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# manager basics
# ---------------------------------------------------------------------------


def test_save_restore_bitwise(tmp_path, prog):
    state = prog.init_fn(jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, state, extra={"step": 7, "data": {"seed": 0, "step": 7}})
    restored, extra = mgr.restore(state)
    assert extra["step"] == 7
    for a, b in zip(_flat(state), _flat(restored)):
        np.testing.assert_array_equal(a, b)


def test_async_save_and_retention(tmp_path, prog):
    state = prog.init_fn(jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30, 40):
        mgr.save_async(s, state, extra={"step": s})
    mgr.wait()
    assert mgr.all_steps() == [30, 40]


def test_atomic_publish_no_tmp_leftover(tmp_path, prog):
    state = prog.init_fn(jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, extra={})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4, 4))}, extra={})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((2, 2))})


# ---------------------------------------------------------------------------
# training loop: resume is bitwise-exact
# ---------------------------------------------------------------------------


def test_failure_injection_then_resume_bitwise(tmp_path, prog):
    """Train 12 steps straight vs. crash-at-8 + restart: identical states."""
    loader_a = make_diffusion_loader(prog, n_corpus=64)
    state_a = prog.init_fn(jax.random.key(0))
    mgr_a = CheckpointManager(str(tmp_path / "a"), keep=5)
    cfg = LoopConfig(total_steps=12, ckpt_every=4, log_every=100)
    state_a, rep_a = run_training(prog.step_fn, state_a, loader_a, mgr_a, cfg)

    loader_b = make_diffusion_loader(prog, n_corpus=64)
    state_b = prog.init_fn(jax.random.key(0))
    mgr_b = CheckpointManager(str(tmp_path / "b"), keep=5)
    cfg_fail = LoopConfig(total_steps=12, ckpt_every=4, log_every=100,
                          fail_at=9)
    with pytest.raises(SimulatedFailure):
        run_training(prog.step_fn, state_b, loader_b, mgr_b, cfg_fail)
    # restart from the checkpoint (fresh process simulation)
    loader_b2 = make_diffusion_loader(prog, n_corpus=64)
    state_b2 = prog.init_fn(jax.random.key(0))
    state_b2, rep_b = run_training(prog.step_fn, state_b2, loader_b2, mgr_b,
                                   LoopConfig(total_steps=12, ckpt_every=4,
                                              log_every=100))
    assert rep_b.restarts == 1
    for a, b in zip(_flat(state_a), _flat(state_b2)):
        np.testing.assert_array_equal(a, b)


def test_nan_rollback(tmp_path, prog):
    """A poisoned BATCH rolls the state back to the last checkpoint and
    the data iterator skips past the poisonous window."""
    loader = make_diffusion_loader(prog, n_corpus=64)
    poisoned_step_idx = 5

    orig_batch_at = loader.batch_at

    def batch_at(state):
        b = orig_batch_at(state)
        if state.step == poisoned_step_idx:
            b = dict(b)
            b["images"] = np.full_like(b["images"], np.nan)
        return b

    loader.batch_at = batch_at
    state = prog.init_fn(jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path), keep=5)
    cfg = LoopConfig(total_steps=8, ckpt_every=2, log_every=100,
                     skip_batches_on_rollback=1)
    state, rep = run_training(prog.step_fn, state, loader, mgr, cfg)
    assert rep.rollbacks == 1
    assert rep.steps_done >= 8
    assert np.isfinite(rep.final_loss)


# ---------------------------------------------------------------------------
# data pipeline determinism (the property resume depends on)
# ---------------------------------------------------------------------------


def test_loader_batches_are_pure_function_of_step():
    arrays = {"x": np.arange(100, dtype=np.float32)}
    a = ShardedDataLoader(arrays, global_batch=8, seed=3)
    b = ShardedDataLoader(arrays, global_batch=8, seed=3)
    for _ in range(5):
        next(b)
    b.skip_to(0)
    for _ in range(3):
        np.testing.assert_array_equal(next(a)["x"], next(b)["x"])


def test_loader_host_sharding_partitions_batch():
    arrays = {"x": np.arange(64, dtype=np.int64)}
    hosts = [ShardedDataLoader(arrays, global_batch=8, seed=0,
                               host_index=i, host_count=4) for i in range(4)]
    parts = [next(h)["x"] for h in hosts]
    merged = np.concatenate(parts)
    solo = ShardedDataLoader(arrays, global_batch=8, seed=0)
    np.testing.assert_array_equal(merged, next(solo)["x"])


# ---------------------------------------------------------------------------
# elastic resharding
# ---------------------------------------------------------------------------


def test_parse_spec_roundtrip():
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    assert parse_spec("PartitionSpec('data', None)", mesh) == P("data", None)
    # axes missing from the new mesh degrade to replication
    assert parse_spec("PartitionSpec(('pod', 'data'),)", mesh) == P(("data",))
    assert parse_spec("PartitionSpec('model',)", mesh) == P(None)
    assert parse_spec("", mesh) == P()


def test_reshard_checkpoint_onto_new_mesh(tmp_path, prog):
    state = prog.init_fn(jax.random.key(0))
    mgr = CheckpointManager(str(tmp_path))
    from jax.sharding import PartitionSpec as P
    specs = jax.tree_util.tree_map(lambda _: P(), state)
    mgr.save(3, state, extra={"step": 3}, specs=specs)
    mesh = jax.make_mesh((1,), ("data",))
    restored, extra = reshard_checkpoint(mgr, state, mesh)
    assert extra["step"] == 3
    for a, b in zip(_flat(state), _flat(restored)):
        np.testing.assert_array_equal(a, b)
