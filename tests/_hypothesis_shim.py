"""Minimal stand-in for `hypothesis` when it is not installed.

The tier-1 suite must collect and run inside the offline container, which
ships no `hypothesis`.  This shim implements exactly the surface the test
modules use — ``@settings(max_examples=..., deadline=...)``, ``@given`` with
positional or keyword strategies, and the handful of strategies below —
driven by seeded pseudo-random examples (deterministic per test function).

It is NOT a replacement for real property testing: there is no shrinking,
no example database, and only light edge-case bias.  When the real
`hypothesis` is importable, test modules prefer it (see the try/except at
their import sites).
"""
from __future__ import annotations

import hashlib
import random


class Strategy:
    """A strategy is just a seeded example generator."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


# Small deterministic character pools per unicode category — enough to
# exercise tokenizer/text properties without a full unicodedata scan.
_CATEGORY_POOLS = {
    "Ll": "abcdefghijklmnopqrstuvwxyzßàéñαω",
    "Zs": "    ",
}


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (imported ``as st``)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 0) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> Strategy:
        def draw(rng):
            roll = rng.random()
            if roll < 0.05:        # bias toward the boundary values
                return float(min_value)
            if roll < 0.10:
                return float(max_value)
            return min_value + rng.random() * (max_value - min_value)
        return Strategy(draw)

    @staticmethod
    def sampled_from(elements) -> Strategy:
        pool = list(elements)
        return Strategy(lambda rng: pool[rng.randrange(len(pool))])

    @staticmethod
    def characters(whitelist_categories=()) -> Strategy:
        pool = "".join(_CATEGORY_POOLS.get(c, "") for c in whitelist_categories)
        pool = pool or "abcdefghijklmnopqrstuvwxyz"
        return Strategy(lambda rng: pool[rng.randrange(len(pool))])

    @staticmethod
    def text(alphabet=None, min_size: int = 0, max_size: int = 10) -> Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            if alphabet is None:
                return "".join(chr(rng.randint(97, 122)) for _ in range(n))
            if isinstance(alphabet, Strategy):
                return "".join(alphabet.example(rng) for _ in range(n))
            return "".join(alphabet[rng.randrange(len(alphabet))]
                           for _ in range(n))
        return Strategy(draw)

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0, max_size: int = 10,
              unique: bool = False) -> Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            if not unique:
                return [elements.example(rng) for _ in range(n)]
            out, seen, tries = [], set(), 0
            while len(out) < n and tries < 50 * (n + 1):
                v = elements.example(rng)
                tries += 1
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out
        return Strategy(draw)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    """Records max_examples on the (possibly already @given-wrapped) test."""

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    """Runs the test once per example with values drawn from the strategies.

    The RNG seed derives from the test's qualified name, so example streams
    are stable across runs and processes (no flaky property tests).
    """

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", 20)
            digest = hashlib.sha256(fn.__qualname__.encode()).hexdigest()
            rng = random.Random(int(digest[:16], 16))
            for _ in range(n):
                drawn = [s.example(rng) for s in arg_strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*drawn, **drawn_kw)
        # NOT functools.wraps: copying __wrapped__ would make pytest
        # introspect the original signature and hunt fixtures named after
        # the strategy parameters.  The test takes no fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__dict__.update(getattr(fn, "__dict__", {}))
        return wrapper

    return deco
