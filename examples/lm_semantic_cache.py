"""Beyond-paper example: the semantic cache adapted to LM serving.

The paper's HIT_RETURN branch ported to the assigned LM architectures
(DESIGN.md §Arch-applicability): near-duplicate prompts return the cached
completion; misses decode with the reduced qwen2-class model and archive.
There is no img2img middle band — tokens are discrete.

    PYTHONPATH=src python examples/lm_semantic_cache.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_shape
from repro.core.embeddings import BertProxyEmbedder
from repro.models.transformer.lm import apply_lm, init_lm
from repro.runtime.serving import LMResponseCache


def main() -> None:
    arch = get_arch("qwen2-0.5b")
    cfg = arch.make_reduced()
    params = init_lm(jax.random.key(0), cfg)
    emb = BertProxyEmbedder()

    from repro.data.tokenizer import HashTokenizer
    tok = HashTokenizer(vocab_size=cfg.vocab)

    @jax.jit
    def greedy_decode(tokens):
        logits, _ = apply_lm(params, cfg, tokens)
        return jnp.argmax(logits[:, -1], -1)

    def generate(prompt: str, n_tokens: int = 8) -> str:
        ids = tok.encode(prompt, max_len=24, add_eos=False)
        out = []
        cur = jnp.asarray(ids)[None]
        for _ in range(n_tokens):
            nxt = greedy_decode(cur)
            out.append(int(nxt[0]))
            cur = jnp.concatenate([cur[:, 1:], nxt[:, None]], axis=1)
        return " ".join(map(str, out))

    cache = LMResponseCache(embed=lambda p: emb.embed_text([p])[0],
                            hit_threshold=0.9)
    prompts = [
        "describe a small red circle on a black background",
        "what is a large blue square",
        "describe a small red circle on a black background",   # exact repeat
        "describe the small red circle on black background",   # near-dup
        "explain a purple triangle at the left",
    ]
    for p in prompts:
        t0 = time.perf_counter()
        hit = cache.lookup(p)
        if hit is None:
            resp = generate(p)
            cache.insert(p, resp)
            kind = "MISS->decode"
        else:
            resp, kind = hit, "HIT (cached)"
        print(f"[{kind:12s}] {time.perf_counter()-t0:6.3f}s  {p[:46]}")
    print(f"\nhit rate: {cache.hit_rate:.2f} "
          f"({cache.hits} hits / {cache.misses} misses)")


if __name__ == "__main__":
    main()
