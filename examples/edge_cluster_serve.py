"""Edge-cluster serving scenario: heterogeneous nodes, score-aware request
scheduling, continuous batching under a timestamped arrival process, node
failure, cache maintenance, and the historical-query fast path — the
operational story of §V/§VI, runnable on one CPU.

    PYTHONPATH=src python examples/edge_cluster_serve.py

The main run uses score-aware routing (the default; on the CLI:
``python -m repro.launch.serve --routing score``) — every request is
routed on its true best composite match per node from the one fused
cluster scan.  Phase 4 replays the same workload under the Eq. 6
centroid baseline (``--routing centroid``) and prints the hit-rate delta.
"""
from __future__ import annotations

import numpy as np

from repro.core.trace import RequestTrace, bursty_arrivals, poisson_arrivals
from repro.launch.serve import _stage_wall_arrays, build_system
from repro.runtime.serving import ServingEngine


def _queue_stats(done):
    qd = np.array([c.queue_delay for c in done])
    return (f"queue delay p50={np.percentile(qd, 50) * 1e3:.1f}ms "
            f"p95={np.percentile(qd, 95) * 1e3:.1f}ms")


def main() -> None:
    system, _, _, _ = build_system(
        n_nodes=4, corpus_n=500, capacity_per_node=150,
        node_speeds=[1.0, 1.0, 0.82, 0.45],     # 4090D/4090D/3090/2070S
        routing="score")                        # route on true best match
    system.cache_capacity = 500
    engine = ServingEngine(system, max_batch=8)

    trace = RequestTrace(seed=2, repeat_rate=0.15, quality_rate=0.1)
    reqs = list(trace.generate(240))

    print("phase 1: steady Poisson traffic (120 requests, 60 req/s offered, "
          "--routing score)")
    done = engine.run(poisson_arrivals(reqs[:120], rate=60.0, seed=2))
    st = system.stats
    print(f"  routes={st.route_counts}  hit_rate={st.hit_rate:.2f}  "
          f"mean_latency={np.mean(st.latencies):.3f}s")
    print(f"  {_queue_stats(done)}  (continuous batching; true per-request "
          f"wait, not batch-amortised)")

    print("phase 2: node 2 (RTX 3090) fails mid-storm — bursty arrivals "
          "reroute")
    engine.fail_node(2)
    t1 = max(c.finished_at for c in done)
    burst = bursty_arrivals(reqs[120:], burst_size=12, burst_gap=0.5,
                            start=t1, seed_base=120)  # same timeline,
    done2 = engine.run(burst, start=t1)               # fresh noise seeds
    st = system.stats
    served_after = len(st.latencies)
    print(f"  total served={served_after} (no request dropped)  "
          f"hit_rate={st.hit_rate:.2f}  {_queue_stats(done2)}")
    walls = _stage_wall_arrays(done2)
    top = sorted(walls, key=lambda k: -float(np.mean(walls[k])))[:3]
    print("  hottest stages: " + "  ".join(
        f"{k} {np.mean(walls[k]) * 1e3:.1f}ms" for k in top))

    print("phase 3: LCU cache maintenance")
    before = system.total_size
    system.cache_capacity = int(before * 0.7)
    evicted = system.maintain()
    n_evicted = sum(len(v) for v in evicted.values())
    print(f"  cache {before} -> {system.total_size} entries "
          f"({n_evicted} semantic outliers evicted, blob store synced)")

    print("phase 4: score-aware vs centroid routing on the same workload")
    score_hit = _replay_hit_rate(reqs[:120], routing="score")
    cent_hit = _replay_hit_rate(reqs[:120], routing="centroid")
    print(f"  hit_rate: score={score_hit:.3f}  centroid={cent_hit:.3f}  "
          f"delta={score_hit - cent_hit:+.3f}  (score mode routes each "
          f"request to the node whose cache actually holds its best "
          f"reference — one fused cluster scan per micro-batch)")

    print(f"\nhistory fast-path hits: {system.scheduler.history_hits}")
    print(f"final route mix: {st.route_counts}")


def _replay_hit_rate(reqs, *, routing: str) -> float:
    """Fresh small fleet, identical trace, selected routing mode."""
    system, _, _, _ = build_system(
        n_nodes=4, corpus_n=500, capacity_per_node=60, routing=routing)
    engine = ServingEngine(system, max_batch=8)
    engine.run(poisson_arrivals(reqs, rate=60.0, seed=3))
    return system.stats.hit_rate


if __name__ == "__main__":
    main()
