"""Edge-cluster serving scenario: heterogeneous nodes, node failure,
cache maintenance, and the historical-query fast path — the operational
story of §V/§VI, runnable on one CPU.

    PYTHONPATH=src python examples/edge_cluster_serve.py
"""
from __future__ import annotations

import numpy as np

from repro.core.trace import RequestTrace
from repro.launch.serve import build_system
from repro.runtime.serving import ServingEngine


def main() -> None:
    system, _, _, _ = build_system(
        n_nodes=4, corpus_n=500, capacity_per_node=150,
        node_speeds=[1.0, 1.0, 0.82, 0.45])     # 4090D/4090D/3090/2070S
    system.cache_capacity = 500
    engine = ServingEngine(system, max_batch=8)

    trace = RequestTrace(seed=2, repeat_rate=0.15, quality_rate=0.1)
    reqs = list(trace.generate(240))

    print("phase 1: normal operation (120 requests)")
    for i, r in enumerate(reqs[:120]):
        engine.submit(r.prompt, seed=i, quality_tier=r.quality_tier)
    engine.drain()
    st = system.stats
    print(f"  routes={st.route_counts}  hit_rate={st.hit_rate:.2f}  "
          f"mean_latency={np.mean(st.latencies):.3f}s")
    print(f"  wall: p50={np.percentile(st.wall_latencies, 50) * 1e3:.1f}ms "
          f"p95={np.percentile(st.wall_latencies, 95) * 1e3:.1f}ms "
          f"(batch-amortised over {len(st.batch_wall_latencies)} "
          f"micro-batches)")

    print("phase 2: node 2 (RTX 3090) fails — traffic reroutes")
    engine.fail_node(2)
    for i, r in enumerate(reqs[120:]):
        engine.submit(r.prompt, seed=120 + i, quality_tier=r.quality_tier)
    engine.drain()
    st = system.stats
    served_after = len(st.latencies)
    print(f"  total served={served_after} (no request dropped)  "
          f"hit_rate={st.hit_rate:.2f}")

    print("phase 3: LCU cache maintenance")
    before = system.total_size
    system.cache_capacity = int(before * 0.7)
    evicted = system.maintain()
    n_evicted = sum(len(v) for v in evicted.values())
    print(f"  cache {before} -> {system.total_size} entries "
          f"({n_evicted} semantic outliers evicted, blob store synced)")

    print(f"\nhistory fast-path hits: {system.scheduler.history_hits}")
    print(f"final route mix: {st.route_counts}")


if __name__ == "__main__":
    main()
