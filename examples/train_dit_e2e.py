"""End-to-end driver: train the paper-reproduction diffusion stack on CPU.

Trains the tiny VAE (reconstruction + KL) and then the tiny DiT
(eps-prediction MSE) on the synthetic captioned corpus for a few hundred
steps through the fault-tolerant training loop (checkpoints + exact
resume), then samples a grid of images with both workflows:

  * text-to-image (N=30 DDIM steps from noise) and
  * image-to-image (K=20 SDEdit steps from a cached reference),

reporting PSNR against the target renders — Figure 1's mechanism, live.

    PYTHONPATH=src python examples/train_dit_e2e.py --steps 300
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common as C
from repro.data.synthetic import (SceneSpec, caption_of, random_spec,
                                  render_scene)
from repro.models.diffusion import dit as dit_mod
from repro.models.diffusion import vae as vae_mod
from repro.models.diffusion.sampler import ddim_sample, sdedit_sample

import jax.numpy as jnp


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="DiT training steps (VAE gets 2× this)")
    ap.add_argument("--corpus", type=int, default=400)
    args = ap.parse_args()

    images, captions, _ = C.make_corpus(args.corpus, res=C.IMG_RES, seed=0)
    from repro.core.embeddings import ProxyClipEmbedder
    from repro.data.synthetic import render_caption
    embedder = ProxyClipEmbedder(render_caption)
    ctx = embedder.embed_text(captions).astype(np.float32)

    print(f"training VAE ({2 * args.steps} steps) ...")
    vae_params, rec = C._train_vae(images, steps=2 * args.steps)
    print(f"  reconstruction MSE: {rec:.5f}")
    print(f"training DiT ({args.steps} steps) ...")
    dit_params, loss = C._train_dit(images, ctx, vae_params,
                                    steps=args.steps)
    print(f"  eps-prediction loss: {loss:.5f}")

    # ---- Figure-1 style comparison -------------------------------------
    dcfg, vcfg = C._dit_cfg(), C._vae_cfg()
    eps_fn = dit_mod.make_eps_fn(dit_params, dcfg)
    rng = np.random.default_rng(0)
    t2i_psnr, i2i_psnr = [], []
    for i in range(8):
        spec = random_spec(rng)
        target = render_scene(spec, C.IMG_RES)
        ref = render_scene(SceneSpec("ring" if spec.shape != "ring"
                                     else "circle", spec.color,
                                     spec.background, spec.size,
                                     spec.position), C.IMG_RES)
        cvec = jnp.asarray(embedder.embed_text([caption_of(spec)]))
        z_t2i = ddim_sample(eps_fn, C.SCHED,
                            (1, dcfg.img_res, dcfg.img_res, dcfg.in_ch),
                            cvec, jax.random.key(i), steps=30)
        mean, _ = vae_mod.encode(vae_params, vcfg, jnp.asarray(ref)[None])
        z_i2i = sdedit_sample(eps_fn, C.SCHED, mean * C.LATENT_SCALE, cvec,
                              jax.random.key(i + 99), steps=20, strength=0.6)
        img_t2i = np.asarray(vae_mod.decode(vae_params, vcfg,
                                            z_t2i / C.LATENT_SCALE)[0])
        img_i2i = np.asarray(vae_mod.decode(vae_params, vcfg,
                                            z_i2i / C.LATENT_SCALE)[0])
        t2i_psnr.append(C.psnr(img_t2i, target))
        i2i_psnr.append(C.psnr(img_i2i, target))

    print(f"\ntext-to-image  (30 steps): PSNR {np.mean(t2i_psnr):.2f} dB")
    print(f"image-to-image (20 steps): PSNR {np.mean(i2i_psnr):.2f} dB")
    print("=> the img2img workflow reaches comparable/better quality with "
          "fewer denoising steps — the paper's Figure 1.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
