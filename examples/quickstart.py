"""Quickstart: the CacheGenius request path in ~40 lines.

Builds a 4-node edge fleet over the synthetic reference corpus, serves a
handful of prompts through Algorithm 1 (direct-return / img2img /
txt2img), and prints the route, Eq. 8 latency, and composite score per
request plus the fleet-level stats the paper reports.

    PYTHONPATH=src python examples/quickstart.py
"""
from __future__ import annotations

import numpy as np

from repro.core.trace import RequestTrace
from repro.launch.serve import build_system


def main() -> None:
    system, embedder, images, captions = build_system(
        n_nodes=4, corpus_n=400, capacity_per_node=200)
    print(f"fleet: {len(system.dbs)} nodes, "
          f"{system.total_size} cached references, "
          f"modal consistency {system.classifier.modal_consistency:.2f}")

    prompts = [r.prompt for r in RequestTrace(seed=5).generate(12)]
    for i, p in enumerate(prompts):
        r = system.serve(p, seed=i)
        print(f"[{r.route.value:10s}] node={r.node} steps={r.steps:2d} "
              f"score={r.score:.3f} latency={r.latency:.3f}s  {p[:48]}")

    st = system.stats
    print(f"\nroutes: {st.route_counts}")
    print(f"hit rate: {st.hit_rate:.2f}   "
          f"mean Eq.8 latency: {np.mean(st.latencies):.3f}s")


if __name__ == "__main__":
    main()
