"""Train the REAL dual-tower embedder (§IV-B) contrastively on the corpus.

The benchmarks use the deterministic CLIP proxy; this example shows the
trainable path: a tiny ViT-ish image tower + the text transformer from
``repro.models.diffusion.text_encoder``, trained with the symmetric InfoNCE
loss CLIP uses, then plugged into the SAME CacheGenius stack via
:class:`repro.core.embeddings.TowerEmbedder`.

    PYTHONPATH=src python examples/train_clip_tower.py --steps 300
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embeddings import TowerEmbedder
from repro.data.synthetic import make_corpus
from repro.data.tokenizer import HashTokenizer
from repro.models.common import layers as L
from repro.models.diffusion.text_encoder import (TextEncoderConfig,
                                                 apply_text_encoder,
                                                 init_text_encoder)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

EMBED_DIM = 512


def init_image_tower(key, *, res=32, patch=8, d=128, n_layers=2, heads=4,
                     param_dtype=jnp.float32):
    """Tiny ViT: patchify → transformer → mean-pool → 512-d projection."""
    ks = jax.random.split(key, 4 + n_layers)
    n_tok = (res // patch) ** 2
    blocks = []
    for i in range(n_layers):
        k1, k2, k3 = jax.random.split(ks[4 + i], 3)
        blocks.append({
            "ln1": L.init_layernorm(d, param_dtype),
            "qkv": L.init_dense(k1, d, 3 * d, param_dtype=param_dtype),
            "proj": L.init_dense(k2, d, d, param_dtype=param_dtype),
            "ln2": L.init_layernorm(d, param_dtype),
            "mlp": L.init_mlp(k3, d, 4 * d, param_dtype=param_dtype),
        })
    return {
        "patch": L.init_dense(ks[0], patch * patch * 3, d, use_bias=True,
                              param_dtype=param_dtype),
        "pos": L._normal(ks[1], (n_tok, d), 0.02, param_dtype),
        "blocks": blocks,
        "out": L.init_dense(ks[2], d, EMBED_DIM, param_dtype=param_dtype),
        "logit_scale": jnp.asarray(2.6, param_dtype),
    }


def apply_image_tower(p, images, *, patch=8):
    from repro.models.common.attention import sdpa
    x = L.patchify(images, patch)
    x = L.dense(p["patch"], x) + p["pos"][None]
    for blk in p["blocks"]:
        h = L.layernorm(blk["ln1"], x)
        b, t, d = h.shape
        qkv = L.dense(blk["qkv"], h).reshape(b, t, 3, 4, d // 4)
        att = sdpa(qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2], causal=False)
        x = x + L.dense(blk["proj"], att.reshape(b, t, d))
        x = x + L.mlp(blk["mlp"], L.layernorm(blk["ln2"], x))
    pooled = jnp.mean(x, axis=1)
    v = L.dense(p["out"], pooled)
    return v / jnp.linalg.norm(v, axis=-1, keepdims=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--corpus", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    images, captions, _ = make_corpus(args.corpus, res=32, seed=0)
    tok = HashTokenizer(vocab_size=4096)
    tokens = tok.encode_batch(captions, max_len=24)
    tcfg = TextEncoderConfig(vocab=4096, max_len=24, n_layers=2, d_model=128,
                             n_heads=4, out_dim=128, pool_dim=EMBED_DIM)

    key = jax.random.key(0)
    params = {
        "img": init_image_tower(jax.random.split(key)[0]),
        "txt": init_text_encoder(jax.random.split(key)[1], tcfg),
    }
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=1e-4)

    @jax.jit
    def step(params, opt, imgs, toks):
        def loss_fn(p):
            iv = apply_image_tower(p["img"], imgs)
            _, tv = apply_text_encoder(p["txt"], tcfg, toks)
            tv = tv / jnp.linalg.norm(tv, axis=-1, keepdims=True)
            logits = iv @ tv.T * jnp.exp(p["img"]["logit_scale"])
            labels = jnp.arange(imgs.shape[0])
            li = -jnp.mean(jax.nn.log_softmax(logits, 0)[labels, labels])
            lt = -jnp.mean(jax.nn.log_softmax(logits, 1)[labels, labels])
            return 0.5 * (li + lt)

        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
        return params, opt, loss

    rng = np.random.default_rng(0)
    for i in range(args.steps):
        idx = rng.integers(0, len(images), args.batch)
        params, opt, loss = step(params, opt, jnp.asarray(images[idx]),
                                 jnp.asarray(tokens[idx]))
        if i % 50 == 0:
            print(f"step {i:4d}  contrastive loss {float(loss):.4f}")

    # retrieval accuracy: does each caption find its own image?
    embedder = TowerEmbedder(
        params,
        apply_text=lambda p, prompts: apply_text_encoder(
            p["txt"], tcfg,
            jnp.asarray(tok.encode_batch(list(prompts), max_len=24)))[1],
        apply_image=lambda p, imgs: apply_image_tower(
            p["img"], jnp.asarray(imgs, jnp.float32)))
    n_eval = 128
    iv = embedder.embed_image(images[:n_eval])
    tv = embedder.embed_text(captions[:n_eval])
    ranks = np.argmax(tv @ iv.T, axis=1)
    acc = float(np.mean(ranks == np.arange(n_eval)))
    print(f"\ntext→image retrieval top-1 over {n_eval}: {acc:.3f} "
          f"(chance {1 / n_eval:.3f})")
    assert acc > 5.0 / n_eval, "tower failed to learn alignment"
    print("TowerEmbedder is drop-in compatible with CacheGenius "
          "(same embed_text/embed_image/clip_score/pick_score interface).")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
